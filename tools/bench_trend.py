#!/usr/bin/env python3
"""Perf-trajectory regression gate for BENCH_*.json files.

CI regenerates each BENCH file on every run; this script compares the
freshly generated numbers against the committed baseline (the same file
at a git ref, default HEAD) and fails when any case's `units_per_s`
drops below `threshold x baseline`.  Zero-dependency by design; shells
out only to `git show`.

Rules, tuned for noisy shared CI runners:

  * a missing baseline (file not at the ref, or case name not in the
    baseline) is a PASS — new benches enter the trajectory silently;
  * a workload-size mismatch (`records` differs between current and
    baseline) skips the file — throughput at different scales is not
    comparable;
  * the summary ratio fields (speedups, binary/json ratio) are reported
    but never gated: they are self-relative and already schema-checked
    by check_bench.py.

Usage:
    python3 tools/bench_trend.py [--ref REF] [--threshold T] [FILE...]

With no FILEs, checks every BENCH_*.json in the repo root that exists
both in the worktree and at REF.  Exits non-zero listing every
regression found.
"""

import glob
import json
import os
import subprocess
import sys

DEFAULT_THRESHOLD = 0.25


def load_current(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def load_baseline(root, path, ref):
    rel = os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{rel}"],
            cwd=root,
            capture_output=True,
            check=True,
        ).stdout
    except subprocess.CalledProcessError:
        return None  # not committed at the ref: no baseline to gate on
    try:
        return json.loads(out.decode("utf-8"))
    except Exception:  # noqa: BLE001 - a rotten baseline must not block CI
        return None


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def case_rates(doc):
    rates = {}
    for case in doc.get("cases", []) or []:
        if not isinstance(case, dict):
            continue
        name, rate = case.get("name"), case.get("units_per_s")
        if isinstance(name, str) and is_num(rate) and rate > 0:
            rates[name] = rate
    return rates


def check_file(root, path, ref, threshold, problems):
    try:
        cur = load_current(path)
    except Exception as e:  # noqa: BLE001 - report, don't crash
        problems.append(f"{path}: unreadable current file ({e})")
        return
    base = load_baseline(root, path, ref)
    if base is None:
        print(f"{path}: no baseline at {ref}, pass")
        return
    if cur.get("records") != base.get("records"):
        print(
            f"{path}: workload changed "
            f"({base.get('records')} -> {cur.get('records')} records), skip"
        )
        return
    base_rates = case_rates(base)
    checked = 0
    for name, rate in sorted(case_rates(cur).items()):
        old = base_rates.get(name)
        if old is None:
            continue
        checked += 1
        ratio = rate / old
        status = "ok" if ratio >= threshold else "REGRESSION"
        print(
            f"{path}: {name}: {rate:.1f} vs baseline {old:.1f} "
            f"units/s ({ratio:.2f}x, floor {threshold:.2f}x) {status}"
        )
        if ratio < threshold:
            problems.append(
                f"{path}: '{name}' fell to {ratio:.2f}x of baseline "
                f"(floor {threshold:.2f}x)"
            )
    if checked == 0:
        print(f"{path}: no comparable cases, pass")


def main():
    argv = sys.argv[1:]
    ref = "HEAD"
    threshold = DEFAULT_THRESHOLD
    paths = []
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg == "--ref" and i + 1 < len(argv):
            ref = argv[i + 1]
            i += 2
        elif arg == "--threshold" and i + 1 < len(argv):
            try:
                threshold = float(argv[i + 1])
            except ValueError:
                print(f"bad --threshold {argv[i + 1]!r}", file=sys.stderr)
                return 2
            i += 2
        elif arg in ("-h", "--help"):
            print(__doc__)
            return 0
        else:
            paths.append(arg)
            i += 1
    if not (0.0 < threshold <= 1.0):
        print(f"--threshold must be in (0, 1], got {threshold}",
              file=sys.stderr)
        return 2
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if not paths:
        paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    problems = []
    for path in paths:
        if not os.path.isfile(path):
            problems.append(f"{path}: no such file")
            continue
        check_file(root, path, ref, threshold, problems)
    if problems:
        for p in problems:
            print(f"BENCH REGRESSION: {p}", file=sys.stderr)
        return 1
    print(f"bench trend ok ({len(paths)} file(s), ref {ref})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
