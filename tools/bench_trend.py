#!/usr/bin/env python3
"""Perf-trajectory regression gate for BENCH_*.json files.

CI regenerates each BENCH file on every run; this script compares the
freshly generated numbers against the committed baseline (the same file
at a git ref, default HEAD) and fails when any case's `units_per_s`
drops below `threshold x baseline`.  Zero-dependency by design; shells
out only to `git show`.

Rules, tuned for noisy shared CI runners:

  * a missing baseline (file not at the ref, or a *current* case name
    not in the baseline) is a PASS — new benches enter the trajectory
    silently;
  * a *baseline* case missing from the current file is a FAILURE — a
    bench that silently stops being measured is indistinguishable from
    a bench that regressed to zero;
  * a workload-size mismatch (`records` differs between current and
    baseline) skips the file — throughput at different scales is not
    comparable;
  * the summary ratio fields (speedups, binary/json ratio) are reported
    but never gated: they are self-relative and already schema-checked
    by check_bench.py.

Usage:
    python3 tools/bench_trend.py [--ref REF] [--threshold T] [FILE...]
    python3 tools/bench_trend.py --self-test

With no FILEs, checks every BENCH_*.json in the repo root that exists
both in the worktree and at REF.  Exits non-zero listing every
regression found.  `--self-test` runs the comparison logic against
synthetic documents (no git, no files) and is wired into CI so the
gate itself stays gated.
"""

import glob
import json
import os
import subprocess
import sys

DEFAULT_THRESHOLD = 0.25


def load_current(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def load_baseline(root, path, ref):
    rel = os.path.relpath(os.path.abspath(path), root).replace(os.sep, "/")
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{rel}"],
            cwd=root,
            capture_output=True,
            check=True,
        ).stdout
    except subprocess.CalledProcessError:
        return None  # not committed at the ref: no baseline to gate on
    try:
        return json.loads(out.decode("utf-8"))
    except Exception:  # noqa: BLE001 - a rotten baseline must not block CI
        return None


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def case_rates(doc):
    rates = {}
    for case in doc.get("cases", []) or []:
        if not isinstance(case, dict):
            continue
        name, rate = case.get("name"), case.get("units_per_s")
        if isinstance(name, str) and is_num(rate) and rate > 0:
            rates[name] = rate
    return rates


def compare_docs(path, cur, base, threshold, problems):
    """Gate current doc `cur` against baseline doc `base`.

    Appends one entry to `problems` per regression: a comparable case
    below `threshold` x baseline, or a baseline case that vanished from
    the current file.  Pure (no git, no filesystem) so --self-test can
    drive it with synthetic documents.
    """
    if cur.get("records") != base.get("records"):
        print(
            f"{path}: workload changed "
            f"({base.get('records')} -> {cur.get('records')} records), skip"
        )
        return
    base_rates = case_rates(base)
    cur_rates = case_rates(cur)
    for name in sorted(set(base_rates) - set(cur_rates)):
        print(f"{path}: {name}: in baseline but not in current file LOST")
        problems.append(
            f"{path}: baseline case '{name}' missing from current file "
            "(a bench that stops being measured is a regression)"
        )
    checked = 0
    for name, rate in sorted(cur_rates.items()):
        old = base_rates.get(name)
        if old is None:
            continue
        checked += 1
        ratio = rate / old
        status = "ok" if ratio >= threshold else "REGRESSION"
        print(
            f"{path}: {name}: {rate:.1f} vs baseline {old:.1f} "
            f"units/s ({ratio:.2f}x, floor {threshold:.2f}x) {status}"
        )
        if ratio < threshold:
            problems.append(
                f"{path}: '{name}' fell to {ratio:.2f}x of baseline "
                f"(floor {threshold:.2f}x)"
            )
    if checked == 0 and cur_rates.keys() >= base_rates.keys():
        print(f"{path}: no comparable cases, pass")


def check_file(root, path, ref, threshold, problems):
    try:
        cur = load_current(path)
    except Exception as e:  # noqa: BLE001 - report, don't crash
        problems.append(f"{path}: unreadable current file ({e})")
        return
    base = load_baseline(root, path, ref)
    if base is None:
        print(f"{path}: no baseline at {ref}, pass")
        return
    compare_docs(path, cur, base, threshold, problems)


def self_test():
    """Exercise compare_docs against synthetic docs; no git required."""

    def doc(records, **rates):
        return {
            "records": records,
            "cases": [
                {"name": n, "units_per_s": r} for n, r in rates.items()
            ],
        }

    def run(cur, base, threshold=DEFAULT_THRESHOLD):
        problems = []
        compare_docs("<self-test>", cur, base, threshold, problems)
        return problems

    failures = []

    def expect(label, problems, want_fragments):
        got = len(problems)
        if got != len(want_fragments):
            failures.append(
                f"{label}: expected {len(want_fragments)} problem(s), "
                f"got {got}: {problems}"
            )
            return
        for frag, p in zip(want_fragments, problems):
            if frag not in p:
                failures.append(f"{label}: {p!r} does not mention {frag!r}")

    steady = doc(1000, open_cold=40.0, open_warm=400.0)
    expect("identical docs pass", run(steady, steady), [])
    expect(
        "drop below floor fails",
        run(doc(1000, open_cold=9.0, open_warm=400.0), steady),
        ["'open_cold' fell to 0.23x"],
    )
    expect(
        "drop above floor passes",
        run(doc(1000, open_cold=11.0, open_warm=400.0), steady),
        [],
    )
    expect(
        "baseline case lost from current fails",
        run(doc(1000, open_cold=40.0), steady),
        ["baseline case 'open_warm' missing from current file"],
    )
    expect(
        "new current case absent from baseline passes",
        run(doc(1000, open_cold=40.0, open_warm=400.0, fresh=1.0), steady),
        [],
    )
    expect(
        "workload-size mismatch skips even lost cases",
        run(doc(500, open_cold=1.0), steady),
        [],
    )
    expect(
        "unrateable baseline cases are not gated",
        run(
            doc(1000, open_cold=40.0),
            {
                "records": 1000,
                "cases": [
                    {"name": "open_cold", "units_per_s": 40.0},
                    {"name": "zero_rate", "units_per_s": 0},
                    {"name": "bool_rate", "units_per_s": True},
                    "not-a-dict",
                ],
            },
        ),
        [],
    )
    expect(
        "custom threshold applies",
        run(doc(1000, open_cold=20.0, open_warm=400.0), steady, 0.75),
        ["'open_cold' fell to 0.50x"],
    )

    if failures:
        for f in failures:
            print(f"SELF-TEST FAILURE: {f}", file=sys.stderr)
        return 1
    print("bench_trend self-test ok (8 checks)")
    return 0


def main():
    argv = sys.argv[1:]
    ref = "HEAD"
    threshold = DEFAULT_THRESHOLD
    paths = []
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg == "--ref" and i + 1 < len(argv):
            ref = argv[i + 1]
            i += 2
        elif arg == "--threshold" and i + 1 < len(argv):
            try:
                threshold = float(argv[i + 1])
            except ValueError:
                print(f"bad --threshold {argv[i + 1]!r}", file=sys.stderr)
                return 2
            i += 2
        elif arg == "--self-test":
            return self_test()
        elif arg in ("-h", "--help"):
            print(__doc__)
            return 0
        else:
            paths.append(arg)
            i += 1
    if not (0.0 < threshold <= 1.0):
        print(f"--threshold must be in (0, 1], got {threshold}",
              file=sys.stderr)
        return 2
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if not paths:
        paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    problems = []
    for path in paths:
        if not os.path.isfile(path):
            problems.append(f"{path}: no such file")
            continue
        check_file(root, path, ref, threshold, problems)
    if problems:
        for p in problems:
            print(f"BENCH REGRESSION: {p}", file=sys.stderr)
        return 1
    print(f"bench trend ok ({len(paths)} file(s), ref {ref})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
