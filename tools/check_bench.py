#!/usr/bin/env python3
"""Schema check for BENCH_*.json perf-trajectory files.

`mrtuner bench store|campaign|serve|trainer` emits machine-readable benchmark
summaries; CI generates one per run and this script fails the build if
an emitted — or committed — file is malformed, so the perf trajectory
stays parseable forever.  Zero-dependency by design.

Usage:
    python3 tools/check_bench.py FILE [FILE...]   # check specific files
    python3 tools/check_bench.py                  # check every committed
                                                  # BENCH_*.json in the
                                                  # repo root
Exits non-zero listing every problem found; checking zero files is a
pass (no trajectory data yet is fine, malformed data is not).
"""

import glob
import json
import os
import sys

# The per-bench summary metric that must be present and positive, and
# the per-bench determinism flags that must be present and true.
SUMMARY_KEYS = {
    "store": "sharded_vs_single_open_speedup",
    "campaign": "parallel_speedup",
    "serve": "binary_vs_json_throughput_ratio",
    "trainer": "resume_records_per_s",
}
IDENTITY_KEYS = {
    "store": [
        "bit_identical_cold_warm",
        "migration_get_identical",
    ],
    "campaign": [
        "bit_identical_serial_parallel",
        "resume_zero_resim",
    ],
    "serve": [
        "bit_identical_json_binary",
        "monotonic_versions_under_hot_swap",
    ],
    "trainer": ["refits_cover_all_apps"],
}


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check_file(path, problems):
    def bad(msg):
        problems.append(f"{path}: {msg}")

    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except Exception as e:  # noqa: BLE001 - report, don't crash
        bad(f"not valid JSON ({e})")
        return
    if not isinstance(doc, dict):
        bad("top level must be an object")
        return
    bench = doc.get("bench")
    if bench not in SUMMARY_KEYS:
        bad(f"'bench' must be one of {sorted(SUMMARY_KEYS)}, got {bench!r}")
        return
    if doc.get("schema") != 1:
        bad(f"'schema' must be 1, got {doc.get('schema')!r}")
    if not (is_num(doc.get("records")) and doc.get("records", 0) > 0):
        bad("'records' must be a positive number")
    cases = doc.get("cases")
    if not (isinstance(cases, list) and cases):
        bad("'cases' must be a non-empty list")
        cases = []
    for i, case in enumerate(cases):
        where = f"cases[{i}]"
        if not isinstance(case, dict):
            bad(f"{where} must be an object")
            continue
        if not (isinstance(case.get("name"), str) and case["name"]):
            bad(f"{where}.name must be a non-empty string")
        if not (is_num(case.get("iters")) and case.get("iters", 0) >= 1):
            bad(f"{where}.iters must be >= 1")
        for field in ("mean_s", "min_s", "p50_s", "units_per_s"):
            if not (is_num(case.get(field)) and case.get(field, -1) >= 0):
                bad(f"{where}.{field} must be a non-negative number")
    summary = SUMMARY_KEYS[bench]
    if not (is_num(doc.get(summary)) and doc.get(summary, 0) > 0):
        bad(f"'{summary}' must be a positive number")
    for identity in IDENTITY_KEYS[bench]:
        if not isinstance(doc.get(identity), bool):
            bad(f"'{identity}' must be a boolean")
        elif not doc[identity]:
            bad(f"'{identity}' is false — determinism regression")
    if bench == "serve":
        p50 = doc.get("p50_latency_s")
        p99 = doc.get("p99_latency_s")
        for name, val in (("p50_latency_s", p50), ("p99_latency_s", p99)):
            if not (is_num(val) and val >= 0):
                bad(f"'{name}' must be a non-negative number")
        if is_num(p50) and is_num(p99) and p50 > p99:
            bad("'p50_latency_s' exceeds 'p99_latency_s'")
        shed = doc.get("shed_rate")
        if not (is_num(shed) and 0.0 <= shed <= 1.0):
            bad("'shed_rate' must be a number in [0, 1]")
    if bench == "trainer":
        p50 = doc.get("incremental_poll_p50_s")
        if not (is_num(p50) and p50 >= 0):
            bad("'incremental_poll_p50_s' must be a non-negative number")


def main():
    if len(sys.argv) > 1:
        paths = sys.argv[1:]
    else:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    problems = []
    for path in paths:
        if not os.path.isfile(path):
            problems.append(f"{path}: no such file")
            continue
        check_file(path, problems)
    if problems:
        for p in problems:
            print(f"MALFORMED BENCH: {p}", file=sys.stderr)
        return 1
    print(f"all {len(paths)} BENCH file(s) well-formed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
