#!/usr/bin/env python3
"""Markdown link checker for docs/ and README.md.

Verifies that every relative link target in the repo's prose docs exists
on disk (anchors are stripped; external http(s)/mailto links are
skipped).  Zero-dependency by design — runs anywhere python3 does.

Usage: python3 tools/check_links.py  (from the repo root; exits non-zero
on the first pass if any link is broken, listing all of them)
"""

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files(root):
    files = [os.path.join(root, "README.md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                files.append(os.path.join(docs, name))
    return [f for f in files if os.path.isfile(f)]


def check(root):
    broken = []
    checked = 0
    for path in doc_files(root):
        base = os.path.dirname(path)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            checked += 1
            dest = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(dest):
                broken.append((os.path.relpath(path, root), target))
    return checked, broken


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    checked, broken = check(root)
    if broken:
        for src, target in broken:
            print(f"BROKEN LINK in {src}: {target}", file=sys.stderr)
        print(f"{len(broken)} broken link(s) out of {checked}", file=sys.stderr)
        return 1
    print(f"all {checked} relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
