#!/usr/bin/env python3
"""Markdown link checker for docs/ and README.md.

Verifies that every relative link target in the repo's prose docs exists
on disk, and that every anchor fragment (`file.md#section` or a
same-file `#section`) names a real heading in the target document
(GitHub-style slugs).  External http(s)/mailto links are skipped.
Zero-dependency by design — runs anywhere python3 does.

Usage: python3 tools/check_links.py  (from the repo root; exits non-zero
on the first pass if any link or anchor is broken, listing all of them)
"""

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.+?)\s*$", re.M)
CODE_FENCE_RE = re.compile(r"^(```|~~~).*?^\1\s*$", re.M | re.S)
INLINE_CODE_RE = re.compile(r"`([^`]*)`")
MD_LINK_IN_HEADING_RE = re.compile(r"\[([^\]]*)\]\([^)]*\)")


def doc_files(root):
    files = [os.path.join(root, "README.md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                files.append(os.path.join(docs, name))
    return [f for f in files if os.path.isfile(f)]


def slugify(heading):
    """GitHub's anchor algorithm, close enough for our docs: inline code
    and link markup reduce to their text, then lowercase, spaces to
    hyphens, and everything except alphanumerics/hyphens/underscores is
    dropped."""
    text = INLINE_CODE_RE.sub(r"\1", heading)
    text = MD_LINK_IN_HEADING_RE.sub(r"\1", text)
    text = text.strip().lower()
    out = []
    for ch in text:
        if ch.isalnum() or ch == "_":
            out.append(ch)
        elif ch in (" ", "-"):
            out.append("-")
        # anything else: dropped
    return "".join(out)


def anchors_of(path, cache):
    """All heading slugs in a markdown file, with GitHub's -1/-2
    suffixing for duplicates."""
    if path in cache:
        return cache[path]
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # Headings inside code fences are not headings.
    text = CODE_FENCE_RE.sub("", text)
    slugs = set()
    counts = {}
    for heading in HEADING_RE.findall(text):
        slug = slugify(heading)
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    cache[path] = slugs
    return slugs


def check(root):
    broken = []
    checked = 0
    anchor_cache = {}
    for path in doc_files(root):
        base = os.path.dirname(path)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        # Links inside fenced code blocks are examples, not links.
        text = CODE_FENCE_RE.sub("", text)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel, _, fragment = target.partition("#")
            dest = path if not rel else os.path.normpath(os.path.join(base, rel))
            if not rel and not fragment:
                continue
            checked += 1
            if not os.path.exists(dest):
                broken.append((os.path.relpath(path, root), target))
                continue
            if fragment and dest.endswith(".md"):
                if fragment not in anchors_of(dest, anchor_cache):
                    broken.append(
                        (os.path.relpath(path, root), f"{target} (no such anchor)")
                    )
    return checked, broken


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    checked, broken = check(root)
    if broken:
        for src, target in broken:
            print(f"BROKEN LINK in {src}: {target}", file=sys.stderr)
        print(f"{len(broken)} broken link(s) out of {checked}", file=sys.stderr)
        return 1
    print(f"all {checked} relative links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
