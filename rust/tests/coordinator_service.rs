//! Integration: the prediction service + TCP server/client end to end,
//! including concurrency, batching behaviour and failure handling.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use mrtuner::coordinator::client::Client;
use mrtuner::coordinator::{
    ModelRegistry, PipelinedClient, PredictionService, ServeOptions, Server,
    ServiceConfig,
};
use mrtuner::model::features::{evaluate, NUM_FEATURES};
use mrtuner::model::regression::{FitBackend, RegressionModel, RustSolverBackend};

fn test_model(app: &str) -> RegressionModel {
    let mut coeffs = [0.0; NUM_FEATURES];
    coeffs[0] = 250.0;
    coeffs[1] = 120.0;
    coeffs[4] = -30.0;
    RegressionModel { app_name: app.into(), coeffs, trained_on: 20 }
}

fn start_service() -> Arc<PredictionService> {
    let mut reg = ModelRegistry::new();
    reg.insert(test_model("wordcount"));
    reg.insert(test_model("exim"));
    Arc::new(PredictionService::start(
        || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
        reg,
        ServiceConfig::default(),
    ))
}

#[test]
fn many_threads_hammering_the_service() {
    let svc = start_service();
    let mut handles = Vec::new();
    for t in 0..8u32 {
        let svc = Arc::clone(&svc);
        handles.push(std::thread::spawn(move || {
            for i in 0..100u32 {
                let m = 5 + ((t * 100 + i) % 36);
                let r = 5 + (i % 36);
                let app = if i % 2 == 0 { "wordcount" } else { "exim" };
                let got = svc.predict(app, m, r).unwrap();
                let want =
                    evaluate(&test_model(app).coeffs, &[m as f64, r as f64]);
                assert!((got - want).abs() < 1e-9, "t{t} i{i}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = &svc.metrics;
    assert_eq!(m.requests.load(Ordering::Relaxed), 800);
    assert!(m.backend_errors.load(Ordering::Relaxed) == 0);
    // Concurrency must have produced at least some multi-request batches.
    assert!(m.mean_batch_size() > 1.0, "mean batch {}", m.mean_batch_size());
}

#[test]
fn tcp_round_trip() {
    let svc = start_service();
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&svc)).unwrap();
    let addr = server.addr.to_string();

    let mut client = Client::connect(&addr).unwrap();
    let pred = client.predict("wordcount", 20, 5).unwrap();
    let want = evaluate(&test_model("wordcount").coeffs, &[20.0, 5.0]);
    assert!((pred - want).abs() < 1e-9);

    let models = client.models().unwrap();
    assert_eq!(models, vec!["exim".to_string(), "wordcount".to_string()]);

    let (requests, batches, mean_batch) = client.health().unwrap();
    assert!(requests >= 1);
    assert!(batches >= 1);
    assert!(mean_batch >= 1.0);

    // Unknown app comes back as a *typed* protocol-level error, not a
    // hang (and not a transport or parse failure).
    let err = client.predict("nope", 1, 1).unwrap_err();
    match &err {
        mrtuner::coordinator::client::ClientError::Server(msg) => {
            assert!(msg.contains("no model"), "{msg}")
        }
        other => panic!("expected Server error, got {other:?}"),
    }

    server.shutdown();
}

#[test]
fn tcp_multiple_clients_parallel() {
    let svc = start_service();
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&svc)).unwrap();
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for i in 0..25u32 {
                let m = 5 + ((t * 25 + i) % 36);
                let got = c.predict("exim", m, 10).unwrap();
                let want =
                    evaluate(&test_model("exim").coeffs, &[m as f64, 10.0]);
                assert!((got - want).abs() < 1e-9);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 100);
    server.shutdown();
}

#[test]
fn malformed_requests_get_errors_not_disconnects() {
    use std::io::{BufRead, BufReader, Write};
    let svc = start_service();
    let mut server = Server::start("127.0.0.1:0", svc).unwrap();
    let stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    for (req, needle) in [
        ("garbage", "bad json"),
        (r#"{"op":"teleport"}"#, "unknown op"),
        (r#"{"no_op":1}"#, "missing 'op'"),
        (r#"{"op":"predict","app":"wordcount"}"#, "mappers"),
    ] {
        writer.write_all(format!("{req}\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "{req} -> {line}");
        assert!(line.contains(needle), "{req} -> {line}");
    }
    // The connection still works afterwards.
    writer
        .write_all(
            b"{\"op\":\"predict\",\"app\":\"wordcount\",\"mappers\":10,\"reducers\":10}\n",
        )
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    server.shutdown();
}

#[test]
fn hot_model_swap_visible_to_inflight_clients() {
    let svc = start_service();
    let before = svc.predict_versioned("wordcount", 20, 5).unwrap();
    assert_eq!(before.version, 1);
    let mut replacement = test_model("wordcount");
    replacement.coeffs[0] += 100.0;
    let v = svc.publish_model(replacement, 0.5);
    assert_eq!(v, 2);
    let after = svc.predict_versioned("wordcount", 20, 5).unwrap();
    assert_eq!(after.version, 2);
    assert!((after.seconds - before.seconds - 100.0).abs() < 1e-9);
}

/// The hot-swap concurrency contract: N threads hammer `predict` while
/// the main thread publishes a stream of refits.  No request may error,
/// every answer must be self-consistent with *some* published version,
/// and the versions each thread observes must be monotonic.
#[test]
fn hot_swap_under_concurrent_predict_load() {
    let svc = start_service();
    let swaps = 30u64;
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = Vec::new();
    for t in 0..6u32 {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            let mut last_version = 0u64;
            let mut served = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let m = 5 + ((served as u32 + t) % 36);
                let p = svc
                    .predict_versioned("wordcount", m, 5)
                    .expect("predict must never fail during a hot swap");
                assert!(
                    p.version >= last_version,
                    "served versions must be monotonic: {} then {}",
                    last_version,
                    p.version
                );
                // Version k serves coefficients with intercept shifted by
                // (k - 1) * 10: the answer must match its own version,
                // whichever side of a swap the batch landed on.
                let mut coeffs = test_model("wordcount").coeffs;
                coeffs[0] += (p.version - 1) as f64 * 10.0;
                let want = evaluate(&coeffs, &[m as f64, 5.0]);
                assert!(
                    (p.seconds - want).abs() < 1e-9,
                    "answer inconsistent with its version {}",
                    p.version
                );
                last_version = p.version;
                served += 1;
            }
            (served, last_version)
        }));
    }
    // Publish refits mid-flight, each shifting the intercept by +10.
    for k in 2..=swaps {
        let mut refit = test_model("wordcount");
        refit.coeffs[0] += (k - 1) as f64 * 10.0;
        let v = svc.publish_model(refit, 0.1);
        assert_eq!(v, k, "publisher is the only writer");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let mut total = 0;
    for h in handles {
        let (served, last) = h.join().unwrap();
        assert!(served > 0);
        assert!(last <= swaps);
        total += served;
    }
    assert_eq!(
        svc.metrics.backend_errors.load(Ordering::Relaxed),
        0,
        "no request errored across {total} predictions and {swaps} swaps"
    );
    assert_eq!(svc.metrics.rejected.load(Ordering::Relaxed), 0);
    // At least one worker must have observed a post-swap version.
    let final_info = svc.model_info("wordcount").unwrap();
    assert_eq!(final_info.version, swaps);
}

/// The hot-swap contract, end to end over the binary protocol: clients
/// keep a pipelined window in flight across the server's batch queue
/// while refits publish concurrently.  Every reply must succeed, carry
/// a strictly non-decreasing version in submission order, and be
/// self-consistent with the version it names — the batch path's atomic
/// `(coeffs, version)` read, observed through TCP.
#[test]
fn hot_swap_under_pipelined_binary_load() {
    let svc = start_service();
    let mut server = Server::start_tuned(
        "127.0.0.1:0",
        Arc::clone(&svc),
        None,
        ServeOptions::default(),
    )
    .unwrap();
    let addr = server.addr.to_string();
    let swaps = 12u64;
    let mut handles = Vec::new();
    for t in 0..3u32 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = PipelinedClient::connect(&addr).unwrap();
            let reqs: Vec<(String, u32, u32)> = (0..800u32)
                .map(|i| ("wordcount".to_string(), 5 + ((i + t) % 36), 5))
                .collect();
            let replies = c.predict_many(&reqs, 64).unwrap();
            let mut last = 0u64;
            for ((_, m, _), r) in reqs.iter().zip(&replies) {
                let p = r
                    .as_ref()
                    .expect("predict must never fail during a hot swap");
                assert!(
                    p.version >= last,
                    "versions must be monotonic: {last} then {}",
                    p.version
                );
                // Version k serves the intercept shifted by (k - 1) * 10.
                let mut coeffs = test_model("wordcount").coeffs;
                coeffs[0] += (p.version - 1) as f64 * 10.0;
                let want = evaluate(&coeffs, &[*m as f64, 5.0]);
                assert!(
                    (p.seconds - want).abs() < 1e-9,
                    "answer inconsistent with its version {}",
                    p.version
                );
                last = p.version;
            }
            last
        }));
    }
    // Publish refits while the pipelined windows are in flight.
    for k in 2..=swaps {
        let mut refit = test_model("wordcount");
        refit.coeffs[0] += (k - 1) as f64 * 10.0;
        assert_eq!(svc.publish_model(refit, 0.1), k);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    for h in handles {
        let last = h.join().unwrap();
        assert!((1..=swaps).contains(&last), "impossible version {last}");
    }
    server.shutdown();
}
