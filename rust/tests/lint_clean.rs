//! Meta-test: the shipped tree must satisfy its own static-analysis
//! pass.  `cargo test --test lint_clean` is therefore equivalent to
//! `mrtuner lint` succeeding, which keeps the invariant enforced even
//! for contributors who only run the test suite and never the CLI.

use std::path::Path;

use mrtuner::analysis;

#[test]
fn shipped_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = analysis::run_lint(&root)
        .expect("lint walk over rust/src must succeed");
    assert!(
        report.files_scanned > 30,
        "suspiciously few files scanned ({}): wrong root?",
        report.files_scanned
    );
    assert!(
        report.findings.is_empty(),
        "tree must be lint-clean; findings:\n{}",
        report
            .findings
            .iter()
            .map(analysis::Finding::render)
            .collect::<Vec<_>>()
            .join("\n")
    );
}
