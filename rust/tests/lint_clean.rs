//! Meta-test: the shipped tree must satisfy its own static-analysis
//! pass.  `cargo test --test lint_clean` is therefore equivalent to
//! `mrtuner lint` succeeding, which keeps the invariant enforced even
//! for contributors who only run the test suite and never the CLI.

use std::path::Path;

use mrtuner::analysis;

/// Source files added by the multi-target PR.  Each must (a) sit inside
/// the determinism scope — a `HashMap`/`Instant` planted at its path
/// must fire — and (b) ship with zero suppression directives, so the
/// multi-target plumbing earns its lint-cleanliness rather than
/// allowing its way past the rules.
const MULTI_TARGET_FILES: [&str; 5] = [
    "apps/sort.rs",
    "apps/join.rs",
    "datagen/sort_records.rs",
    "datagen/join_log.rs",
    "model/target.rs",
];

#[test]
fn multi_target_modules_are_in_scope_and_suppression_free() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let probe = "fn probe() { let m = HashMap::new(); let t = Instant::now(); }\n";
    for rel in MULTI_TARGET_FILES {
        // (a) The path is inside the determinism scope: the probe fires.
        let fired: Vec<String> = analysis::rules::lint_source(rel, probe)
            .into_iter()
            .map(|f| f.rule)
            .collect();
        assert_eq!(
            fired,
            ["determinism", "determinism"],
            "{rel} must be in the determinism scope"
        );
        // (b) The shipped file exists and carries no allow directives at
        // all — not even justified ones.  (clippy.toml's
        // disallowed-methods are crate-global, so they need no per-file
        // check.)
        let text = std::fs::read_to_string(root.join(rel))
            .unwrap_or_else(|e| panic!("read {rel}: {e}"));
        assert!(
            !text.contains("mrlint"),
            "{rel} must ship without lint suppressions"
        );
    }
}

#[test]
fn shipped_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = analysis::run_lint(&root)
        .expect("lint walk over rust/src must succeed");
    assert!(
        report.files_scanned > 30,
        "suspiciously few files scanned ({}): wrong root?",
        report.files_scanned
    );
    assert!(
        report.findings.is_empty(),
        "tree must be lint-clean; findings:\n{}",
        report
            .findings
            .iter()
            .map(analysis::Finding::render)
            .collect::<Vec<_>>()
            .join("\n")
    );
}
