//! Integration: the paper's full pipeline (profile → fit → predict) over
//! the simulated cluster, including the headline-claim reproduction and
//! the experiment drivers used by the benches.

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::model::regression::{RegressionModel, RustSolverBackend};
use mrtuner::profiler::{paper_campaign, run_experiment, Dataset, ExperimentSpec};
use mrtuner::report::experiments::{fig3, fig4, table1};

#[test]
fn paper_headline_under_5_percent_for_both_apps() {
    for row in table1(42) {
        assert!(
            row.mean_pct < 5.0,
            "{}: mean error {:.2}% breaks the paper's headline",
            row.app.name(),
            row.mean_pct
        );
        assert!(row.variance_pct.is_finite() && row.variance_pct >= 0.0);
    }
}

#[test]
fn error_ordering_matches_paper() {
    // §V.B: streaming makes Exim's prediction error larger than
    // WordCount's.  Use the multi-seed mean to avoid single-session flukes.
    let mut wc = 0.0;
    let mut ex = 0.0;
    for seed in [42, 7, 2012] {
        let rows = table1(seed);
        wc += rows[0].mean_pct;
        ex += rows[1].mean_pct;
    }
    assert!(
        ex > wc,
        "exim mean error {ex:.3} must exceed wordcount {wc:.3} (3-seed sums)"
    );
}

#[test]
fn fig3_protocol_shapes() {
    let d = fig3(AppId::WordCount, 11);
    assert_eq!(d.errors.len(), 20, "20 held-out settings");
    assert_eq!(d.train.len(), 20, "20 training settings");
    assert_eq!(d.model.trained_on, 20);
    // Predictions and actuals must be on the same scale.
    for (a, p) in d.errors.actual.iter().zip(&d.errors.predicted) {
        assert!(*a > 60.0 && *a < 3600.0, "actual {a}");
        assert!((p - a).abs() / a < 0.5, "gross misprediction {p} vs {a}");
    }
}

#[test]
fn fig4_shape_claims() {
    let wc = fig4(AppId::WordCount, 7, 3, 5);
    let ex = fig4(AppId::EximParse, 7, 3, 5);
    // WordCount runs substantially slower (paper: ~2x).
    let ratio = wc.mean_time() / ex.mean_time();
    assert!(ratio > 1.3, "wordcount/exim ratio {ratio:.2}");
    // Surfaces are positive and bounded.
    for t in wc.times.iter().chain(&ex.times) {
        assert!(*t > 60.0 && *t < 7200.0);
    }
    // Configuration choice matters: the spread over the grid is real.
    assert!(wc.fluctuation() > 0.02);
}

#[test]
fn model_transfers_within_app_but_not_across() {
    // §I: a model fitted for one application must not be used for another.
    let cluster = Cluster::paper_cluster();
    let (wc_train, _) = paper_campaign(AppId::WordCount, 3);
    let (_, wc_ds) = wc_train.run(&cluster);
    let model =
        RegressionModel::fit_dataset(&mut RustSolverBackend, &wc_ds).unwrap();

    // Same app, fresh runs: good.
    let same = run_experiment(
        &cluster,
        &ExperimentSpec::new(AppId::WordCount, 22, 9),
        5,
        888,
    );
    let pred = model.predict_one(22, 9);
    let err_same = (pred - same.mean_time_s).abs() / same.mean_time_s;
    assert!(err_same < 0.10, "within-app error {err_same:.3}");

    // Different app, same platform: prediction should be way off.
    let other = run_experiment(
        &cluster,
        &ExperimentSpec::new(AppId::EximParse, 22, 9),
        5,
        888,
    );
    let err_cross = (pred - other.mean_time_s).abs() / other.mean_time_s;
    assert!(
        err_cross > 0.25,
        "cross-app prediction unexpectedly good: {err_cross:.3}"
    );
}

#[test]
fn model_does_not_transfer_across_platforms() {
    // §I: the model of an application on one platform may not predict the
    // same application on another platform.
    let paper = Cluster::paper_cluster();
    let (train, _) = paper_campaign(AppId::WordCount, 4);
    let (_, ds) = train.run(&paper);
    let model = RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).unwrap();

    // A beefier platform: twice the nodes.
    let mut specs = Vec::new();
    for n in paper.nodes.iter().cycle().take(8) {
        specs.push(n.spec.clone());
    }
    let big = Cluster::new(
        specs,
        mrtuner::cluster::Network::switched_ethernet_1gbps(8),
    );
    let spec = ExperimentSpec::new(AppId::WordCount, 20, 5);
    let actual = run_experiment(&big, &spec, 5, 99).mean_time_s;
    let pred = model.predict_one(20, 5);
    let err = (pred - actual).abs() / actual;
    assert!(err > 0.2, "cross-platform prediction unexpectedly good: {err:.3}");
}

#[test]
fn dataset_round_trip_through_files_preserves_fit() {
    let cluster = Cluster::paper_cluster();
    let (train, _) = paper_campaign(AppId::EximParse, 8);
    let (_, ds) = train.run(&cluster);
    let path = std::env::temp_dir().join("mrtuner_e2e_dataset.json");
    ds.save(&path).unwrap();
    let loaded = Dataset::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let m1 = RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).unwrap();
    let m2 = RegressionModel::fit_dataset(&mut RustSolverBackend, &loaded).unwrap();
    for i in 0..m1.coeffs.len() {
        assert!((m1.coeffs[i] - m2.coeffs[i]).abs() < 1e-9);
    }
}

#[test]
fn five_rep_averaging_reduces_variance() {
    // The paper's justification for averaging: the mean of five runs is a
    // steadier target than a single run.
    let cluster = Cluster::paper_cluster();
    let spec = ExperimentSpec::new(AppId::EximParse, 20, 5);
    let singles: Vec<f64> = (0..20)
        .map(|i| run_experiment(&cluster, &spec, 1, 3000 + i).mean_time_s)
        .collect();
    let averaged: Vec<f64> = (0..20)
        .map(|i| run_experiment(&cluster, &spec, 5, 7000 + i).mean_time_s)
        .collect();
    let var = |xs: &[f64]| {
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
    };
    assert!(
        var(&averaged) < var(&singles),
        "averaging must shrink variance: {} vs {}",
        var(&averaged),
        var(&singles)
    );
}
