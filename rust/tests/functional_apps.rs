//! Integration: the benchmark applications executed *functionally* over
//! generated data, verified against independently computed ground truth —
//! the proof that the framework's MapReduce semantics are real.

use std::collections::{HashMap, HashSet};

use mrtuner::api::engine::{execute, ExecOptions};
use mrtuner::api::traits::HashPartitioner;
use mrtuner::apps::{exim, AppId};
use mrtuner::datagen;
use mrtuner::util::prop::forall;
use mrtuner::util::rng::Rng;

fn opts(app: AppId, r: u32, splits: u32) -> (AppId, u32, u32) {
    (app, r, splits)
}

fn run_app(
    app: AppId,
    input: &str,
    r: u32,
    splits: u32,
) -> mrtuner::api::engine::JobOutput {
    let (mapper, reducer, combiner) = app.functional();
    let o = ExecOptions {
        num_reducers: r,
        combiner: combiner.as_deref(),
        partitioner: &HashPartitioner,
        num_splits: splits,
    };
    execute(mapper.as_ref(), reducer.as_ref(), input, &o)
}

#[test]
fn wordcount_matches_hashmap_ground_truth() {
    let mut rng = Rng::new(1);
    let corpus = datagen::corpus::generate(&mut rng, 300_000);
    let out = run_app(AppId::WordCount, &corpus, 7, 9);

    let mut truth: HashMap<&str, u64> = HashMap::new();
    for w in corpus.split_whitespace() {
        *truth.entry(w).or_insert(0) += 1;
    }
    let pairs = out.all_pairs();
    assert_eq!(pairs.len(), truth.len(), "vocabulary size");
    for p in &pairs {
        assert_eq!(
            p.value.parse::<u64>().unwrap(),
            truth[p.key.as_str()],
            "count for {}",
            p.key
        );
    }
}

#[test]
fn exim_matches_transaction_ground_truth() {
    let mut rng = Rng::new(2);
    let log = datagen::exim_log::generate(&mut rng, 300_000);
    let out = run_app(AppId::EximParse, &log, 5, 7);

    let mut truth: HashMap<String, Vec<&str>> = HashMap::new();
    for line in log.lines() {
        if let Some(id) = exim::message_id(line) {
            truth.entry(id.to_string()).or_default().push(line);
        }
    }
    let pairs = out.all_pairs();
    assert_eq!(pairs.len(), truth.len(), "transaction count");
    for p in &pairs {
        let mut expect = truth[&p.key].clone();
        expect.sort();
        assert_eq!(p.value, expect.join("|"), "transaction {}", p.key);
    }
}

#[test]
fn grep_matches_line_scan() {
    let mut rng = Rng::new(3);
    // Mix corpus lines with injected "error" lines.
    let mut text = datagen::corpus::generate(&mut rng, 50_000);
    text.push_str("an error\nerror error here\nclean line\n");
    let out = run_app(AppId::Grep, &text, 3, 4);
    let truth: usize = text.lines().map(|l| l.matches("error").count()).sum();
    let pairs = out.all_pairs();
    if truth == 0 {
        assert!(pairs.is_empty());
    } else {
        assert_eq!(pairs.len(), 1);
        assert_eq!(pairs[0].value.parse::<usize>().unwrap(), truth);
    }
}

#[test]
fn prop_results_invariant_to_parallelism_knobs() {
    // The defining MapReduce property: output is independent of the
    // number of reducers and splits (the paper's tunables change *time*,
    // never *answers*).
    forall("parallelism invariance", 6, |rng| {
        let corpus = datagen::corpus::generate(rng, 20_000);
        let base = run_app(AppId::WordCount, &corpus, 1, 1).all_pairs();
        let r = rng.range_u64(2, 40) as u32;
        let s = rng.range_u64(2, 16) as u32;
        let got = run_app(AppId::WordCount, &corpus, r, s).all_pairs();
        assert_eq!(got, base, "r={r} s={s}");
    });
}

#[test]
fn prop_exim_invariant_to_parallelism_knobs() {
    forall("exim parallelism invariance", 4, |rng| {
        let log = datagen::exim_log::generate(rng, 30_000);
        let base = run_app(AppId::EximParse, &log, 1, 1).all_pairs();
        let r = rng.range_u64(2, 40) as u32;
        let s = rng.range_u64(2, 16) as u32;
        let got = run_app(AppId::EximParse, &log, r, s).all_pairs();
        assert_eq!(got, base, "r={r} s={s}");
    });
}

#[test]
fn partitions_are_disjoint_and_complete() {
    let mut rng = Rng::new(4);
    let corpus = datagen::corpus::generate(&mut rng, 40_000);
    let out = run_app(AppId::WordCount, &corpus, 11, 5);
    let mut seen: HashSet<String> = HashSet::new();
    for part in &out.partitions {
        for p in part {
            assert!(seen.insert(p.key.clone()), "key {} in two partitions", p.key);
        }
    }
    let mut truth: HashSet<&str> = HashSet::new();
    for w in corpus.split_whitespace() {
        truth.insert(w);
    }
    assert_eq!(seen.len(), truth.len());
}

#[test]
fn counters_are_consistent() {
    let mut rng = Rng::new(5);
    let corpus = datagen::corpus::generate(&mut rng, 60_000);
    let out = run_app(AppId::WordCount, &corpus, 4, 6);
    assert_eq!(out.input_bytes as usize, corpus.len());
    assert_eq!(out.input_records as usize, corpus.lines().count());
    // Combiner can only shrink the shuffle.
    assert!(out.shuffle_records <= out.map_output_records);
    assert!(out.shuffle_bytes <= out.map_output_bytes);
    // Reduce output = distinct keys.
    assert_eq!(
        out.output_records,
        out.all_pairs().len() as u64
    );
    let _ = opts(AppId::WordCount, 1, 1);
}
