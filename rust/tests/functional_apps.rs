//! Integration: the benchmark applications executed *functionally* over
//! generated data, verified against independently computed ground truth —
//! the proof that the framework's MapReduce semantics are real.

use std::collections::{HashMap, HashSet};

use mrtuner::api::engine::{execute, ExecOptions};
use mrtuner::api::traits::HashPartitioner;
use mrtuner::api::Pair;
use mrtuner::apps::{exim, AppId};
use mrtuner::cluster::Cluster;
use mrtuner::datagen;
use mrtuner::profiler::{CampaignExecutor, ExperimentSpec, Ext4Spec};
use mrtuner::util::prop::forall;
use mrtuner::util::rng::Rng;

fn opts(app: AppId, r: u32, splits: u32) -> (AppId, u32, u32) {
    (app, r, splits)
}

fn run_app(
    app: AppId,
    input: &str,
    r: u32,
    splits: u32,
) -> mrtuner::api::engine::JobOutput {
    let (mapper, reducer, combiner) = app.functional();
    let o = ExecOptions {
        num_reducers: r,
        combiner: combiner.as_deref(),
        partitioner: &HashPartitioner,
        num_splits: splits,
    };
    execute(mapper.as_ref(), reducer.as_ref(), input, &o)
}

#[test]
fn wordcount_matches_hashmap_ground_truth() {
    let mut rng = Rng::new(1);
    let corpus = datagen::corpus::generate(&mut rng, 300_000);
    let out = run_app(AppId::WordCount, &corpus, 7, 9);

    let mut truth: HashMap<&str, u64> = HashMap::new();
    for w in corpus.split_whitespace() {
        *truth.entry(w).or_insert(0) += 1;
    }
    let pairs = out.all_pairs();
    assert_eq!(pairs.len(), truth.len(), "vocabulary size");
    for p in &pairs {
        assert_eq!(
            p.value.parse::<u64>().unwrap(),
            truth[p.key.as_str()],
            "count for {}",
            p.key
        );
    }
}

#[test]
fn exim_matches_transaction_ground_truth() {
    let mut rng = Rng::new(2);
    let log = datagen::exim_log::generate(&mut rng, 300_000);
    let out = run_app(AppId::EximParse, &log, 5, 7);

    let mut truth: HashMap<String, Vec<&str>> = HashMap::new();
    for line in log.lines() {
        if let Some(id) = exim::message_id(line) {
            truth.entry(id.to_string()).or_default().push(line);
        }
    }
    let pairs = out.all_pairs();
    assert_eq!(pairs.len(), truth.len(), "transaction count");
    for p in &pairs {
        let mut expect = truth[&p.key].clone();
        expect.sort();
        assert_eq!(p.value, expect.join("|"), "transaction {}", p.key);
    }
}

#[test]
fn grep_matches_line_scan() {
    let mut rng = Rng::new(3);
    // Mix corpus lines with injected "error" lines.
    let mut text = datagen::corpus::generate(&mut rng, 50_000);
    text.push_str("an error\nerror error here\nclean line\n");
    let out = run_app(AppId::Grep, &text, 3, 4);
    let truth: usize = text.lines().map(|l| l.matches("error").count()).sum();
    let pairs = out.all_pairs();
    if truth == 0 {
        assert!(pairs.is_empty());
    } else {
        assert_eq!(pairs.len(), 1);
        assert_eq!(pairs[0].value.parse::<usize>().unwrap(), truth);
    }
}

#[test]
fn prop_results_invariant_to_parallelism_knobs() {
    // The defining MapReduce property: output is independent of the
    // number of reducers and splits (the paper's tunables change *time*,
    // never *answers*).
    forall("parallelism invariance", 6, |rng| {
        let corpus = datagen::corpus::generate(rng, 20_000);
        let base = run_app(AppId::WordCount, &corpus, 1, 1).all_pairs();
        let r = rng.range_u64(2, 40) as u32;
        let s = rng.range_u64(2, 16) as u32;
        let got = run_app(AppId::WordCount, &corpus, r, s).all_pairs();
        assert_eq!(got, base, "r={r} s={s}");
    });
}

#[test]
fn prop_exim_invariant_to_parallelism_knobs() {
    forall("exim parallelism invariance", 4, |rng| {
        let log = datagen::exim_log::generate(rng, 30_000);
        let base = run_app(AppId::EximParse, &log, 1, 1).all_pairs();
        let r = rng.range_u64(2, 40) as u32;
        let s = rng.range_u64(2, 16) as u32;
        let got = run_app(AppId::EximParse, &log, r, s).all_pairs();
        assert_eq!(got, base, "r={r} s={s}");
    });
}

#[test]
fn partitions_are_disjoint_and_complete() {
    let mut rng = Rng::new(4);
    let corpus = datagen::corpus::generate(&mut rng, 40_000);
    let out = run_app(AppId::WordCount, &corpus, 11, 5);
    let mut seen: HashSet<String> = HashSet::new();
    for part in &out.partitions {
        for p in part {
            assert!(seen.insert(p.key.clone()), "key {} in two partitions", p.key);
        }
    }
    let mut truth: HashSet<&str> = HashSet::new();
    for w in corpus.split_whitespace() {
        truth.insert(w);
    }
    assert_eq!(seen.len(), truth.len());
}

#[test]
fn sort_matches_multiset_ground_truth_in_key_order() {
    let mut rng = Rng::new(6);
    let data = datagen::sort_records::generate(&mut rng, 30_000);
    let out = run_app(AppId::Sort, &data, 5, 7);

    // Ground truth: every input record survives, and the merged output
    // is exactly the input multiset in (key, payload) order — payloads
    // carry unique sequence numbers, so the comparison is exact.
    let mut truth: Vec<Pair> = data
        .lines()
        .map(|l| {
            let (k, p) = l.split_once('\t').expect("tab-separated");
            Pair::new(k, p)
        })
        .collect();
    truth.sort();
    assert_eq!(out.all_pairs(), truth);
    assert_eq!(out.output_records, out.input_records, "a sort loses nothing");
    // The shuffle-bound signature the simulator profile encodes:
    // essentially every input byte crosses the network.
    assert!(out.selectivity() > 0.9, "selectivity {}", out.selectivity());
}

#[test]
fn join_matches_hash_join_ground_truth() {
    let mut rng = Rng::new(7);
    let data = datagen::join_log::generate(&mut rng, 30_000);
    let out = run_app(AppId::Join, &data, 4, 6);

    // Independent hash join over the same tagged lines.
    let mut left: HashMap<&str, Vec<&str>> = HashMap::new();
    let mut right: HashMap<&str, Vec<&str>> = HashMap::new();
    for line in data.lines() {
        let mut cols = line.split('\t');
        let (tag, key, payload) = (
            cols.next().unwrap(),
            cols.next().unwrap(),
            cols.next().unwrap(),
        );
        match tag {
            "L" => left.entry(key).or_default().push(payload),
            "R" => right.entry(key).or_default().push(payload),
            other => panic!("generator emitted tag {other:?}"),
        }
    }
    let mut truth: Vec<Pair> = Vec::new();
    for (key, ls) in &left {
        if let Some(rs) = right.get(key) {
            for l in ls {
                for r in rs {
                    truth.push(Pair::new(*key, format!("{l},{r}")));
                }
            }
        }
    }
    truth.sort();
    assert!(!truth.is_empty(), "skewed keys must actually join");
    assert_eq!(out.all_pairs(), truth);
}

#[test]
fn prop_sort_join_invariant_to_parallelism_knobs() {
    forall("sort/join parallelism invariance", 4, |rng| {
        let sorted = datagen::sort_records::generate(rng, 12_000);
        let joined = datagen::join_log::generate(rng, 12_000);
        let r = rng.range_u64(2, 40) as u32;
        let s = rng.range_u64(2, 16) as u32;
        for (app, input) in
            [(AppId::Sort, &sorted), (AppId::Join, &joined)]
        {
            let base = run_app(app, input, 1, 1).all_pairs();
            let got = run_app(app, input, r, s).all_pairs();
            assert_eq!(got, base, "{app:?} r={r} s={s}");
        }
    });
}

#[test]
fn sort_join_deterministic_across_sessions() {
    // Two fully independent "sessions" — fresh RNG, fresh data, fresh
    // engine — must agree on every output pair *and* every counter the
    // byte-level model trains on.
    for app in [AppId::Sort, AppId::Join] {
        let session = || {
            let mut rng = Rng::new(77);
            let data = match app {
                AppId::Sort => {
                    datagen::sort_records::generate(&mut rng, 25_000)
                }
                _ => datagen::join_log::generate(&mut rng, 25_000),
            };
            run_app(app, &data, 6, 5)
        };
        let (a, b) = (session(), session());
        assert_eq!(a.all_pairs(), b.all_pairs(), "{app:?}");
        assert_eq!(a.shuffle_bytes, b.shuffle_bytes, "{app:?}");
        assert_eq!(a.shuffle_records, b.shuffle_records, "{app:?}");
        assert_eq!(a.output_bytes, b.output_bytes, "{app:?}");
    }
}

#[test]
fn shuffle_bytes_monotone_in_input_size() {
    // The relationship the `shuffle_bytes` prediction target models:
    // more input, more bytes across the network — for the shuffle-bound
    // sort and the skew-prone join alike.
    for app in [AppId::Sort, AppId::Join] {
        let mut last = 0u64;
        for target in [8_000usize, 32_000, 128_000] {
            let mut rng = Rng::new(9);
            let data = match app {
                AppId::Sort => {
                    datagen::sort_records::generate(&mut rng, target)
                }
                _ => datagen::join_log::generate(&mut rng, target),
            };
            let out = run_app(app, &data, 4, 4);
            assert!(
                out.shuffle_bytes > last,
                "{app:?} at {target}: {} !> {last}",
                out.shuffle_bytes
            );
            last = out.shuffle_bytes;
        }
    }
}

#[test]
fn paper_plane_ext4_shares_the_two_parameter_cache() {
    // Simulator-level cache soundness for the new apps: an extended
    // 4-parameter setting on the paper plane *is* the 2-parameter
    // setting — same StoreKey, same seeds — so one executor answers it
    // from the reps the 2-parameter campaign already simulated, bit for
    // bit and with zero new simulations.
    let cluster = Cluster::paper_cluster();
    let exec = CampaignExecutor::serial();
    let specs = [
        ExperimentSpec::new(AppId::Sort, 12, 6),
        ExperimentSpec::new(AppId::Join, 9, 7),
    ];
    let paper = exec.run_specs(&cluster, &specs, 2, 5);
    let simulated = exec.stats().simulated;
    assert_eq!(simulated, 4, "2 specs x 2 reps, cold");

    let ext: Vec<Ext4Spec> = specs
        .iter()
        .map(|s| Ext4Spec {
            app: s.app,
            num_mappers: s.num_mappers,
            num_reducers: s.num_reducers,
            input_gb: 8.0,
            block_mb: 64,
        })
        .collect();
    assert!(ext.iter().all(Ext4Spec::is_paper_plane));
    let shared = exec.run_ext4_specs(&cluster, &ext, 2, 5);
    assert_eq!(
        exec.stats().simulated,
        simulated,
        "paper-plane reps come from the shared cache"
    );
    for (p, e) in paper.iter().zip(&shared) {
        assert_eq!(
            p.mean_time_s.to_bits(),
            e.mean_time_s.to_bits(),
            "{:?}",
            p.spec
        );
    }

    // Off the plane the key differs, so the cache must *not* answer.
    let mut off = ext.clone();
    off[0].input_gb = 4.0;
    exec.run_ext4_specs(&cluster, &off[..1], 2, 5);
    assert!(
        exec.stats().simulated > simulated,
        "off-plane settings are distinct simulations"
    );
}

#[test]
fn counters_are_consistent() {
    let mut rng = Rng::new(5);
    let corpus = datagen::corpus::generate(&mut rng, 60_000);
    let out = run_app(AppId::WordCount, &corpus, 4, 6);
    assert_eq!(out.input_bytes as usize, corpus.len());
    assert_eq!(out.input_records as usize, corpus.lines().count());
    // Combiner can only shrink the shuffle.
    assert!(out.shuffle_records <= out.map_output_records);
    assert!(out.shuffle_bytes <= out.map_output_bytes);
    // Reduce output = distinct keys.
    assert_eq!(
        out.output_records,
        out.all_pairs().len() as u64
    );
    let _ = opts(AppId::WordCount, 1, 1);
}
