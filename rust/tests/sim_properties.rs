//! Property tests and failure injection across the simulator, cost
//! model, scheduler and service — the invariants DESIGN.md commits to.

use mrtuner::apps::AppId;
use mrtuner::cluster::{Cluster, Network, NodeSpec};
use mrtuner::coordinator::{
    evaluate_order, fifo_order, sjf_order, JobRequest, ModelRegistry,
    PredictionService, ServiceConfig,
};
use mrtuner::model::features::NUM_FEATURES;
use mrtuner::model::regression::{FitBackend, RegressionModel};
use mrtuner::mr::config::SplitPolicy;
use mrtuner::mr::cost;
use mrtuner::mr::{run_job, JobConfig};
use mrtuner::util::bytes::{GB, MB};
use mrtuner::util::prop::forall;

fn wc() -> mrtuner::mr::cost::AppProfile {
    AppId::WordCount.profile()
}

// ----------------------------------------------------------- cost model

#[test]
fn prop_map_cost_monotone_in_bytes() {
    let c = Cluster::paper_cluster();
    forall("map cost monotone", 30, |rng| {
        let a = rng.range_u64(1 * MB, 2 * GB);
        let b = a + rng.range_u64(1, GB);
        let node = &c.nodes[rng.range_usize(0, 4)].spec;
        let local = rng.bool(0.5);
        let ca = cost::map_cost(&wc(), node, &c.network, a, local);
        let cb = cost::map_cost(&wc(), node, &c.network, b, local);
        assert!(cb.total_s() >= ca.total_s(), "bytes {a} vs {b}");
    });
}

#[test]
fn prop_reduce_cost_monotone_in_volume() {
    let c = Cluster::paper_cluster();
    forall("reduce cost monotone", 30, |rng| {
        let a = rng.range_u64(1 * MB, GB);
        let b = a + rng.range_u64(1, GB);
        let node = &c.nodes[rng.range_usize(0, 4)].spec;
        let maps = rng.range_u64(1, 200) as u32;
        let ca = cost::reduce_cost(&wc(), node, &c.network, a, maps, 10, 3);
        let cb = cost::reduce_cost(&wc(), node, &c.network, b, maps, 10, 3);
        assert!(cb.total_s() >= ca.total_s());
    });
}

#[test]
fn prop_faster_cpu_never_slower() {
    let c = Cluster::paper_cluster();
    forall("cpu speed helps", 20, |rng| {
        let bytes = rng.range_u64(16 * MB, GB);
        let mut fast = c.nodes[0].spec.clone();
        let mut slow = fast.clone();
        fast.cpu_ghz = 3.4;
        slow.cpu_ghz = 1.7;
        let cf = cost::map_cost(&wc(), &fast, &c.network, bytes, true);
        let cs = cost::map_cost(&wc(), &slow, &c.network, bytes, true);
        assert!(cf.total_s() <= cs.total_s());
    });
}

// ------------------------------------------------------------ simulator

#[test]
fn prop_more_input_takes_longer() {
    let cluster = Cluster::paper_cluster();
    let mut app = wc();
    app.noise_sigma = 0.0;
    app.job_sigma = 0.0;
    forall("input monotone", 10, |rng| {
        let mut cfg = JobConfig::paper_default(20, 5).with_seed(1);
        cfg.input_bytes = rng.range_u64(GB, 4 * GB);
        let t_small = run_job(&cluster, &app, &cfg).total_time_s;
        let mut big = cfg.clone();
        big.input_bytes = cfg.input_bytes * 2;
        let t_big = run_job(&cluster, &app, &big).total_time_s;
        assert!(t_big > t_small, "{t_big} vs {t_small}");
    });
}

#[test]
fn prop_total_time_bounded_by_serial_execution() {
    let cluster = Cluster::paper_cluster();
    forall("parallel beats serial", 10, |rng| {
        let m = rng.range_u64(5, 41) as u32;
        let r = rng.range_u64(5, 41) as u32;
        let cfg = JobConfig::paper_default(m, r)
            .with_seed(rng.next_u64())
            .with_split_policy(SplitPolicy::Direct);
        let res = run_job(&cluster, &wc(), &cfg);
        // Serial bound: every committed task on the slowest node, one at
        // a time (generous x2 for noise).
        let serial: f64 = res
            .maps
            .iter()
            .chain(&res.reduces)
            .map(|t| t.duration_s())
            .sum();
        assert!(
            res.total_time_s < 2.0 * serial + 60.0,
            "m={m} r={r}: {} vs serial {serial}",
            res.total_time_s
        );
    });
}

#[test]
fn replication_one_reduces_locality() {
    let cluster = Cluster::paper_cluster();
    // Default (HadoopHint) policy: 64 MB single-block splits, where each
    // split has exactly `replication` candidate homes.
    let mut lo = 0.0;
    let mut hi = 0.0;
    for seed in 0..5 {
        let mut cfg = JobConfig::paper_default(40, 5).with_seed(seed);
        cfg.replication = 1;
        lo += run_job(&cluster, &wc(), &cfg).locality_fraction();
        cfg.replication = 3;
        hi += run_job(&cluster, &wc(), &cfg).locality_fraction();
    }
    assert!(
        hi > lo,
        "replication 3 locality {hi} must beat replication 1 {lo}"
    );
}

#[test]
fn degenerate_configs_rejected() {
    let cluster = Cluster::paper_cluster();
    let mut cfg = JobConfig::paper_default(20, 5);
    cfg.input_bytes = 0;
    assert!(cfg.validate().is_err());
    let result = std::panic::catch_unwind(|| {
        run_job(&cluster, &wc(), &cfg);
    });
    assert!(result.is_err(), "zero-byte job must be rejected");
}

#[test]
fn single_node_cluster_works() {
    let spec = NodeSpec {
        name: "solo".into(),
        cpu_ghz: 2.0,
        ram_bytes: GB,
        disk_bytes: 100 * GB,
        cache_kb: 512,
        disk_read_mbps: 70.0,
        disk_write_mbps: 55.0,
        map_slots: 2,
        reduce_slots: 1,
    };
    let cluster = Cluster::new(vec![spec], Network::switched_ethernet_1gbps(1));
    let cfg = JobConfig::paper_default(10, 3).with_seed(1);
    let res = run_job(&cluster, &wc(), &cfg);
    assert!(res.total_time_s.is_finite() && res.total_time_s > 0.0);
    // Everything is local on one node.
    assert!((res.locality_fraction() - 1.0).abs() < 1e-9);
}

#[test]
fn speculative_execution_wins_sometimes() {
    // With heavy-tailed task noise, backups must occasionally beat the
    // original attempt.
    let cluster = Cluster::paper_cluster();
    let mut app = wc();
    app.noise_sigma = 0.5;
    let mut wins = 0;
    for seed in 0..30 {
        let cfg = JobConfig::paper_default(20, 5)
            .with_seed(seed)
            .with_split_policy(SplitPolicy::Direct);
        wins += run_job(&cluster, &app, &cfg).counters.speculative_wins;
    }
    assert!(wins > 0, "no speculative win in 30 noisy runs");
}

#[test]
fn slowstart_extremes() {
    let cluster = Cluster::paper_cluster();
    for slowstart in [0.0, 1.0] {
        let mut cfg = JobConfig::paper_default(20, 5).with_seed(2);
        cfg.slowstart = slowstart;
        let res = run_job(&cluster, &wc(), &cfg);
        assert!(res.total_time_s > 0.0);
        assert!(res.first_reduce_s <= res.map_phase_s + 1e-9);
    }
}

// ------------------------------------------------------------- scheduler

#[test]
fn prop_sjf_is_permutation_and_no_worse_with_oracle() {
    let cluster = Cluster::paper_cluster();
    forall("sjf permutation + optimality", 5, |rng| {
        let apps = [AppId::WordCount, AppId::EximParse, AppId::Grep];
        let jobs: Vec<JobRequest> = (0..rng.range_u64(2, 8))
            .map(|i| JobRequest {
                app: *rng.choice(&apps),
                num_mappers: rng.range_u64(5, 41) as u32,
                num_reducers: rng.range_u64(5, 41) as u32,
                seed: i,
            })
            .collect();
        // Oracle predictions = true simulated durations.
        let order = sjf_order(&jobs, |j| {
            let cfg = JobConfig::paper_default(j.num_mappers, j.num_reducers)
                .with_seed(j.seed);
            Some(run_job(&cluster, &j.app.profile(), &cfg).total_time_s)
        });
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(sorted, (0..jobs.len()).collect::<Vec<_>>(), "permutation");

        let sjf = evaluate_order(&cluster, &jobs, &order);
        let fifo = evaluate_order(&cluster, &jobs, &fifo_order(&jobs));
        assert!(
            sjf.mean_completion_s <= fifo.mean_completion_s + 1e-6,
            "oracle SJF must not lose to FIFO"
        );
        assert!((sjf.makespan_s - fifo.makespan_s).abs() < 1e-6);
    });
}

// --------------------------------------------------------------- service

/// A backend that always fails — exercises error propagation.
struct BrokenBackend;
impl FitBackend for BrokenBackend {
    fn fit(
        &mut self,
        _: &[[f64; 2]],
        _: &[f64],
        _: &[f64],
    ) -> Result<[f64; NUM_FEATURES], String> {
        Err("broken".into())
    }
    fn predict(
        &mut self,
        _: &[f64; NUM_FEATURES],
        _: &[[f64; 2]],
    ) -> Result<Vec<f64>, String> {
        Err("backend exploded".into())
    }
    fn name(&self) -> &'static str {
        "broken"
    }
}

#[test]
fn service_surfaces_backend_failures() {
    let mut reg = ModelRegistry::new();
    reg.insert(RegressionModel {
        app_name: "wordcount".into(),
        coeffs: [1.0; NUM_FEATURES],
        trained_on: 20,
    });
    let svc = PredictionService::start(
        || Box::new(BrokenBackend) as Box<dyn FitBackend>,
        reg,
        ServiceConfig::default(),
    );
    let err = svc.predict("wordcount", 20, 5).unwrap_err();
    assert!(err.contains("exploded"), "{err}");
    assert!(
        svc.metrics
            .backend_errors
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 1
    );
    // The worker survives failed batches.
    let err2 = svc.predict("wordcount", 10, 10).unwrap_err();
    assert!(err2.contains("exploded"));
}
