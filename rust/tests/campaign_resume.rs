//! Crash/fault-injection harness for campaign checkpoint/resume.
//!
//! Drives the real `mrtuner` binary as child processes to pin down the
//! executor's failure-domain contracts end to end:
//!
//! * a campaign SIGKILLed mid-run resumes with **zero re-simulation**
//!   and a dataset bit-identical to an uninterrupted run (the store
//!   journal is the checkpoint);
//! * two `--cooperative` processes sharing one store split a campaign so
//!   their `simulated` counts *exactly* cover the grid, with
//!   bit-identical outputs;
//! * a repetition poisoned via `MRTUNER_FAIL_SPEC` lands in the
//!   dead-letter queue without aborting the campaign, is listed and
//!   retried by `mrtuner dlq`, and the final `--resume` pass dispatches
//!   nothing.

use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_mrtuner");

/// Unique per-test scratch directory (removed up front so reruns are
/// deterministic even after a crashed run).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_resume_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A `mrtuner` invocation hermetic to this test: machine-wide store and
/// fault-injection variables never leak in.
fn mrtuner(args: &[&str]) -> Command {
    let mut cmd = Command::new(BIN);
    cmd.args(args)
        .env_remove("MRTUNER_STORE")
        .env_remove("MRTUNER_STORE_MAX_MB")
        .env_remove("MRTUNER_FAIL_SPEC");
    cmd
}

/// Run to completion, asserting success; returns (stdout, stderr).
fn run_ok(args: &[&str]) -> (Vec<u8>, String) {
    let out = mrtuner(args).output().expect("spawn mrtuner");
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(
        out.status.success(),
        "mrtuner {args:?} failed:\n{stderr}\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
    (out.stdout, stderr)
}

/// The first integer right after `key` in `text` (e.g. `simulated=`).
fn stat(text: &str, key: &str) -> u64 {
    let i = text
        .find(key)
        .unwrap_or_else(|| panic!("no '{key}' in:\n{text}"));
    let digits: String = text[i + key.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().unwrap_or_else(|_| panic!("no integer after '{key}'"))
}

/// Parse the `resume: D/T reps already complete on disk, Q quarantined;
/// dispatching M` stderr line into (done, total, missing).
fn resume_line(stderr: &str) -> (u64, u64, u64) {
    let line = stderr
        .lines()
        .find(|l| l.starts_with("resume: "))
        .unwrap_or_else(|| panic!("no resume line in:\n{stderr}"));
    let done = stat(line, "resume: ");
    let total = stat(line, &format!("resume: {done}/"));
    let missing = stat(line, "dispatching ");
    (done, total, missing)
}

/// Total bytes of append-only store segments in `dir` (0 when none
/// exist).  A segment is created, 8-byte header included, on the first
/// flush carrying records — so anything past the header is record data.
fn segment_bytes(dir: &PathBuf) -> u64 {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    entries
        .flatten()
        .filter(|e| {
            let name = e.file_name().to_string_lossy().into_owned();
            name.starts_with("seg-") && name.ends_with(".bin")
        })
        .filter_map(|e| e.metadata().ok())
        .map(|m| m.len())
        .sum()
}

/// SIGKILL a profiling campaign mid-run, re-run it with `--resume`, and
/// require zero re-simulation plus a bit-identical dataset.
#[test]
fn sigkilled_campaign_resumes_with_zero_resimulation() {
    let dir = scratch("kill");
    let store = dir.join("store");
    let ref_out = dir.join("ref.json");
    let resumed_out = dir.join("resumed.json");

    // Uninterrupted reference: same campaign, no store, no injection.
    run_ok(&[
        "profile", "--app", "wordcount", "--seed", "7", "--jobs", "1",
        "--no-store", "--out", ref_out.to_str().unwrap(),
    ]);
    let reference = std::fs::read(&ref_out).unwrap();
    assert!(!reference.is_empty());

    // The doomed run: every rep stretched by 40 ms wall-clock (output
    // unchanged), serial dispatch, store-backed.  100 reps ≈ 4 s — ample
    // room to observe records hitting disk and kill mid-campaign.
    let mut child = mrtuner(&[
        "profile", "--app", "wordcount", "--seed", "7", "--jobs", "1",
        "--store", store.to_str().unwrap(),
        "--out", dir.join("doomed.json").to_str().unwrap(),
    ])
    .env("MRTUNER_FAIL_SPEC", "app=wordcount,mode=slow=40")
    .stdout(Stdio::null())
    .stderr(Stdio::null())
    .spawn()
    .expect("spawn doomed campaign");

    // Wait for the first completed reps to reach disk, let a few more
    // land, then SIGKILL — no drop/flush/lock-release code runs.
    let deadline = Instant::now() + Duration::from_secs(30);
    while segment_bytes(&store) <= 8 {
        assert!(Instant::now() < deadline, "no store segment appeared");
        assert!(
            child.try_wait().expect("try_wait").is_none(),
            "doomed campaign finished before it could be killed"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    std::thread::sleep(Duration::from_millis(300));
    child.kill().expect("SIGKILL");
    let _ = child.wait();

    // Resume: the same invocation (sans injection) against the same
    // store must re-simulate exactly the missing remainder.
    let (_, stderr) = run_ok(&[
        "profile", "--app", "wordcount", "--seed", "7", "--jobs", "1",
        "--store", store.to_str().unwrap(), "--resume",
        "--out", resumed_out.to_str().unwrap(),
    ]);
    let (done, total, missing) = resume_line(&stderr);
    assert_eq!(total, 100, "20 settings x 5 reps");
    assert_eq!(done + missing, total);
    assert!(done >= 1, "killed campaign checkpointed at least one rep");
    let stats = stderr
        .lines()
        .find(|l| l.contains("executor stats:"))
        .expect("stats line");
    assert_eq!(
        stat(stats, "simulated="),
        missing,
        "resume simulated exactly the missing reps: {stderr}"
    );
    assert_eq!(stat(stats, "quarantined="), 0);

    // The checkpointed+resumed dataset is the uninterrupted one, bit for
    // bit (mode=slow stretches wall time without touching outputs).
    assert_eq!(
        std::fs::read(&resumed_out).unwrap(),
        reference,
        "resumed dataset differs from uninterrupted reference"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two `--cooperative` processes on one store split the campaign: their
/// `simulated` counts sum to exactly the grid, outputs bit-identical.
#[test]
fn cooperative_processes_split_campaign_exactly() {
    let dir = scratch("coop");
    let store = dir.join("store");
    let ref_out = dir.join("ref.json");
    run_ok(&[
        "profile", "--app", "grep", "--seed", "11", "--jobs", "1",
        "--no-store", "--out", ref_out.to_str().unwrap(),
    ]);
    let reference = std::fs::read(&ref_out).unwrap();

    let out_a = dir.join("a.json");
    let out_b = dir.join("b.json");
    let spawn = |out: &PathBuf| {
        mrtuner(&[
            "profile", "--app", "grep", "--seed", "11", "--jobs", "1",
            "--store", store.to_str().unwrap(), "--cooperative",
            "--out", out.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn cooperative campaign")
    };
    let a = spawn(&out_a);
    let b = spawn(&out_b);
    let a = a.wait_with_output().unwrap();
    let b = b.wait_with_output().unwrap();
    let (err_a, err_b) = (
        String::from_utf8_lossy(&a.stderr).into_owned(),
        String::from_utf8_lossy(&b.stderr).into_owned(),
    );
    assert!(a.status.success(), "peer A failed:\n{err_a}");
    assert!(b.status.success(), "peer B failed:\n{err_b}");

    // Exact coverage: every rep simulated by exactly one peer.  Lease
    // release happens only after the claiming peer flushed, and peers
    // re-check the store before simulating, so the fault-free case has
    // no double work.
    let sim_a = stat(&err_a, "simulated=");
    let sim_b = stat(&err_b, "simulated=");
    assert_eq!(
        sim_a + sim_b,
        100,
        "combined simulated counts must cover the grid exactly \
         (A={sim_a}, B={sim_b})\nA:\n{err_a}\nB:\n{err_b}"
    );
    assert_eq!(stat(&err_a, "quarantined="), 0);
    assert_eq!(stat(&err_b, "quarantined="), 0);

    // Both peers assembled the full campaign, bit-identical to solo.
    let bytes_a = std::fs::read(&out_a).unwrap();
    assert_eq!(bytes_a, std::fs::read(&out_b).unwrap());
    assert_eq!(bytes_a, reference, "cooperative output == solo output");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A poisoned rep is quarantined (not fatal), listed and retried via
/// `mrtuner dlq`, after which `--resume` has nothing left to dispatch.
#[test]
fn poisoned_rep_round_trips_through_dlq() {
    let dir = scratch("dlq");
    let store = dir.join("store");
    let store_s = store.to_str().unwrap();
    let ref_out = dir.join("ref.json");
    run_ok(&[
        "profile", "--app", "wordcount", "--seed", "5", "--jobs", "1",
        "--no-store", "--out", ref_out.to_str().unwrap(),
    ]);
    let reference = std::fs::read(&ref_out).unwrap();

    // Poison repetition 2 of every setting: 20 reps panic through the
    // retry budget and must quarantine without aborting the campaign.
    let poisoned_out = dir.join("poisoned.json");
    let out = mrtuner(&[
        "profile", "--app", "wordcount", "--seed", "5", "--jobs", "2",
        "--store", store_s, "--out", poisoned_out.to_str().unwrap(),
    ])
    .env("MRTUNER_FAIL_SPEC", "rep=2,mode=panic")
    .env("RUST_BACKTRACE", "0")
    .output()
    .expect("spawn poisoned campaign");
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(
        out.status.success(),
        "a quarantined rep must never abort the campaign:\n{stderr}"
    );
    assert_eq!(stat(&stderr, "quarantined="), 20, "{stderr}");
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("wrote"),
        "campaign still produced its dataset"
    );

    // The quarantined reps are visible in the dead-letter queue ...
    let (stdout, _) = run_ok(&["dlq", "list", "--store", store_s]);
    let listing = String::from_utf8_lossy(&stdout).into_owned();
    assert!(listing.contains("20 quarantined rep(s)"), "{listing}");
    assert!(listing.contains("rep=2"), "{listing}");
    assert!(listing.contains("injected fault"), "{listing}");

    // ... and retry (injection gone) recovers every one into the store.
    let (stdout, _) =
        run_ok(&["dlq", "retry", "--store", store_s, "--jobs", "1"]);
    let retry = String::from_utf8_lossy(&stdout).into_owned();
    assert!(retry.contains("20 recovered, 0 re-quarantined"), "{retry}");
    let (stdout, _) = run_ok(&["dlq", "list", "--store", store_s]);
    assert!(
        String::from_utf8_lossy(&stdout).contains("0 quarantined rep(s)"),
        "queue drained after retry"
    );

    // Nothing left to dispatch; the final dataset is the clean one.
    let final_out = dir.join("final.json");
    let (_, stderr) = run_ok(&[
        "profile", "--app", "wordcount", "--seed", "5", "--jobs", "1",
        "--store", store_s, "--resume",
        "--out", final_out.to_str().unwrap(),
    ]);
    let (done, total, missing) = resume_line(&stderr);
    assert_eq!((done, total, missing), (100, 100, 0), "{stderr}");
    let stats = stderr
        .lines()
        .find(|l| l.contains("executor stats:"))
        .expect("stats line");
    assert_eq!(stat(stats, "simulated="), 0, "{stderr}");
    assert_eq!(
        std::fs::read(&final_out).unwrap(),
        reference,
        "recovered campaign == never-poisoned campaign, bit for bit"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
