//! Integration: the parallel campaign executor is a pure optimization —
//! bit-identical results for any worker count, and a rep cache that turns
//! overlapping campaigns (train/test protocols, grid sweeps, what-if
//! replays) into lookups.

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::profiler::campaign::grid_specs;
use mrtuner::profiler::{
    paper_campaign, run_experiment, CampaignExecutor, ExperimentSpec,
};
use mrtuner::report::experiments::{fig4, fig4_with};

#[test]
fn parallel_campaign_bit_identical_to_serial() {
    let cluster = Cluster::paper_cluster();
    let (train, _) = paper_campaign(AppId::WordCount, 42);
    let (serial_results, serial_ds) =
        CampaignExecutor::serial().run_campaign(&cluster, &train);
    for jobs in [4usize, 8] {
        let (results, ds) = CampaignExecutor::new(jobs).run_campaign(&cluster, &train);
        // Bit-level equality: same params, same times, same per-rep raws.
        assert_eq!(ds.params, serial_ds.params, "jobs={jobs}");
        assert_eq!(ds.times, serial_ds.times, "jobs={jobs}");
        for (a, b) in results.iter().zip(&serial_results) {
            assert_eq!(a.rep_times_s, b.rep_times_s, "jobs={jobs}");
            assert_eq!(
                a.mean_time_s.to_bits(),
                b.mean_time_s.to_bits(),
                "jobs={jobs}"
            );
        }
    }
}

#[test]
fn parallel_fig4_surface_bit_identical_to_serial() {
    let serial = fig4(AppId::EximParse, 7, 2, 9);
    let par = fig4_with(&CampaignExecutor::new(4), AppId::EximParse, 7, 2, 9);
    assert_eq!(serial.ms, par.ms);
    assert_eq!(serial.rs, par.rs);
    assert_eq!(serial.times, par.times);
}

#[test]
fn overlapping_grid_and_train_specs_hit_the_cache() {
    let cluster = Cluster::paper_cluster();
    let exec = CampaignExecutor::new(4);
    let seed = 21;
    // "Training": a few hand-picked settings that sit on the step-7 grid.
    let train: Vec<ExperimentSpec> = [(5, 5), (12, 19), (26, 33)]
        .iter()
        .map(|&(m, r)| ExperimentSpec::new(AppId::Grep, m, r))
        .collect();
    let train_results = exec.run_specs(&cluster, &train, 2, seed);
    let misses_after_train = exec.cache_misses();
    assert_eq!(misses_after_train, (train.len() * 2) as u64);
    assert_eq!(exec.cache_hits(), 0);

    // Grid sweep at the same session seed: the three shared settings come
    // back from cache (both reps each), only the rest simulate.
    let grid = grid_specs(AppId::Grep, 7);
    assert!(train.iter().all(|t| grid
        .iter()
        .any(|g| (g.num_mappers, g.num_reducers) == (t.num_mappers, t.num_reducers))));
    let grid_results = exec.run_specs(&cluster, &grid, 2, seed);
    assert_eq!(exec.cache_hits(), (train.len() * 2) as u64);
    assert_eq!(
        exec.cache_misses(),
        misses_after_train + ((grid.len() - train.len()) * 2) as u64
    );

    // Cached rows agree exactly with the original computation.
    for t in &train_results {
        let g = grid_results
            .iter()
            .find(|g| g.spec == t.spec)
            .expect("shared setting present in grid results");
        assert_eq!(g.rep_times_s, t.rep_times_s);
    }
}

#[test]
fn executor_agrees_with_run_experiment() {
    let cluster = Cluster::paper_cluster();
    let spec = ExperimentSpec::new(AppId::WordCount, 20, 5);
    let direct = run_experiment(&cluster, &spec, 3, 77);
    let via_exec = CampaignExecutor::new(4)
        .run_specs(&cluster, &[spec], 3, 77)
        .pop()
        .unwrap();
    assert_eq!(direct.rep_times_s, via_exec.rep_times_s);
    assert_eq!(direct.mean_time_s, via_exec.mean_time_s);
}
