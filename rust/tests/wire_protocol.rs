//! Adversarial and property tests for the binary wire protocol against
//! a live server: arbitrary byte-split delivery, truncated / oversize /
//! garbage-magic frames, per-request error isolation, JSON-op
//! tunneling, and typed GOAWAY + load-shed semantics.
//!
//! The framing contract under test: a decoder must survive any byte
//! split without desync, and structurally impossible bytes must end the
//! connection with a typed GOAWAY — never a panic, never a resync
//! guess.  These run in CI under the bounded-time profile (see
//! `.github/workflows/ci.yml`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use mrtuner::coordinator::client::{Client, ClientError};
use mrtuner::coordinator::wire;
use mrtuner::coordinator::{
    ModelRegistry, PipelinedClient, PredictionService, ServeOptions, Server,
    ServiceConfig,
};
use mrtuner::model::features::NUM_FEATURES;
use mrtuner::model::regression::{FitBackend, RegressionModel, RustSolverBackend};
use mrtuner::util::json::Json;
use mrtuner::util::prop::forall;

fn flat_model(app: &str, base: f64) -> RegressionModel {
    let mut coeffs = [0.0; NUM_FEATURES];
    coeffs[0] = base;
    RegressionModel { app_name: app.into(), coeffs, trained_on: 20 }
}

fn start_service() -> Arc<PredictionService> {
    let mut reg = ModelRegistry::new();
    reg.insert(flat_model("wordcount", 400.0));
    Arc::new(PredictionService::start(
        || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
        reg,
        ServiceConfig::default(),
    ))
}

fn start_server() -> (Server, String) {
    let server = Server::start("127.0.0.1:0", start_service()).unwrap();
    let addr = server.addr.to_string();
    (server, addr)
}

/// A raw socket speaking hand-rolled bytes, with a generous read
/// timeout so a buggy server hangs the test, not CI.
fn raw_conn(addr: &str) -> TcpStream {
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stream
}

/// Read exactly `want` frames off the wire; panics on close, timeout,
/// or (the real assertion) any response bytes that fail to parse.
fn read_frames(stream: &mut TcpStream, want: usize) -> Vec<wire::Frame> {
    let mut fr = wire::FrameReader::new();
    let mut out = Vec::new();
    let mut buf = [0u8; 4096];
    while out.len() < want {
        let n = match stream.read(&mut buf) {
            Ok(0) => panic!("server closed after {}/{want} frames", out.len()),
            Ok(n) => n,
            Err(e) => panic!(
                "read failed after {}/{want} frames: {e}",
                out.len()
            ),
        };
        fr.feed(&buf[..n]);
        while let Some(f) = fr.next_frame().expect("server frames must parse")
        {
            out.push(f);
        }
    }
    out
}

/// Read frames until the server hangs up; every byte it sent must
/// parse as well-formed frames (no trailing garbage).
fn read_frames_until_eof(stream: &mut TcpStream) -> Vec<wire::Frame> {
    let mut fr = wire::FrameReader::new();
    let mut out = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        let n = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e) => panic!("read failed awaiting hang-up: {e}"),
        };
        fr.feed(&buf[..n]);
        while let Some(f) = fr.next_frame().expect("server frames must parse")
        {
            out.push(f);
        }
    }
    assert_eq!(fr.pending_bytes(), 0, "server hung up mid-frame");
    out
}

/// Property: however the client's bytes are split across writes, every
/// pipelined request gets exactly one correct response — framing never
/// desyncs.
#[test]
fn property_pipelined_predicts_survive_arbitrary_byte_splits() {
    let (_server, addr) = start_server();
    forall("byte-split pipelining", 6, |rng| {
        let n = rng.range_usize(8, 24);
        let mut buf = Vec::new();
        wire::encode_preamble(&mut buf);
        for i in 0..n {
            wire::encode_predict_req(
                &mut buf,
                (i + 1) as u64,
                "wordcount",
                5 + (i % 36) as u32,
                5,
            );
        }
        let mut stream = raw_conn(&addr);
        let mut sent = 0;
        while sent < buf.len() {
            let end = (sent + rng.range_usize(1, 17)).min(buf.len());
            stream.write_all(&buf[sent..end]).unwrap();
            stream.flush().unwrap();
            sent = end;
        }
        let frames = read_frames(&mut stream, n);
        let mut ids: Vec<u64> = frames.iter().map(|f| f.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (1..=n as u64).collect::<Vec<_>>());
        for f in &frames {
            assert_eq!(f.tag, wire::RESP_OK, "id {}", f.id);
            let p = wire::decode_predict_ok(&f.body).unwrap();
            assert_eq!(p.seconds, 400.0);
            assert_eq!(p.version, 1);
        }
    });
}

/// Two connections writing interleaved chunks must each get exactly
/// their own request ids back — per-connection framing state never
/// bleeds across handlers.
#[test]
fn interleaved_connections_do_not_cross_talk() {
    let (_server, addr) = start_server();
    let build = |base_id: u64| {
        let mut buf = Vec::new();
        wire::encode_preamble(&mut buf);
        for i in 0..5u64 {
            wire::encode_predict_req(
                &mut buf,
                base_id + i,
                "wordcount",
                10 + i as u32,
                5,
            );
        }
        buf
    };
    let (a_bytes, b_bytes) = (build(1), build(101));
    let mut a = raw_conn(&addr);
    let mut b = raw_conn(&addr);
    let (mut ai, mut bi) = (0usize, 0usize);
    while ai < a_bytes.len() || bi < b_bytes.len() {
        if ai < a_bytes.len() {
            let end = (ai + 9).min(a_bytes.len());
            a.write_all(&a_bytes[ai..end]).unwrap();
            ai = end;
        }
        if bi < b_bytes.len() {
            let end = (bi + 13).min(b_bytes.len());
            b.write_all(&b_bytes[bi..end]).unwrap();
            bi = end;
        }
    }
    let mut a_ids: Vec<u64> =
        read_frames(&mut a, 5).iter().map(|f| f.id).collect();
    let mut b_ids: Vec<u64> =
        read_frames(&mut b, 5).iter().map(|f| f.id).collect();
    a_ids.sort_unstable();
    b_ids.sort_unstable();
    assert_eq!(a_ids, (1..=5).collect::<Vec<_>>());
    assert_eq!(b_ids, (101..=105).collect::<Vec<_>>());
}

/// A connection opening with the binary magic byte but a wrong magic
/// tail gets a typed GOAWAY naming the problem, then a hang-up — not
/// the silent close the JSON protocol used to give.
#[test]
fn garbage_magic_preamble_gets_typed_goaway() {
    let (_server, addr) = start_server();
    let mut s = raw_conn(&addr);
    s.write_all(b"MRTX\x02\x00\x00\x00").unwrap();
    let frames = read_frames_until_eof(&mut s);
    assert_eq!(frames.len(), 1, "{frames:?}");
    assert_eq!(frames[0].tag, wire::RESP_GOAWAY);
    assert_eq!(frames[0].id, 0);
    let reason = String::from_utf8_lossy(&frames[0].body).into_owned();
    assert!(reason.contains("magic"), "{reason}");
}

/// An unsupported wire version is refused with a GOAWAY that names the
/// version this build speaks.
#[test]
fn unsupported_wire_version_gets_typed_goaway() {
    let (_server, addr) = start_server();
    let mut s = raw_conn(&addr);
    s.write_all(b"MRTW").unwrap();
    s.write_all(&9u32.to_le_bytes()).unwrap();
    let frames = read_frames_until_eof(&mut s);
    assert_eq!(frames.len(), 1, "{frames:?}");
    assert_eq!(frames[0].tag, wire::RESP_GOAWAY);
    let reason = String::from_utf8_lossy(&frames[0].body).into_owned();
    assert!(reason.contains("version"), "{reason}");
}

/// An impossible frame length (here: larger than the 64 KB cap) is
/// unrecoverable corruption: GOAWAY, then hang-up — the buffer never
/// grows toward the announced length.
#[test]
fn oversize_frame_length_gets_goaway_not_buffered() {
    let (_server, addr) = start_server();
    let mut s = raw_conn(&addr);
    let mut buf = Vec::new();
    wire::encode_preamble(&mut buf);
    buf.extend_from_slice(&((wire::MAX_FRAME_LEN + 1) as u32).to_le_bytes());
    buf.extend_from_slice(&[0u8; 32]);
    s.write_all(&buf).unwrap();
    let frames = read_frames_until_eof(&mut s);
    assert_eq!(frames.len(), 1, "{frames:?}");
    assert_eq!(frames[0].tag, wire::RESP_GOAWAY);
    let reason = String::from_utf8_lossy(&frames[0].body).into_owned();
    assert!(reason.contains("length"), "{reason}");
}

/// A client vanishing mid-frame is not an error worth answering: the
/// server just closes, and the listener keeps serving new connections.
#[test]
fn truncated_frame_then_close_is_harmless() {
    let (_server, addr) = start_server();
    let mut s = raw_conn(&addr);
    let mut buf = Vec::new();
    wire::encode_preamble(&mut buf);
    wire::encode_predict_req(&mut buf, 1, "wordcount", 20, 5);
    // Preamble plus five bytes of frame, then a half-close.
    s.write_all(&buf[..wire::PREAMBLE_LEN + 5]).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let frames = read_frames_until_eof(&mut s);
    assert!(frames.is_empty(), "{frames:?}");
    // The server is still healthy for the next client.
    let mut c = PipelinedClient::connect(&addr).unwrap();
    let id = c.submit_predict("wordcount", 20, 5);
    c.flush().unwrap();
    let (got, _) = c.recv().unwrap();
    assert_eq!(got, id);
}

/// A malformed request *body* inside intact framing is isolated to its
/// request id: RESP_ERR for the broken one, normal service for every
/// other request before and after it.
#[test]
fn malformed_predict_body_is_isolated_per_request() {
    let (_server, addr) = start_server();
    let mut s = raw_conn(&addr);
    let mut buf = Vec::new();
    wire::encode_preamble(&mut buf);
    // Body announces a 513-byte app name in a 3-byte body.
    wire::encode_frame(&mut buf, 7, wire::REQ_PREDICT, &[1, 2, 3]);
    wire::encode_predict_req(&mut buf, 8, "wordcount", 20, 5);
    s.write_all(&buf).unwrap();
    let frames = read_frames(&mut s, 2);
    for f in &frames {
        match f.id {
            7 => assert_eq!(f.tag, wire::RESP_ERR, "{f:?}"),
            8 => {
                assert_eq!(f.tag, wire::RESP_OK, "{f:?}");
                let p = wire::decode_predict_ok(&f.body).unwrap();
                assert_eq!(p.seconds, 400.0);
            }
            other => panic!("unrequested id {other}"),
        }
    }
    // The connection survived the bad request.
    let mut more = Vec::new();
    wire::encode_predict_req(&mut more, 9, "wordcount", 21, 5);
    s.write_all(&more).unwrap();
    let after = read_frames(&mut s, 1);
    assert_eq!(after[0].id, 9);
    assert_eq!(after[0].tag, wire::RESP_OK);
}

/// Unknown-app failures ride the batch path as per-request server
/// errors: surrounding requests on the same pipelined connection are
/// untouched.
#[test]
fn unknown_app_errors_are_isolated_per_request() {
    let (_server, addr) = start_server();
    let mut c = PipelinedClient::connect(&addr).unwrap();
    let reqs = vec![
        ("wordcount".to_string(), 10, 5),
        ("nosuchapp".to_string(), 10, 5),
        ("wordcount".to_string(), 11, 5),
    ];
    let replies = c.predict_many(&reqs, 8).unwrap();
    assert_eq!(replies.len(), 3);
    assert_eq!(replies[0].as_ref().unwrap().seconds, 400.0);
    match &replies[1] {
        Err(ClientError::Server(msg)) => {
            assert!(msg.contains("no model"), "{msg}")
        }
        other => panic!("expected isolated server error, got {other:?}"),
    }
    assert_eq!(replies[2].as_ref().unwrap().seconds, 400.0);
}

/// Structural corruption mid-stream (after valid traffic) ends the
/// connection with a GOAWAY as the final frame; everything the server
/// sent up to the hang-up still parses cleanly.
#[test]
fn corrupt_framing_mid_stream_ends_with_goaway() {
    let (_server, addr) = start_server();
    let mut s = raw_conn(&addr);
    let mut buf = Vec::new();
    wire::encode_preamble(&mut buf);
    wire::encode_predict_req(&mut buf, 1, "wordcount", 20, 5);
    // A length below the frame-header minimum: unrecoverable.
    buf.extend_from_slice(&3u32.to_le_bytes());
    buf.extend_from_slice(&[0u8; 16]);
    s.write_all(&buf).unwrap();
    let frames = read_frames_until_eof(&mut s);
    let last = frames.last().expect("a GOAWAY must be sent");
    assert_eq!(last.tag, wire::RESP_GOAWAY, "{frames:?}");
    // Any frames before the GOAWAY answer request 1; a GOAWAY may also
    // outrun that in-flight reply — both are within the contract.
    for f in &frames[..frames.len() - 1] {
        assert_eq!(f.id, 1, "{f:?}");
        assert!(
            matches!(f.tag, wire::RESP_OK | wire::RESP_SHED),
            "{f:?}"
        );
    }
}

/// A client writing response tags is outside the protocol: typed
/// GOAWAY naming the misuse, then hang-up.
#[test]
fn client_sending_response_tag_gets_goaway() {
    let (_server, addr) = start_server();
    let mut s = raw_conn(&addr);
    let mut buf = Vec::new();
    wire::encode_preamble(&mut buf);
    wire::encode_frame(&mut buf, 3, wire::RESP_OK, &[0u8; 16]);
    s.write_all(&buf).unwrap();
    let frames = read_frames_until_eof(&mut s);
    assert_eq!(frames.len(), 1, "{frames:?}");
    assert_eq!(frames[0].tag, wire::RESP_GOAWAY);
    let reason = String::from_utf8_lossy(&frames[0].body).into_owned();
    assert!(reason.contains("response tag"), "{reason}");
}

/// The whole legacy JSON surface tunnels through REQ_JSON frames, and
/// a tunneled predict answers with exactly the bits the native binary
/// predict produces.
#[test]
fn json_ops_tunnel_through_binary_frames() {
    let (_server, addr) = start_server();
    let mut c = PipelinedClient::connect(&addr).unwrap();

    let models = c
        .json_op(&Json::obj(vec![("op", Json::Str("models".into()))]))
        .unwrap();
    let names: Vec<&str> = models
        .get("models")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .filter_map(|v| v.as_str())
        .collect();
    assert_eq!(names, vec!["wordcount"]);

    let health = c
        .json_op(&Json::obj(vec![("op", Json::Str("health".into()))]))
        .unwrap();
    assert_eq!(health.get("shed").and_then(|v| v.as_f64()), Some(0.0));

    let tunneled = c
        .json_op(&Json::obj(vec![
            ("op", Json::Str("predict".into())),
            ("app", Json::Str("wordcount".into())),
            ("mappers", Json::Num(20.0)),
            ("reducers", Json::Num(5.0)),
        ]))
        .unwrap();
    let via_json = tunneled.get("predicted_s").and_then(|v| v.as_f64());

    let id = c.submit_predict("wordcount", 20, 5);
    c.flush().unwrap();
    let (got, reply) = c.recv().unwrap();
    assert_eq!(got, id);
    let native = match reply {
        mrtuner::coordinator::client::Reply::Predict(p) => p.seconds,
        other => panic!("expected predict reply, got {other:?}"),
    };
    assert_eq!(via_json.map(f64::to_bits), Some(native.to_bits()));
}

/// Read one `\n`-terminated line off a raw legacy (JSON-lines) socket.
fn read_json_line(stream: &mut TcpStream) -> String {
    let mut out = Vec::new();
    let mut b = [0u8; 1];
    loop {
        match stream.read(&mut b) {
            Ok(0) => panic!("server closed mid-line: {out:?}"),
            Ok(_) if b[0] == b'\n' => break,
            Ok(_) => out.push(b[0]),
            Err(e) => panic!("read failed awaiting line: {e}"),
        }
    }
    String::from_utf8(out).expect("legacy replies are UTF-8")
}

/// Multi-target serving conformance: one `(app, target, M, R)` predict
/// answers with exactly the same bits over all three surfaces — the
/// legacy JSON-lines `target` field, a REQ_JSON tunnel through binary
/// frames, and a native binary predict against the target-qualified
/// registry name.  And the target-*less* legacy predict is untouched:
/// same figure as the plain `time_s` model, with no `target` key in the
/// reply line.
#[test]
fn multi_target_predicts_bit_identical_across_protocols() {
    let mut reg = ModelRegistry::new();
    reg.insert(flat_model("wordcount", 400.0));
    reg.insert(flat_model("wordcount@cpu_s", 1234.5));
    reg.insert(flat_model("wordcount@shuffle_bytes", 8.6e9));
    let svc = Arc::new(PredictionService::start(
        || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
        reg,
        ServiceConfig::default(),
    ));
    let server = Server::start("127.0.0.1:0", svc).unwrap();
    let addr = server.addr.to_string();

    let mut legacy = Client::connect(&addr).unwrap();
    let mut pipelined = PipelinedClient::connect(&addr).unwrap();
    for (target, qualified, expect) in [
        ("time_s", "wordcount", 400.0f64),
        ("cpu_s", "wordcount@cpu_s", 1234.5),
        ("shuffle_bytes", "wordcount@shuffle_bytes", 8.6e9),
    ] {
        let via_legacy =
            legacy.predict_target("wordcount", target, 20, 5).unwrap();
        assert_eq!(via_legacy.version, 1, "{target}");

        let tunneled = pipelined
            .json_op(&Json::obj(vec![
                ("op", Json::Str("predict".into())),
                ("app", Json::Str("wordcount".into())),
                ("target", Json::Str(target.into())),
                ("mappers", Json::Num(20.0)),
                ("reducers", Json::Num(5.0)),
            ]))
            .unwrap();
        assert_eq!(
            tunneled.get("target").and_then(|v| v.as_str()),
            Some(target),
            "tunneled reply echoes the requested target"
        );
        let via_tunnel = tunneled
            .get("predicted_s")
            .and_then(|v| v.as_f64())
            .expect("tunneled predict carries predicted_s");

        let id = pipelined.submit_predict(qualified, 20, 5);
        pipelined.flush().unwrap();
        let (got, reply) = pipelined.recv().unwrap();
        assert_eq!(got, id);
        let native = match reply {
            mrtuner::coordinator::client::Reply::Predict(p) => p.seconds,
            other => panic!("expected predict reply, got {other:?}"),
        };

        assert_eq!(native, expect, "{target}");
        assert_eq!(via_legacy.seconds.to_bits(), native.to_bits(), "{target}");
        assert_eq!(via_tunnel.to_bits(), native.to_bits(), "{target}");
    }

    // Byte-level legacy conformance on a raw socket: no `target` in the
    // request means no `target` in the reply — the pre-multi-target
    // response shape, serving the plain time model.
    let mut raw = raw_conn(&addr);
    raw.write_all(
        b"{\"op\":\"predict\",\"app\":\"wordcount\",\
          \"mappers\":20,\"reducers\":5}\n",
    )
    .unwrap();
    let line = read_json_line(&mut raw);
    assert!(line.contains("\"predicted_s\":400"), "{line}");
    assert!(!line.contains("\"target\""), "{line}");
    // And a targeted request over the same raw socket does echo it.
    raw.write_all(
        b"{\"op\":\"predict\",\"app\":\"wordcount\",\
          \"target\":\"shuffle_bytes\",\"mappers\":20,\"reducers\":5}\n",
    )
    .unwrap();
    let line = read_json_line(&mut raw);
    assert!(line.contains("\"target\":\"shuffle_bytes\""), "{line}");
}

/// Admission control under a deliberately starved queue: some requests
/// come back as typed SHED (surfaced as [`ClientError::Shed`]), the
/// rest are answered correctly, and the `shed` health counter agrees
/// with what the client observed.
#[test]
fn starved_queue_sheds_typed_and_counted() {
    let svc = start_service();
    let opts = ServeOptions {
        workers: 1,
        queue_depth: 1,
        max_batch: 4,
        batch_delay: Duration::from_millis(5),
    };
    let mut server =
        Server::start_tuned("127.0.0.1:0", Arc::clone(&svc), None, opts)
            .unwrap();
    let addr = server.addr.to_string();
    let reqs: Vec<(String, u32, u32)> = (0..200u32)
        .map(|i| ("wordcount".to_string(), 5 + (i % 36), 5))
        .collect();
    let mut c = PipelinedClient::connect(&addr).unwrap();
    let replies = c.predict_many(&reqs, 128).unwrap();
    let mut shed = 0u64;
    let mut served = 0u64;
    for r in &replies {
        match r {
            Ok(p) => {
                assert_eq!(p.seconds, 400.0);
                served += 1;
            }
            Err(ClientError::Shed) => shed += 1,
            Err(other) => panic!("only Ok or Shed expected, got {other:?}"),
        }
    }
    assert_eq!(served + shed, 200);
    assert!(served > 0, "starved server answered nothing");
    assert!(shed > 0, "queue depth 1 with a 5 ms worker never shed");
    assert_eq!(
        svc.metrics.shed.load(Ordering::Relaxed),
        shed,
        "health counter must match the typed SHED frames sent"
    );
    server.shutdown();
}
