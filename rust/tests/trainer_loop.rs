//! End-to-end acceptance for the profile → model loop: a server started
//! against a warm store serves `predict`; a later profiling campaign
//! appends reps for a *new* application to the same store; after
//! `retrain` the server answers `predict` for the new app **without
//! restart**, with refit coefficients matching a from-scratch
//! `RegressionModel::fit_dataset` over the same reps to within 1e-9.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::coordinator::client::{Client, ClientError};
use mrtuner::coordinator::{
    ModelRegistry, PredictionService, Server, ServiceConfig, Trainer,
};
use mrtuner::model::features::NUM_FEATURES;
use mrtuner::model::regression::{FitBackend, RegressionModel, RustSolverBackend};
use mrtuner::profiler::{
    CampaignExecutor, Dataset, ExperimentResult, ExperimentSpec, ProfileStore,
};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_trainer_loop_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small grid that still identifies the 7-coefficient cubic.
fn settings(app: AppId) -> Vec<ExperimentSpec> {
    let mut out = Vec::new();
    for m in [5u32, 12, 19, 26, 33, 40] {
        for r in [5u32, 22, 40] {
            out.push(ExperimentSpec::new(app, m, r));
        }
    }
    out
}

/// Profile `app` into the store at `dir` with its own executor instance
/// (a separate "profiling campaign" session), returning the raw results.
fn run_campaign(
    dir: &Path,
    app: AppId,
    reps: u32,
    seed: u64,
) -> Vec<ExperimentResult> {
    let exec = CampaignExecutor::new(2)
        .with_store(ProfileStore::open(dir).expect("open store"));
    let cluster = Cluster::paper_cluster();
    exec.run_specs(&cluster, &settings(app), reps, seed)
}

/// From-scratch reference fit over the same reps the trainer saw: one
/// mean row per setting, rows sorted by `(M, R)` — the trainer's
/// deterministic construction.
fn fit_from_scratch(app: AppId, results: &[ExperimentResult]) -> RegressionModel {
    let mut rows: Vec<(ExperimentSpec, f64)> =
        results.iter().map(|r| (r.spec, r.mean_time_s)).collect();
    rows.sort_by_key(|(s, _)| (s.num_mappers, s.num_reducers));
    let mut ds = Dataset {
        app_name: app.name().to_string(),
        params: Vec::new(),
        times: Vec::new(),
    };
    for (spec, mean) in &rows {
        ds.push(spec, *mean);
    }
    RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).expect("fit")
}

#[test]
fn profile_retrain_predict_without_restart() {
    let dir = tmp_dir("e2e");
    let cluster = Cluster::paper_cluster();

    // ---- 1. A prior session warms the store with a wordcount campaign.
    let wc_results = run_campaign(&dir, AppId::WordCount, 2, 11);

    // ---- 2. A server starts against the warm store: empty registry, a
    // trainer synced once at startup (as `serve --store` does).
    let service = Arc::new(PredictionService::start(
        || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
        ModelRegistry::new(),
        ServiceConfig::default(),
    ));
    let trainer = {
        let mut t = Trainer::open(&dir, &cluster).expect("open trainer");
        let summary = t.retrain(&service).expect("initial retrain");
        // Store records carry every figure, so one campaign publishes
        // one model per target: the time model under the plain app
        // name, the others target-qualified.
        assert_eq!(
            summary.published,
            vec![
                ("wordcount".to_string(), 1),
                ("wordcount@cpu_s".to_string(), 1),
                ("wordcount@shuffle_bytes".to_string(), 1),
            ]
        );
        Arc::new(Mutex::new(t))
    };
    let server = Server::start_with(
        "127.0.0.1:0",
        Arc::clone(&service),
        Some(Arc::clone(&trainer)),
    )
    .unwrap();
    let addr = server.addr.to_string();

    // The warm-store app serves immediately, version 1 ...
    let mut client = Client::connect(&addr).unwrap();
    let p = client.predict_versioned("wordcount", 20, 5).unwrap();
    assert_eq!(p.version, 1);
    assert!(p.seconds.is_finite() && p.seconds > 0.0);
    // ... and the wordcount coefficients already match a from-scratch
    // fit over the store's reps.
    let scratch_wc = fit_from_scratch(AppId::WordCount, &wc_results);
    let info = client.model_info("wordcount").unwrap();
    for i in 0..NUM_FEATURES {
        assert!(
            (info.coeffs[i] - scratch_wc.coeffs[i]).abs() < 1e-9,
            "wordcount coeff {i}"
        );
    }
    assert_eq!(info.trained_on, 18);
    assert!(info.fit_rmse.is_some());

    // The companion targets serve through the request's `target` field,
    // in their own units; `time_s` resolves the identical legacy entry.
    let shuffle =
        client.predict_target("wordcount", "shuffle_bytes", 20, 5).unwrap();
    assert_eq!(shuffle.version, 1);
    assert!(shuffle.seconds.is_finite() && shuffle.seconds > 0.0);
    let cpu = client.predict_target("wordcount", "cpu_s", 20, 5).unwrap();
    assert!(cpu.seconds.is_finite() && cpu.seconds > 0.0);
    let t = client.predict_target("wordcount", "time_s", 20, 5).unwrap();
    assert_eq!(t.seconds.to_bits(), p.seconds.to_bits());
    assert_eq!(client.model_info("wordcount@shuffle_bytes").unwrap().trained_on, 18);

    // Grep has never been profiled: a typed protocol error.
    match client.predict("grep", 20, 5) {
        Err(ClientError::Server(msg)) => assert!(msg.contains("no model")),
        other => panic!("expected no-model error, got {other:?}"),
    }

    // ---- 3. A *subsequent* profiling campaign appends reps for a new
    // app to the same store (its own executor + store session).
    let grep_results = run_campaign(&dir, AppId::Grep, 3, 7);

    // Still unknown until a retrain tails the store ...
    assert!(client.predict("grep", 20, 5).is_err());

    // ---- 4. `retrain` over the wire: the server picks the new app up
    // without restart.
    let reply = client.retrain().unwrap();
    assert_eq!(reply.new_records, 54, "18 settings x 3 reps of grep");
    assert_eq!(
        reply.refits,
        vec![
            ("grep".to_string(), 1),
            ("grep@cpu_s".to_string(), 1),
            ("grep@shuffle_bytes".to_string(), 1),
        ]
    );

    let p = client.predict_versioned("grep", 20, 5).unwrap();
    assert_eq!(p.version, 1);
    assert!(p.seconds.is_finite() && p.seconds > 0.0);

    // ---- 5. The acceptance bound: refit coefficients match the
    // from-scratch fit over the same reps to within 1e-9.
    let scratch = fit_from_scratch(AppId::Grep, &grep_results);
    let info = client.model_info("grep").unwrap();
    assert_eq!(info.version, 1);
    assert_eq!(info.trained_on, 18);
    for i in 0..NUM_FEATURES {
        assert!(
            (info.coeffs[i] - scratch.coeffs[i]).abs() < 1e-9,
            "grep coeff {i}: {} vs {}",
            info.coeffs[i],
            scratch.coeffs[i]
        );
    }
    // The served prediction is the refit model's own prediction.
    assert!((p.seconds - scratch.predict_one(20, 5)).abs() < 1e-9);

    // ---- 6. More wordcount data (a new session) tightens the fit: the
    // next retrain publishes version 2, trained on more reps, while
    // untouched apps keep their version.
    run_campaign(&dir, AppId::WordCount, 2, 99);
    let reply = client.retrain().unwrap();
    assert_eq!(
        reply.refits,
        vec![
            ("wordcount".to_string(), 2),
            ("wordcount@cpu_s".to_string(), 2),
            ("wordcount@shuffle_bytes".to_string(), 2),
        ]
    );
    let p2 = client.predict_versioned("wordcount", 20, 5).unwrap();
    assert_eq!(p2.version, 2, "hot-swapped refit serves immediately");
    assert_eq!(client.model_info("grep").unwrap().version, 1);
    // A retrain with nothing new refits nothing.
    let idle = client.retrain().unwrap();
    assert_eq!(idle.new_records, 0);
    assert!(idle.refits.is_empty());

    drop(client);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The same loop through the in-process API, hammered concurrently and
/// per target: a retrain hot-swap must never error a single in-flight
/// predict, and every worker must observe each target's model version
/// monotonically — a swap of three models never serves a version that
/// goes backwards on any of them.
#[test]
fn concurrent_predicts_survive_a_retrain_swap() {
    let dir = tmp_dir("swap");
    let cluster = Cluster::paper_cluster();
    run_campaign(&dir, AppId::WordCount, 2, 11);

    let service = Arc::new(PredictionService::start(
        || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
        ModelRegistry::new(),
        ServiceConfig::default(),
    ));
    let mut trainer = Trainer::open(&dir, &cluster).unwrap();
    trainer.retrain(&service).unwrap();

    // New data lands while traffic is in flight.
    run_campaign(&dir, AppId::WordCount, 2, 42);
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let targets =
        ["wordcount", "wordcount@cpu_s", "wordcount@shuffle_bytes"];
    let mut workers = Vec::new();
    for name in targets {
        for _ in 0..2 {
            let service = Arc::clone(&service);
            let stop = Arc::clone(&stop);
            workers.push(std::thread::spawn(move || {
                let mut last = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let p = service
                        .predict_versioned(name, 20, 5)
                        .expect("no errors mid-swap");
                    assert!(
                        p.version >= last,
                        "monotonic versions for {name}"
                    );
                    last = p.version;
                }
                (name, last)
            }));
        }
    }
    let summary = trainer.retrain(&service).unwrap();
    assert_eq!(
        summary.published,
        vec![
            ("wordcount".to_string(), 2),
            ("wordcount@cpu_s".to_string(), 2),
            ("wordcount@shuffle_bytes".to_string(), 2),
        ]
    );
    // Let the workers observe the new versions before stopping.
    std::thread::sleep(std::time::Duration::from_millis(50));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let finals: Vec<(&str, u64)> =
        workers.into_iter().map(|w| w.join().unwrap()).collect();
    for name in targets {
        assert!(
            finals.iter().any(|&(n, v)| n == name && v == 2),
            "some worker must see the swapped version of {name}: {finals:?}"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
