//! Integration: the extended 4-parameter sweeps run through the caching
//! campaign executor and the persistent profile store.
//!
//! Pins the ISSUE 3 acceptance criteria down: executor-backed ext4
//! serial/parallel bit-identity, cold→warm store round-trips across two
//! `ProfileStore` opens, and a repeated `ext4` CLI campaign against a
//! warm `--store` simulating **zero** reps while emitting stdout
//! bit-identical to a cold serial run.

use std::path::PathBuf;
use std::process::Command;

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::profiler::{run_ext4, CampaignExecutor, Ext4Spec, ProfileStore};

/// Unique per-test scratch directory (removed up front so reruns are
/// deterministic even after a crashed run).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_ext4_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn espec(m: u32, r: u32, input_gb: f64, block_mb: u32) -> Ext4Spec {
    Ext4Spec { app: AppId::WordCount, num_mappers: m, num_reducers: r, input_gb, block_mb }
}

fn specs() -> Vec<Ext4Spec> {
    vec![
        espec(20, 5, 2.0, 64),
        espec(10, 30, 4.5, 128),
        espec(35, 12, 1.0, 32),
    ]
}

#[test]
fn ext4_parallel_and_wrappers_agree_with_serial() {
    let cluster = Cluster::paper_cluster();
    let serial = CampaignExecutor::serial().run_ext4_specs(&cluster, &specs(), 2, 9);
    for jobs in [2usize, 4] {
        let par = CampaignExecutor::new(jobs).run_ext4_specs(&cluster, &specs(), 2, 9);
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.mean_time_s.to_bits(), b.mean_time_s.to_bits(), "jobs={jobs}");
            assert_eq!(a.mean_cpu_s.to_bits(), b.mean_cpu_s.to_bits(), "jobs={jobs}");
        }
    }
    // The free-function convenience wrapper is the same computation.
    let one = run_ext4(&cluster, &specs()[1], 2, 9);
    assert_eq!(one.mean_time_s.to_bits(), serial[1].mean_time_s.to_bits());
    assert_eq!(one.mean_cpu_s.to_bits(), serial[1].mean_cpu_s.to_bits());
}

#[test]
fn ext4_cold_then_warm_across_two_store_opens() {
    let dir = scratch("warm");
    let cluster = Cluster::paper_cluster();

    let cold = {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        let res = exec.run_ext4_specs(&cluster, &specs(), 2, 11);
        assert_eq!(exec.stats().simulated, 6);
        res
    }; // drop flushes the store and releases the segment lock

    // Second open of the same directory: everything answers from disk,
    // including the CPU figures the 4-parameter pipeline needs.
    let exec2 = CampaignExecutor::new(4)
        .with_store(ProfileStore::open(&dir).unwrap());
    let warm = exec2.run_ext4_specs(&cluster, &specs(), 2, 11);
    let st = exec2.stats();
    assert_eq!(st.simulated, 0, "fully warm-started from disk");
    assert_eq!(st.store_hits, 6);
    assert_eq!(st.store_entries, 6);
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.mean_time_s.to_bits(), b.mean_time_s.to_bits());
        assert_eq!(a.mean_cpu_s.to_bits(), b.mean_cpu_s.to_bits());
    }
    drop(exec2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ext4_and_paper_campaigns_share_one_store() {
    let dir = scratch("shared");
    let cluster = Cluster::paper_cluster();
    // A paper-plane ext4 setting written by one session ...
    {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        exec.run_ext4_specs(&cluster, &[espec(20, 5, 8.0, 64)], 2, 7);
        assert_eq!(exec.stats().simulated, 2);
    }
    // ... warm-starts the 2-parameter path in another process/session,
    // because on the paper plane both shapes share keys *and* configs.
    let exec = CampaignExecutor::new(2)
        .with_store(ProfileStore::open(&dir).unwrap());
    let specs = [mrtuner::profiler::ExperimentSpec::new(AppId::WordCount, 20, 5)];
    exec.run_specs(&cluster, &specs, 2, 7);
    assert_eq!(exec.stats().simulated, 0, "paper reps answered by ext4 records");
    assert_eq!(exec.stats().store_hits, 2);
    drop(exec);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE 3 acceptance criterion, via the real binary: a repeated
/// `ext4` campaign against a warm `--store` simulates zero reps and its
/// stdout is bit-identical to a cold serial run.
#[test]
fn ext4_cli_warm_store_is_bit_identical_to_cold_serial() {
    let dir = scratch("cli");
    std::fs::create_dir_all(&dir).unwrap();
    let bin = env!("CARGO_BIN_EXE_mrtuner");
    let base_args = [
        "ext4", "--app", "wordcount", "--train", "20", "--test", "5",
        "--reps", "1", "--seed", "7",
    ];

    // Cold *serial* reference run, no store.
    let cold = Command::new(bin)
        .args(base_args)
        .args(["--jobs", "1", "--no-store"])
        .output()
        .expect("spawn mrtuner ext4 (cold serial)");
    assert!(
        cold.status.success(),
        "cold ext4 failed: {}",
        String::from_utf8_lossy(&cold.stderr)
    );

    let run_store = || {
        let out = Command::new(bin)
            .args(base_args)
            .args(["--jobs", "2", "--store"])
            .arg(&dir)
            .output()
            .expect("spawn mrtuner ext4 (store)");
        assert!(
            out.status.success(),
            "store ext4 failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        (out.stdout, String::from_utf8_lossy(&out.stderr).into_owned())
    };

    // 20 train + 5 test settings × 1 rep: everything simulates when cold
    // (25 reps, minus any random-sampling duplicate coalesced by the
    // cache — hence the shape of the assertions).
    let (out1, err1) = run_store();
    assert!(err1.contains("store=on"), "store attached: {err1}");
    assert!(!err1.contains("simulated=0"), "cold run must simulate: {err1}");
    assert!(err1.contains("store_hits=0"), "nothing on disk yet: {err1}");
    let (out2, err2) = run_store();
    assert!(err2.contains("simulated=0"), "warm run simulates none: {err2}");
    assert!(!err2.contains("store_hits=0"), "store answers the reps: {err2}");

    assert!(!cold.stdout.is_empty());
    assert_eq!(cold.stdout, out1, "parallel+store output == cold serial output");
    assert_eq!(out1, out2, "warm output bit-identical to cold output");
    let _ = std::fs::remove_dir_all(&dir);
}
