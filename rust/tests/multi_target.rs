//! Cross-layer conformance tests for multi-target modeling: the
//! shuffle/HDFS byte counters introduced with store format v4.
//!
//! Covers the counters' determinism contract (serial, parallel, and
//! warm-store replay all bit-identical), the v3→v4 store migration
//! (records open in place with bytes absent, NaN payloads survive, a
//! full-path run upgrades them without losing the time bits), the store
//! precedence invariant (a bytes-less record never displaces a full
//! one, property-tested over arbitrary bit patterns), and the
//! quarantine contract (a poisoned rep surfaces as a null byte-mean
//! without aborting the campaign).

use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::mr::{RepBytes, RepOutcome};
use mrtuner::profiler::store::{encode_record_bin, read_file_records};
use mrtuner::profiler::{
    cluster_fingerprint, CampaignExecutor, ExperimentSpec, ProfileStore,
    RetryPolicy, StoreKey, STORE_FORMAT_VERSION,
};
use mrtuner::util::prop::forall;

/// Unique per-test scratch directory (removed up front so reruns are
/// deterministic even after a crashed run).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_mt_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The store root plus every `shard-NN/` directory under it.
fn store_dirs(dir: &PathBuf) -> Vec<PathBuf> {
    let mut out = vec![dir.clone()];
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if name.starts_with("shard-") && e.path().is_dir() {
                out.push(e.path());
            }
        }
    }
    out.sort();
    out
}

/// Every store file holding records: live segments plus compacted
/// indexes, across the root and all shards.
fn record_files(dir: &PathBuf) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = store_dirs(dir)
        .iter()
        .filter_map(|d| std::fs::read_dir(d).ok())
        .flatten()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            let n = p.file_name().unwrap().to_string_lossy().into_owned();
            (n.starts_with("seg-") && n.ends_with(".bin")) || n == "index.bin"
        })
        .collect();
    out.sort();
    out
}

/// A v3 frame is a v4 frame minus the bytes section: strip the trailing
/// bytes-absent flag and shrink the length prefix — exactly what a
/// pre-byte-counter build wrote.
fn v3_frame(key: &StoreKey, outcome: &RepOutcome, touch: u64) -> Vec<u8> {
    assert!(outcome.bytes.is_none(), "v3 cannot carry bytes");
    let mut frame = encode_record_bin(key, outcome, touch);
    assert_eq!(*frame.last().unwrap(), 0, "bytes-absent flag");
    frame.pop();
    let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) - 1;
    frame[0..4].copy_from_slice(&len.to_le_bytes());
    frame
}

/// A whole store file as a v3 build left it: `MRTS` magic, version 3,
/// then concatenated v3 frames.
fn v3_file(records: &[(StoreKey, RepOutcome)]) -> Vec<u8> {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"MRTS");
    bytes.extend_from_slice(&3u32.to_le_bytes());
    for (i, (key, outcome)) in records.iter().enumerate() {
        bytes.extend_from_slice(&v3_frame(key, outcome, 1 + i as u64));
    }
    bytes
}

/// The paper-plane store key of one `(spec, rep)` within a session.
fn paper_key(fp: u64, spec: &ExperimentSpec, rep: u32, seed: u64) -> StoreKey {
    StoreKey {
        cluster: fp,
        app: spec.app,
        num_mappers: spec.num_mappers,
        num_reducers: spec.num_reducers,
        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
        block_mb: StoreKey::PAPER_BLOCK_MB,
        rep,
        base_seed: seed,
    }
}

/// The multi-target determinism contract across every app: shuffle and
/// HDFS byte-means are always recorded and bit-identical whether the
/// campaign runs serially, over a worker pool, or replays warm from a
/// persistent store (with zero re-simulation).
#[test]
fn byte_counters_bit_identical_serial_parallel_and_warm_store() {
    let cluster = Cluster::paper_cluster();
    let mut specs = Vec::new();
    for app in AppId::all() {
        specs.push(ExperimentSpec::new(app, 10, 10));
        specs.push(ExperimentSpec::new(app, 20, 5));
    }
    let (reps, seed) = (2, 33);

    let serial =
        CampaignExecutor::serial().run_specs_full(&cluster, &specs, reps, seed);
    let parallel =
        CampaignExecutor::new(4).run_specs_full(&cluster, &specs, reps, seed);

    let assert_bit_identical = |a: &[mrtuner::profiler::FullExperimentResult],
                                b: &[mrtuner::profiler::FullExperimentResult],
                                label: &str| {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.rep_times_s, y.rep_times_s, "{label}: {:?}", x.spec);
            assert_eq!(
                x.mean_cpu_s.to_bits(),
                y.mean_cpu_s.to_bits(),
                "{label}: {:?}",
                x.spec
            );
            let (xs, ys) = (
                x.mean_shuffle_bytes.expect("counters always recorded"),
                y.mean_shuffle_bytes.expect("counters always recorded"),
            );
            assert_eq!(xs.to_bits(), ys.to_bits(), "{label}: {:?}", x.spec);
            let (xh, yh) = (
                x.mean_hdfs_bytes.expect("counters always recorded"),
                y.mean_hdfs_bytes.expect("counters always recorded"),
            );
            assert_eq!(xh.to_bits(), yh.to_bits(), "{label}: {:?}", x.spec);
        }
    };
    assert_bit_identical(&serial, &parallel, "serial vs parallel");

    // Every app moves bytes on this plane — even grep's near-zero
    // selectivity leaves megabytes of an 8 GB input in the shuffle —
    // and the shuffle-bound sort moves more than any other app at the
    // same setting, which is the signal the new target models.
    for r in &serial {
        assert!(r.mean_shuffle_bytes.unwrap() > 0.0, "{:?}", r.spec);
        assert!(
            r.mean_hdfs_bytes.unwrap() > r.mean_shuffle_bytes.unwrap(),
            "HDFS traffic includes the input read: {:?}",
            r.spec
        );
    }
    let shuffle_at = |app: AppId| {
        serial
            .iter()
            .find(|r| r.spec.app == app && r.spec.num_mappers == 10)
            .unwrap()
            .mean_shuffle_bytes
            .unwrap()
    };
    for other in AppId::all() {
        if other != AppId::Sort {
            assert!(
                shuffle_at(AppId::Sort) > shuffle_at(other),
                "sort out-shuffles {other:?}"
            );
        }
    }

    // Warm-store replay: a second executor over the same directory
    // serves every rep — counters included — from disk, bit-identically.
    let dir = scratch("fullwarm");
    let cold = {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        let res = exec.run_specs_full(&cluster, &specs, reps, seed);
        assert_eq!(exec.stats().simulated, (specs.len() * reps as usize) as u64);
        res
    }; // drop flushes the store and releases the segment lock
    assert_bit_identical(&serial, &cold, "storeless vs store-backed");
    let exec = CampaignExecutor::new(4)
        .with_store(ProfileStore::open(&dir).unwrap());
    let warm = exec.run_specs_full(&cluster, &specs, reps, seed);
    assert_eq!(exec.stats().simulated, 0, "fully warm from disk");
    assert_bit_identical(&cold, &warm, "cold vs warm");
    drop(exec);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A store left behind by a v3 build opens in place: every record is
/// served with bytes absent and its time/CPU bits — NaN payloads
/// included — intact, and the first compaction rewrites the file at the
/// current format version without perturbing a single bit.
#[test]
fn v3_store_round_trips_nan_payloads_through_migration() {
    let dir = scratch("v3nan");
    let patterns: [u64; 4] = [
        0x7FF8_DEAD_BEEF_0001, // quiet NaN with payload
        0x7FF0_0000_0000_0001, // signaling NaN
        0xFFF8_0000_0000_0042, // negative quiet NaN with payload
        f64::NEG_INFINITY.to_bits(),
    ];
    let key = |rep: u32| StoreKey {
        cluster: 0xC0FF_EE00,
        app: AppId::Sort,
        num_mappers: 7,
        num_reducers: 3,
        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
        block_mb: StoreKey::PAPER_BLOCK_MB,
        rep,
        base_seed: 13,
    };
    let mut records = Vec::new();
    for (rep, bits) in patterns.iter().enumerate() {
        records.push((
            key(rep as u32),
            RepOutcome::full(
                f64::from_bits(*bits),
                f64::from_bits(bits ^ 1),
            ),
        ));
    }
    // And one v1-era time-only record that the v3 build preserved.
    records.push((key(99), RepOutcome::time_only(f64::from_bits(patterns[0]))));

    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("seg-0000beef-0000-v3legacy.bin"),
        v3_file(&records),
    )
    .unwrap();

    let store = ProfileStore::open(&dir).unwrap();
    for (k, o) in &records {
        let got = store.get(k).expect("v3 record opens in place");
        assert!(got.same_bits(o), "rep {}: bits preserved", k.rep);
        assert_eq!(got.bytes, None, "v3 records carry no counters");
    }
    store.compact_now().unwrap();
    drop(store);

    // Post-compaction the records live in current-version files, still
    // bit-identical and still bytes-less (migration never invents data).
    let mut seen = 0;
    for path in record_files(&dir) {
        for (k, o, ver) in read_file_records(&path).unwrap() {
            assert_eq!(ver, STORE_FORMAT_VERSION, "rewritten at v4");
            let (_, expect) = records
                .iter()
                .find(|(rk, _)| *rk == k)
                .expect("no record orphaned");
            assert!(o.same_bits(expect), "rep {}: bits preserved", k.rep);
            seen += 1;
        }
    }
    assert_eq!(seen, records.len(), "every record survived compaction");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The in-place upgrade path end to end: v3 records keep answering the
/// paper's time path with zero re-simulation and bit-identical times; a
/// full (multi-target) run re-simulates exactly those records — with
/// bit-identical times and counters — and upgrades them on disk, after
/// which the full path is warm too.
#[test]
fn v3_records_warm_time_path_and_full_run_upgrades_in_place() {
    let dir = scratch("v3upgrade");
    let cluster = Cluster::paper_cluster();
    let fp = cluster_fingerprint(&cluster);
    let specs = [
        ExperimentSpec::new(AppId::Sort, 10, 10),
        ExperimentSpec::new(AppId::Join, 20, 5),
    ];
    let (reps, seed) = (2u32, 11u64);

    // Cold v4 run to learn the authoritative records.
    let cold = {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        let res = exec.run_specs_full(&cluster, &specs, reps, seed);
        assert_eq!(exec.stats().simulated, 4);
        res
    };

    // Rewrite the store as the v3 build would have left it: the same
    // records, bytes stripped, in one version-3 file.
    let mut v3_records = Vec::new();
    {
        let store = ProfileStore::peek(&dir).unwrap();
        for s in &specs {
            for rep in 0..reps {
                let k = paper_key(fp, s, rep, seed);
                let o = store.get(&k).expect("cold record on disk");
                assert!(o.bytes.is_some(), "v4 records carry counters");
                v3_records.push((k, RepOutcome { bytes: None, ..o }));
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("seg-0000beef-0000-v3legacy.bin"),
        v3_file(&v3_records),
    )
    .unwrap();

    // Time path: v3 records answer without any re-simulation, and the
    // paper's `time_s` pipeline output is bit-identical.
    let exec = CampaignExecutor::new(4)
        .with_store(ProfileStore::open(&dir).unwrap());
    let warm_time = exec.run_specs(&cluster, &specs, reps, seed);
    assert_eq!(exec.stats().simulated, 0, "time path warm from v3 records");
    for (a, b) in cold.iter().zip(&warm_time) {
        assert_eq!(a.rep_times_s, b.rep_times_s, "{:?}", a.spec);
    }
    drop(exec);

    // Full path: every v3 record counts as a miss, is re-simulated
    // bit-identically, and the stored record is upgraded in place.
    let exec = CampaignExecutor::new(2)
        .with_store(ProfileStore::open(&dir).unwrap());
    let full = exec.run_specs_full(&cluster, &specs, reps, seed);
    assert_eq!(exec.stats().simulated, 4, "bytes-less records re-simulated");
    for (a, b) in cold.iter().zip(&full) {
        assert_eq!(a.rep_times_s, b.rep_times_s, "{:?}", a.spec);
        assert_eq!(
            a.mean_shuffle_bytes.unwrap().to_bits(),
            b.mean_shuffle_bytes.unwrap().to_bits()
        );
        assert_eq!(
            a.mean_hdfs_bytes.unwrap().to_bits(),
            b.mean_hdfs_bytes.unwrap().to_bits()
        );
    }
    exec.flush_store().unwrap();
    drop(exec);

    // The upgrade stuck: a third session finds full records on disk and
    // serves the multi-target path with zero re-simulation.
    let exec = CampaignExecutor::serial()
        .with_store(ProfileStore::open(&dir).unwrap());
    for s in &specs {
        for rep in 0..reps {
            let o = exec
                .store()
                .unwrap()
                .get(&paper_key(fp, s, rep, seed))
                .expect("record survived the upgrade");
            assert!(o.bytes.is_some(), "upgraded in place");
        }
    }
    let warm_full = exec.run_specs_full(&cluster, &specs, reps, seed);
    assert_eq!(exec.stats().simulated, 0, "full path warm after upgrade");
    for (a, b) in full.iter().zip(&warm_full) {
        assert_eq!(a.rep_times_s, b.rep_times_s);
        assert_eq!(
            a.mean_shuffle_bytes.unwrap().to_bits(),
            b.mean_shuffle_bytes.unwrap().to_bits()
        );
    }
    drop(exec);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The store precedence invariant behind the whole migration story,
/// property-tested over arbitrary key and value bit patterns: a
/// bytes-less record never displaces a bytes-carrying one, while the
/// fuller record always upgrades a partial one — in either put order.
#[test]
fn partial_record_never_displaces_a_fuller_one() {
    forall("partial vs full store precedence", 200, |rng| {
        let apps = AppId::all();
        let key = StoreKey {
            cluster: rng.next_u64(),
            app: apps[rng.range_usize(0, apps.len())],
            num_mappers: rng.next_u64() as u32,
            num_reducers: rng.next_u64() as u32,
            input_gb_bits: rng.next_u64(),
            block_mb: rng.next_u64() as u32,
            rep: rng.next_u64() as u32,
            base_seed: rng.next_u64(),
        };
        // Arbitrary bits — NaN payload times, extreme counters — with
        // the partial record either v3-shaped (time+CPU) or v1-shaped
        // (time only).
        let full = RepOutcome::with_bytes(
            f64::from_bits(rng.next_u64()),
            f64::from_bits(rng.next_u64()),
            RepBytes { shuffle: rng.next_u64(), hdfs: rng.next_u64() },
        );
        let partial = if rng.next_u64() % 2 == 0 {
            RepOutcome::full(
                f64::from_bits(rng.next_u64()),
                f64::from_bits(rng.next_u64()),
            )
        } else {
            RepOutcome::time_only(f64::from_bits(rng.next_u64()))
        };

        let store = ProfileStore::memory();
        store.put(key, full);
        store.put(key, partial);
        let got = store.get(&key).expect("record present");
        assert!(got.same_bits(&full), "partial displaced a full record");

        let store = ProfileStore::memory();
        store.put(key, partial);
        store.put(key, full);
        let got = store.get(&key).expect("record present");
        assert!(got.same_bits(&full), "full record upgrades a partial one");
    });
}

/// Guard variable marking the re-spawned child half of the quarantine
/// test (`MRTUNER_FAIL_SPEC` is parsed once per process and cached, so
/// the faulting scenario cannot run inside the shared test process).
const QUARANTINE_CHILD_ENV: &str = "MRTUNER_MT_QUARANTINE_CHILD";

/// A rep that exhausts its retries is quarantined, and the setting's
/// byte-means surface as `None` — null, never silently wrong — while
/// the campaign completes and healthy settings keep their counters.
#[test]
fn quarantined_reps_surface_as_null_byte_means_without_aborting() {
    if std::env::var(QUARANTINE_CHILD_ENV).is_ok() {
        quarantine_child();
        return;
    }
    let out = Command::new(std::env::current_exe().unwrap())
        .args([
            "quarantined_reps_surface_as_null_byte_means_without_aborting",
            "--exact",
            "--nocapture",
            "--test-threads=1",
        ])
        .env(QUARANTINE_CHILD_ENV, "1")
        .env("MRTUNER_FAIL_SPEC", "app=grep,m=11,r=7,rep=1,mode=panic")
        .output()
        .expect("re-spawn test binary");
    assert!(
        out.status.success(),
        "child failed:\n--- stdout ---\n{}\n--- stderr ---\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("MT_QUARANTINE_OK"),
        "child never reached its assertions:\n{}",
        String::from_utf8_lossy(&out.stdout)
    );
}

/// The faulting half: runs in a child process with `MRTUNER_FAIL_SPEC`
/// poisoning rep 1 of grep's (11, 7) setting.
fn quarantine_child() {
    let cluster = Cluster::paper_cluster();
    let specs = [
        ExperimentSpec::new(AppId::Grep, 11, 7),
        ExperimentSpec::new(AppId::Grep, 12, 7),
    ];
    let exec = CampaignExecutor::new(2).with_retry_policy(RetryPolicy {
        max_attempts: 1,
        backoff: Duration::from_millis(0),
    });
    let res = exec.run_specs_full(&cluster, &specs, 2, 21);
    assert_eq!(res.len(), 2, "campaign completed despite the poisoned rep");
    assert_eq!(exec.quarantined(), 1, "exactly the injected rep quarantined");
    // Poisoned setting: NaN time mean, null byte-means.
    assert!(res[0].mean_time_s.is_nan(), "time mean visibly poisoned");
    assert_eq!(res[0].mean_shuffle_bytes, None, "null, never silently wrong");
    assert_eq!(res[0].mean_hdfs_bytes, None);
    // Healthy setting: untouched.
    assert!(res[1].mean_time_s.is_finite());
    assert!(res[1].mean_shuffle_bytes.is_some());
    assert!(res[1].mean_hdfs_bytes.is_some());
    println!("MT_QUARANTINE_OK");
}
