//! Integration tests for the persistent on-disk profile store.
//!
//! Covers the store's contract end to end: bit-exact record codec
//! (property-tested), cross-process warm starts (a second executor and a
//! genuinely separate spawned `mrtuner` process), corruption tolerance,
//! compaction idempotence, and migration of flat pre-shard layouts into
//! the sharded one.

use std::path::PathBuf;
use std::process::Command;

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::coordinator::Trainer;
use mrtuner::mr::RepOutcome;
use mrtuner::profiler::store::{
    decode_record, decode_record_bin, encode_record, encode_record_bin,
    read_file_records, RecordError,
};
use mrtuner::profiler::{
    cluster_fingerprint, CampaignExecutor, ExperimentSpec, ProfileStore,
    StoreKey,
};
use mrtuner::util::bytes::hex_u64;
use mrtuner::util::json::Json;
use mrtuner::util::prop::forall;

/// Unique per-test scratch directory (removed up front so reruns are
/// deterministic even after a crashed run).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_store_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn spec(m: u32, r: u32) -> ExperimentSpec {
    ExperimentSpec::new(AppId::WordCount, m, r)
}

/// The store root plus every `shard-NN/` directory under it.
fn store_dirs(dir: &PathBuf) -> Vec<PathBuf> {
    let mut out = vec![dir.clone()];
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            if name.starts_with("shard-") && e.path().is_dir() {
                out.push(e.path());
            }
        }
    }
    out.sort();
    out
}

/// Every live binary segment, across the root and all shards.
fn seg_files(dir: &PathBuf) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = store_dirs(dir)
        .iter()
        .filter_map(|d| std::fs::read_dir(d).ok())
        .flatten()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            let n = p.file_name().unwrap().to_string_lossy().into_owned();
            n.starts_with("seg-") && n.ends_with(".bin")
        })
        .collect();
    out.sort();
    out
}

/// Every compacted index, across the root and all shards.
fn index_files(dir: &PathBuf) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = store_dirs(dir)
        .iter()
        .map(|d| d.join("index.bin"))
        .filter(|p| p.exists())
        .collect();
    out.sort();
    out
}

#[test]
fn record_codec_round_trips_any_key_and_bits() {
    forall("store record round-trip", 200, |rng| {
        let apps = AppId::all();
        let key = StoreKey {
            cluster: rng.next_u64(),
            app: apps[rng.range_usize(0, apps.len())],
            num_mappers: rng.next_u64() as u32,
            num_reducers: rng.next_u64() as u32,
            input_gb_bits: rng.next_u64(),
            block_mb: rng.next_u64() as u32,
            rep: rng.next_u64() as u32,
            base_seed: rng.next_u64(),
        };
        // Arbitrary bit patterns, including NaNs/infinities/subnormals:
        // the codec must preserve every bit, not just "nice" values —
        // with and without the CPU figure.
        let time_s = f64::from_bits(rng.next_u64());
        let outcome = if rng.next_u64() % 2 == 0 {
            RepOutcome::full(time_s, f64::from_bits(rng.next_u64()))
        } else {
            RepOutcome::time_only(time_s)
        };
        let line = encode_record(&key, &outcome);
        let (k2, o2, ver) = decode_record(&line).expect("round trip");
        assert_eq!(k2, key);
        assert_eq!(ver, 2);
        assert!(o2.same_bits(&outcome));
    });
}

/// The binary v3 codec under the same adversarial population: random
/// `f64` bit patterns (NaNs with payloads, infinities, subnormals) must
/// survive the frame round-trip bit for bit, together with the touch
/// generation the LRU eviction sorts by.
#[test]
fn binary_record_round_trips_any_key_and_bits() {
    forall("binary store record round-trip", 200, |rng| {
        let apps = AppId::all();
        let key = StoreKey {
            cluster: rng.next_u64(),
            app: apps[rng.range_usize(0, apps.len())],
            num_mappers: rng.next_u64() as u32,
            num_reducers: rng.next_u64() as u32,
            input_gb_bits: rng.next_u64(),
            block_mb: rng.next_u64() as u32,
            rep: rng.next_u64() as u32,
            base_seed: rng.next_u64(),
        };
        let time_s = f64::from_bits(rng.next_u64());
        let outcome = if rng.next_u64() % 2 == 0 {
            RepOutcome::full(time_s, f64::from_bits(rng.next_u64()))
        } else {
            RepOutcome::time_only(time_s)
        };
        let touch = rng.next_u64();
        let frame = encode_record_bin(&key, &outcome, touch);
        let (k2, o2, t2, used) =
            decode_record_bin(&frame).expect("binary round trip");
        assert_eq!(k2, key);
        assert_eq!(t2, touch);
        assert_eq!(used, frame.len(), "whole frame consumed");
        assert!(o2.same_bits(&outcome));
    });
}

/// NaN payload bits are the canonical "JSON would destroy this" case:
/// the binary codec must preserve them exactly, and a store round-trip
/// through disk must serve them back bit-identically.
#[test]
fn binary_codec_and_store_preserve_nan_payloads() {
    let quiet_payload = f64::from_bits(0x7FF8_0000_0000_BEEF);
    let signaling = f64::from_bits(0x7FF0_0000_0000_0001);
    let neg_quiet = f64::from_bits(0xFFF8_0000_0000_0001);
    let dir = scratch("nanbits");
    let store = ProfileStore::open(&dir).unwrap();
    for (rep, t) in [quiet_payload, signaling, neg_quiet, f64::NEG_INFINITY]
        .into_iter()
        .enumerate()
    {
        let key = StoreKey {
            cluster: 0xAB,
            app: AppId::Grep,
            num_mappers: 5,
            num_reducers: 5,
            input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
            block_mb: StoreKey::PAPER_BLOCK_MB,
            rep: rep as u32,
            base_seed: 6,
        };
        let outcome = RepOutcome::full(t, t);
        let frame = encode_record_bin(&key, &outcome, 1);
        let (_, o2, _, _) = decode_record_bin(&frame).unwrap();
        assert!(o2.same_bits(&outcome), "codec preserves bits of {t:?}");
        store.put(key, outcome);
    }
    store.flush().unwrap();
    drop(store);
    let store = ProfileStore::open(&dir).unwrap();
    for (rep, t) in [quiet_payload, signaling, neg_quiet, f64::NEG_INFINITY]
        .into_iter()
        .enumerate()
    {
        let got = store
            .get(&StoreKey {
                cluster: 0xAB,
                app: AppId::Grep,
                num_mappers: 5,
                num_reducers: 5,
                input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
                block_mb: StoreKey::PAPER_BLOCK_MB,
                rep: rep as u32,
                base_seed: 6,
            })
            .expect("stored");
        assert_eq!(got.time_s.to_bits(), t.to_bits(), "rep {rep}");
    }
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn version_bump_is_stale_not_corrupt() {
    let key = StoreKey {
        cluster: 1,
        app: AppId::Grep,
        num_mappers: 5,
        num_reducers: 5,
        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
        block_mb: StoreKey::PAPER_BLOCK_MB,
        rep: 0,
        base_seed: 2,
    };
    let line = encode_record(&key, &RepOutcome::full(10.0, 1.0))
        .replace("\"v\":2", "\"v\":3");
    assert_eq!(decode_record(&line), Err(RecordError::StaleVersion(3)));
}

/// A record line exactly as the v1 (PR 2) store wrote it.
fn v1_line(key: &StoreKey, time_s: f64) -> String {
    Json::obj(vec![
        ("v", Json::Num(1.0)),
        ("cluster", Json::Str(hex_u64(key.cluster))),
        ("app", Json::Str(key.app.name().to_string())),
        ("m", Json::Num(key.num_mappers as f64)),
        ("r", Json::Num(key.num_reducers as f64)),
        ("rep", Json::Num(key.rep as f64)),
        ("seed", Json::Str(hex_u64(key.base_seed))),
        ("bits", Json::Str(hex_u64(time_s.to_bits()))),
        ("t", Json::Num(time_s)),
    ])
    .to_string()
}

/// The ISSUE 3 migration criterion end to end: a store written by the v1
/// build keeps answering after the v2 bump — the executor warm-starts
/// from it with **zero** simulations and bit-identical times, and the
/// first compaction rewrites it as v2 without orphaning anything.
#[test]
fn v1_store_warm_starts_v2_executor_without_resimulating() {
    let dir = scratch("v1migrate");
    let cluster = Cluster::paper_cluster();
    let specs = [spec(10, 10), spec(20, 5)];

    // Cold v2 run to learn the authoritative keys and times.
    let cold = {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        exec.run_specs(&cluster, &specs, 2, 11)
    };
    // Rewrite the store as the v1 build would have left it: one flat
    // directory (no shards, no meta) holding v1 lines (no input/block
    // fields, no CPU figure).
    let mut v1_records = Vec::new();
    for path in seg_files(&dir).into_iter().chain(index_files(&dir)) {
        for (key, outcome, _) in read_file_records(&path).unwrap() {
            v1_records.push(v1_line(&key, outcome.time_s));
        }
    }
    assert_eq!(v1_records.len(), 4);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("seg-0000cafe-0000-v1legacy.jsonl"),
        v1_records.join("\n") + "\n",
    )
    .unwrap();

    // A v2 executor over the v1 store: zero simulations, identical bits
    // — and the open migrates the flat layout into the shards.
    let exec = CampaignExecutor::new(4)
        .with_store(ProfileStore::open(&dir).unwrap());
    let st = exec.store().unwrap().stats();
    assert_eq!(st.migrated_lines, 4, "every v1 line migrated");
    assert_eq!(st.stale_lines, 0, "nothing orphaned");
    let warm = exec.run_specs(&cluster, &specs, 2, 11);
    assert_eq!(exec.stats().simulated, 0, "warm from migrated records");
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.rep_times_s, b.rep_times_s);
    }
    drop(exec);
    // Migration rewrote the records as v3 binary inside the shards;
    // nothing JSONL survives anywhere in the tree.
    let mut total = 0;
    for path in seg_files(&dir).into_iter().chain(index_files(&dir)) {
        let recs = read_file_records(&path).unwrap();
        assert!(recs.iter().all(|(_, _, ver)| *ver == 3));
        total += recs.len();
    }
    assert_eq!(total, 4);
    for d in store_dirs(&dir) {
        assert!(
            std::fs::read_dir(&d).unwrap().all(|e| {
                !e.unwrap().file_name().to_string_lossy().ends_with(".jsonl")
            }),
            "no legacy files survive the migration"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn second_executor_on_same_dir_simulates_nothing() {
    let dir = scratch("reuse");
    let cluster = Cluster::paper_cluster();
    let specs = [spec(10, 10), spec(20, 5), spec(35, 30)];

    let cold = {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        let res = exec.run_specs(&cluster, &specs, 2, 11);
        assert_eq!(exec.cache_misses(), 6);
        res
    }; // drop flushes the store and releases the segment lock

    let exec2 = CampaignExecutor::new(4)
        .with_store(ProfileStore::open(&dir).unwrap());
    let warm = exec2.run_specs(&cluster, &specs, 2, 11);
    assert_eq!(exec2.cache_misses(), 0, "fully warm-started from disk");
    assert_eq!(exec2.store_hits(), 6);
    let st = exec2.stats();
    assert_eq!(st.simulated, 0);
    assert_eq!(st.store_entries, 6);
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.rep_times_s, b.rep_times_s, "warm is bit-identical");
        assert_eq!(a.mean_time_s, b.mean_time_s);
    }
    drop(exec2);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ISSUE 2 acceptance criterion: a `fig4` sweep run twice in two
/// separate OS processes with `--store` performs zero simulations on the
/// second run, store-hit count equals rep count, and the output is
/// bit-identical to the cold run.
#[test]
fn fig4_across_two_processes_is_warm_and_bit_identical() {
    let dir = scratch("fig4");
    let csv1 = dir.join("run1.csv");
    let csv2 = dir.join("run2.csv");
    std::fs::create_dir_all(&dir).unwrap();
    let bin = env!("CARGO_BIN_EXE_mrtuner");

    let run = |csv: &PathBuf| {
        let out = Command::new(bin)
            .args([
                "fig4",
                "--app",
                "wordcount",
                "--step",
                "20",
                "--reps",
                "2",
                "--seed",
                "7",
                "--jobs",
                "2",
                "--store",
            ])
            .arg(&dir)
            .arg("--csv")
            .arg(csv)
            .output()
            .expect("spawn mrtuner fig4");
        assert!(
            out.status.success(),
            "fig4 failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stderr).into_owned()
    };

    // step 20 on [5,40] → M,R ∈ {5,25} → 4 settings × 2 reps = 8 reps.
    let err1 = run(&csv1);
    assert!(err1.contains("simulated=8"), "cold run simulates all: {err1}");
    let err2 = run(&csv2);
    assert!(err2.contains("simulated=0"), "warm run simulates none: {err2}");
    assert!(err2.contains("store_hits=8"), "store answers every rep: {err2}");

    let a = std::fs::read(&csv1).unwrap();
    let b = std::fs::read(&csv2).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "warm output bit-identical to cold output");

    // The store subcommand sees the same picture from a third process.
    let stats = Command::new(bin)
        .args(["store", "stats", "--store"])
        .arg(&dir)
        .output()
        .expect("spawn mrtuner store stats");
    assert!(stats.status.success());
    let text = String::from_utf8_lossy(&stats.stdout).into_owned();
    assert!(text.contains("entries=8"), "8 stored reps: {text}");

    let cleared = Command::new(bin)
        .args(["store", "clear", "--store"])
        .arg(&dir)
        .output()
        .expect("spawn mrtuner store clear");
    assert!(cleared.status.success());
    let store = ProfileStore::peek(&dir).unwrap();
    assert!(store.is_empty(), "clear removed every record");
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_segment_recovers_good_lines() {
    let dir = scratch("trunc");
    {
        let store = ProfileStore::open(&dir).unwrap();
        for rep in 0..3 {
            store.put(
                StoreKey {
                    cluster: 9,
                    app: AppId::WordCount,
                    num_mappers: 20,
                    num_reducers: 5,
                    input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
                    block_mb: StoreKey::PAPER_BLOCK_MB,
                    rep,
                    base_seed: 4,
                },
                RepOutcome::full(100.0 + rep as f64, 10.0 + rep as f64),
            );
        }
        store.flush().unwrap();
    }
    // Simulate a crash mid-append: a truncated record at the segment tail.
    let segs = seg_files(&dir);
    assert_eq!(segs.len(), 1);
    let mut bytes = std::fs::read(&segs[0]).unwrap();
    bytes.extend_from_slice(b"{\"v\":1,\"cluster\":\"00");
    std::fs::write(&segs[0], bytes).unwrap();

    // And a wholly unreadable (non-UTF-8) segment alongside it.
    let bogus = dir.join("seg-ffffffff-0000-bogus.jsonl");
    std::fs::write(&bogus, [0xFF, 0xFE, 0x00, 0x80]).unwrap();

    let store = ProfileStore::open(&dir).unwrap();
    let st = store.stats();
    assert_eq!(store.len(), 3, "good lines all recovered");
    assert_eq!(st.corrupt_lines, 1, "truncated tail counted");
    assert_eq!(st.corrupt_segments, 1, "unreadable file counted");
    assert!(
        bogus.exists(),
        "unreadable segment preserved, never deleted"
    );
    // The recovered records are still served.
    let got = store.get(&StoreKey {
        cluster: 9,
        app: AppId::WordCount,
        num_mappers: 20,
        num_reducers: 5,
        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
        block_mb: StoreKey::PAPER_BLOCK_MB,
        rep: 2,
        base_seed: 4,
    });
    assert_eq!(got, Some(RepOutcome::full(102.0, 12.0)));
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compaction_is_idempotent() {
    let dir = scratch("compact");
    // Two separate writing sessions → two segments.  `peek` keeps the
    // second session's open from compacting the first one's segment.
    for session in 0..2u64 {
        let store = ProfileStore::peek(&dir).unwrap();
        store.put(
            StoreKey {
                cluster: 7,
                app: AppId::EximParse,
                num_mappers: 10 + session as u32,
                num_reducers: 10,
                input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
                block_mb: StoreKey::PAPER_BLOCK_MB,
                rep: 0,
                base_seed: 1,
            },
            RepOutcome::full(50.5 + session as f64, 5.5),
        );
        store.flush().unwrap();
    }
    assert_eq!(seg_files(&dir).len(), 2);

    // First compacting open folds both segments into the shard's index.
    // The explicit pass and the open's background thread arbitrate over
    // the same on-disk lock: whichever runs first does the merge, the
    // other finds nothing to do, and the stats record the work exactly
    // once either way.
    {
        let store = ProfileStore::open(&dir).unwrap();
        store.compact_now().unwrap();
        let st = store.stats();
        assert!(st.compacted);
        assert_eq!(st.merged_segments, 2);
        assert_eq!(store.len(), 2);
    }
    assert!(seg_files(&dir).is_empty(), "merged segments deleted");
    let indexes = index_files(&dir);
    assert_eq!(indexes.len(), 1, "both records route to the same shard");
    let index = indexes.into_iter().next().unwrap();
    let first = std::fs::read(&index).unwrap();
    assert!(!first.is_empty());

    // Re-compacting an already-compact store finds no work and changes
    // nothing on disk.
    {
        let store = ProfileStore::open(&dir).unwrap();
        let pass = store.compact_now().unwrap();
        assert!(!pass.compacted, "nothing left to merge");
        assert_eq!(store.len(), 2);
    }
    let second = std::fs::read(&index).unwrap();
    assert_eq!(first, second, "index byte-stable across compactions");

    // Writing the identical records again queues nothing new, so a third
    // open still finds a byte-identical index.
    {
        let store = ProfileStore::open(&dir).unwrap();
        store.put(
            StoreKey {
                cluster: 7,
                app: AppId::EximParse,
                num_mappers: 10,
                num_reducers: 10,
                input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
                block_mb: StoreKey::PAPER_BLOCK_MB,
                rep: 0,
                base_seed: 1,
            },
            RepOutcome::full(50.5, 5.5),
        );
        assert_eq!(store.pending(), 0, "known value not re-queued");
    }
    let third = std::fs::read(&index).unwrap();
    assert_eq!(first, third);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The eviction regression the trainer depends on: a size cap tight
/// enough to force evictions must never drop paper-plane repetitions —
/// they are exactly the records the trainer journal references — so a
/// trainer opened *after* a capped compaction still refits from every
/// rep, while extended-sweep filler is gone.
#[test]
fn eviction_never_drops_trainer_referenced_records() {
    let dir = scratch("evict_trainer");
    let cluster = Cluster::paper_cluster();
    let fp = cluster_fingerprint(&cluster);
    let paper_key = |m: u32, r: u32, rep: u32| StoreKey {
        cluster: fp,
        app: AppId::Grep,
        num_mappers: m,
        num_reducers: r,
        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
        block_mb: StoreKey::PAPER_BLOCK_MB,
        rep,
        base_seed: 9,
    };
    {
        let store = ProfileStore::open(&dir).unwrap();
        // 18 settings x 2 reps of synthetic paper-plane training data
        // (what a profiling campaign would leave for the trainer) ...
        for (i, m) in [5u32, 12, 19, 26, 33, 40].into_iter().enumerate() {
            for (j, r) in [5u32, 22, 40].into_iter().enumerate() {
                for rep in 0..2 {
                    store.put(
                        paper_key(m, r, rep),
                        RepOutcome::full(
                            200.0 + 3.0 * (i as f64) + 2.0 * (j as f64)
                                + rep as f64,
                            50.0,
                        ),
                    );
                }
            }
        }
        // ... drowned in extended-sweep filler that the cap will evict.
        for i in 0..400u32 {
            store.put(
                StoreKey {
                    cluster: fp,
                    app: AppId::WordCount,
                    num_mappers: 5 + (i % 36),
                    num_reducers: 5,
                    input_gb_bits: (2.0 + (i / 36) as f64).to_bits(),
                    block_mb: 128,
                    rep: i,
                    base_seed: 77,
                },
                RepOutcome::full(10.0 + i as f64, 1.0),
            );
        }
        store.flush().unwrap();
    }
    {
        // ~36 paper records (~75 B each) fit in 8 KB; 400 filler do not.
        // Eviction runs inside compaction, so force a synchronous pass.
        let store = ProfileStore::open_capped(&dir, Some(8 * 1024)).unwrap();
        store.compact_now().unwrap();
        let st = store.stats();
        assert!(st.compacted);
        assert!(st.evicted > 300, "filler evicted: {st}");
    }
    // A freshly opened trainer sees every paper-plane rep and refits.
    let mut trainer = Trainer::open(&dir, &cluster).unwrap();
    let report = trainer.poll().unwrap();
    assert_eq!(report.refits.len(), 1, "grep refits from pinned records");
    let refit = &report.refits[0];
    assert_eq!(refit.app, AppId::Grep);
    assert_eq!(refit.model.trained_on, 18, "no setting lost a rep");
    assert!(refit.fit_rmse.is_finite());
    drop(trainer);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `store compact --store-max-mb N` end to end in a spawned process: the
/// rewritten index respects the cap and the CLI reports the evictions.
#[test]
fn store_compact_cli_respects_size_cap() {
    let dir = scratch("cli_cap");
    {
        let store = ProfileStore::open(&dir).unwrap();
        // ~1.6 MB of extended-sweep records (about 80 B each).
        for i in 0..20_000u32 {
            store.put(
                StoreKey {
                    cluster: 1,
                    app: AppId::WordCount,
                    num_mappers: 5 + (i % 36),
                    num_reducers: 5 + (i % 7),
                    input_gb_bits: (1.0 + (i % 13) as f64).to_bits(),
                    block_mb: 256,
                    rep: i,
                    base_seed: 3,
                },
                RepOutcome::full(5.0 + i as f64, 0.5),
            );
        }
        store.flush().unwrap();
    }
    let bin = env!("CARGO_BIN_EXE_mrtuner");
    let out = Command::new(bin)
        .args(["store", "compact", "--store-max-mb", "1", "--store"])
        .arg(&dir)
        .output()
        .expect("spawn mrtuner store compact");
    assert!(
        out.status.success(),
        "compact failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("compacted=true"), "compacted: {text}");
    assert!(
        text.contains("evicted=") && !text.contains("evicted=0 "),
        "evictions reported: {text}"
    );
    let indexes = index_files(&dir);
    assert!(!indexes.is_empty(), "compaction wrote at least one index");
    let total: u64 = indexes
        .iter()
        .map(|p| std::fs::metadata(p).unwrap().len())
        .sum();
    assert!(
        total <= 1024 * 1024,
        "shard indexes fit the 1 MB cap, got {total} B"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
