//! Sharded-store integration tests: per-app shard affinity across real
//! OS processes, and compaction idempotence under the background thread.
//!
//! The scenarios here are the ones the sharding invariant exists for:
//! two campaigns profiling *disjoint* applications share one store
//! without ever touching each other's shard (so neither can contend on
//! the other's segment or compaction locks), and a reader that opened
//! the store before either writer existed sees both after one
//! `refresh()`.

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};

use mrtuner::apps::AppId;
use mrtuner::mr::RepOutcome;
use mrtuner::profiler::store::{
    ProfileStore, StoreKey, StoreOptions, DEFAULT_STORE_SHARDS,
};

/// Unique per-test scratch directory (removed up front so reruns are
/// deterministic even after a crashed run).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_shard_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A paper-plane repetition (8 GB input, 64 MB blocks).
fn plane_key(app: AppId, m: u32, r: u32, rep: u32) -> StoreKey {
    StoreKey {
        cluster: 0xABCD_0123,
        app,
        num_mappers: m,
        num_reducers: r,
        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
        block_mb: StoreKey::PAPER_BLOCK_MB,
        rep,
        base_seed: 11,
    }
}

/// Whether a shard directory holds any store data (segment or index).
fn shard_has_data(dir: &Path, shard: &str) -> bool {
    std::fs::read_dir(dir.join(shard))
        .map(|rd| {
            rd.flatten().any(|e| {
                let name = e.file_name().to_string_lossy().into_owned();
                name.starts_with("seg-") || name == "index.bin"
            })
        })
        .unwrap_or(false)
}

/// Bytes of every shard index, keyed by shard directory name.
fn index_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            let idx = e.path().join("index.bin");
            if name.starts_with("shard-") && idx.is_file() {
                out.push((name, std::fs::read(&idx).unwrap()));
            }
        }
    }
    out.sort();
    out
}

/// The ISSUE 8 concurrency criterion: two spawned `mrtuner` processes
/// profiling disjoint applications write the same store at the same
/// time, each confined to its own shard, and a third session that
/// opened the store *before* either writer sees all of their records
/// after one `refresh()`.
#[test]
fn disjoint_app_campaigns_share_a_store_without_contention() {
    let dir = scratch("disjoint");
    std::fs::create_dir_all(&dir).unwrap();

    // The reader opens first — and fully loads every (empty) shard —
    // so only refresh() can show it records written afterwards.
    let reader = ProfileStore::open_with_opts(
        &dir,
        StoreOptions {
            background_compaction: false,
            ..StoreOptions::default()
        },
    )
    .unwrap();
    assert_eq!(reader.shard_count(), DEFAULT_STORE_SHARDS);
    assert_eq!(reader.generation(), 0, "store starts empty");

    let bin = env!("CARGO_BIN_EXE_mrtuner");
    let spawn = |app: &str, csv: &str| {
        Command::new(bin)
            .args([
                "fig4", "--app", app, "--step", "20", "--reps", "2",
                "--seed", "7", "--jobs", "2", "--store",
            ])
            .arg(&dir)
            .arg("--csv")
            .arg(dir.join(csv))
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .expect("spawn mrtuner fig4")
    };

    // Both writers run concurrently against the same store root.
    let wc = spawn("wordcount", "wc.csv");
    let gr = spawn("grep", "grep.csv");
    let wc = wc.wait_with_output().expect("wait for wordcount run");
    let gr = gr.wait_with_output().expect("wait for grep run");
    for (label, out) in [("wordcount", &wc), ("grep", &gr)] {
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(out.status.success(), "{label} run failed: {err}");
        // step 20 on [5,40] → M,R ∈ {5,25} → 4 settings × 2 reps.
        assert!(err.contains("simulated=8"), "{label} cold run: {err}");
        assert!(
            !err.contains("lock busy"),
            "{label} contended on a lock it should never touch: {err}"
        );
    }

    // Per-app affinity (FNV-1a over the app name, 4 shards): wordcount
    // routes to shard-00 and grep to shard-01 — each writer left data
    // in exactly its own shard, so neither could have contended on the
    // other's segment or compaction locks.
    assert_eq!(DEFAULT_STORE_SHARDS, 4, "affinity map assumes 4 shards");
    assert!(shard_has_data(&dir, "shard-00"), "wordcount → shard-00");
    assert!(shard_has_data(&dir, "shard-01"), "grep → shard-01");
    assert!(
        !shard_has_data(&dir, "shard-02")
            && !shard_has_data(&dir, "shard-03"),
        "shards no writer routed to stay empty"
    );

    // The pre-existing reader catches up with one refresh.
    let fresh = reader.refresh().unwrap();
    assert_eq!(fresh, 16, "refresh surfaces both writers' reps");
    let (records, _) = reader.read_since(0);
    let per_app = |app: AppId| {
        records.iter().filter(|(k, _)| k.app == app).count()
    };
    assert_eq!(per_app(AppId::WordCount), 8);
    assert_eq!(per_app(AppId::Grep), 8);
    assert_eq!(reader.len(), 16);
    drop(reader);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Compaction is idempotent and safe under the background thread: a
/// synchronous `compact_now()` racing the open-time background pass
/// rewrites every shard exactly once (the per-shard `compact.lock`
/// makes the loser skip), reads stay bit-identical throughout, and a
/// later pass over the settled store changes nothing on disk.
#[test]
fn background_compaction_is_idempotent_and_race_safe() {
    let dir = scratch("bgcompact");

    // Session 1: write across all three apps with compaction off, so
    // dropping leaves one fresh segment in every touched shard.
    let mut expect: Vec<(StoreKey, RepOutcome)> = Vec::new();
    {
        let store = ProfileStore::open_with_opts(
            &dir,
            StoreOptions {
                background_compaction: false,
                ..StoreOptions::default()
            },
        )
        .unwrap();
        for (ai, app) in AppId::all().into_iter().enumerate() {
            for rep in 0..5 {
                let k = plane_key(app, 10 + ai as u32, 5, rep);
                let o = RepOutcome::full(
                    50.0 * (ai + 1) as f64 + rep as f64,
                    3.0 + rep as f64,
                );
                store.put(k, o);
                expect.push((k, o));
            }
        }
        store.flush().unwrap();
        assert_eq!(store.pending(), 0, "flush drained every shard");
    }

    // Session 2: background compaction ON, raced by a synchronous
    // compact_now() from this thread.  Whichever pass reaches a shard
    // first rewrites it; the other skips on the busy lock.
    {
        let store = ProfileStore::open(&dir).unwrap();
        let pass = store.compact_now().unwrap();
        assert_eq!(pass.entries, expect.len(), "no records lost: {pass}");
        for (k, o) in &expect {
            let got = store.get(k).expect("record survives the race");
            assert!(got.same_bits(o), "compaction changed stored bits");
        }
    } // drop joins the background thread: compaction fully settled

    // Session 3: one more pass finds nothing to do, and the shard
    // indexes do not change byte-for-byte — idempotence.
    let before = index_bytes(&dir);
    assert!(!before.is_empty(), "compaction produced shard indexes");
    {
        let store = ProfileStore::open_with_opts(
            &dir,
            StoreOptions {
                background_compaction: false,
                ..StoreOptions::default()
            },
        )
        .unwrap();
        let pass = store.compact_now().unwrap();
        assert!(!pass.compacted, "nothing left to compact: {pass}");
        assert_eq!(pass.merged_segments, 0, "no segments remain");
    }
    assert_eq!(
        before,
        index_bytes(&dir),
        "re-compaction is a byte-for-byte no-op"
    );

    // And a fresh read-only session still sees the original bits.
    let store = ProfileStore::peek(&dir).unwrap();
    assert_eq!(store.len(), expect.len());
    for (k, o) in &expect {
        let got = store.get(k).expect("record present after settle");
        assert!(got.same_bits(o), "peek disagrees with written bits");
    }
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}
