//! Backend-agnostic contract tests for [`StoreBackend`].
//!
//! Every assertion here runs against *both* implementations — the
//! persistent [`FileBackend`] and the disk-free [`MemoryBackend`] —
//! through `&dyn StoreBackend`, so the [`ProfileStore`] facade (and the
//! executor, trainer, and DLQ above it) can treat the two
//! interchangeably.  Backend-specific behavior (persistence across
//! reopens, ephemerality) gets its own tests at the bottom.

use std::path::PathBuf;

use mrtuner::apps::AppId;
use mrtuner::mr::RepOutcome;
use mrtuner::profiler::store::{
    FileBackend, MemoryBackend, StoreBackend, StoreKey,
};

/// Unique per-test scratch directory (removed up front so reruns are
/// deterministic even after a crashed run).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_backend_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A repetition on the paper plane (8 GB input, 64 MB blocks): pinned
/// through capped eviction, exactly what the online trainer consumes.
fn paper_key(app: AppId, m: u32, r: u32, rep: u32) -> StoreKey {
    StoreKey {
        cluster: 0xFEED_F00D,
        app,
        num_mappers: m,
        num_reducers: r,
        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
        block_mb: StoreKey::PAPER_BLOCK_MB,
        rep,
        base_seed: 5,
    }
}

/// An off-plane repetition: evictable under a size cap.
fn filler_key(i: u32) -> StoreKey {
    StoreKey {
        cluster: 0xFEED_F00D,
        app: AppId::WordCount,
        num_mappers: 5 + (i % 36),
        num_reducers: 6,
        input_gb_bits: (4.0f64).to_bits(),
        block_mb: 128,
        rep: i,
        base_seed: 5,
    }
}

/// The put/get/journal portion of the contract: journaling is exactly
/// "the generation advanced", CPU-ful records never downgrade, and
/// `read_since` is a resumable upsert log.
fn check_core_contract(backend: &dyn StoreBackend, label: &str) {
    assert!(backend.is_empty(), "{label}: starts empty");
    let k = paper_key(AppId::Grep, 10, 5, 0);
    let partial = RepOutcome::time_only(123.5);
    let full = RepOutcome::full(123.5, 45.25);

    assert!(backend.put(k, partial), "{label}: new key journals");
    assert!(!backend.is_empty(), "{label}: no longer empty");
    assert_eq!(backend.len(), 1, "{label}: one record resident");
    assert_eq!(backend.get(&k), Some(partial), "{label}: get roundtrip");
    assert_eq!(backend.lookup(&k), Some(partial), "{label}: lookup");
    assert!(
        !backend.put(k, partial),
        "{label}: identical re-put only bumps recency"
    );
    assert!(
        backend.put(k, full),
        "{label}: CPU upgrade journals the richer record"
    );
    assert!(
        !backend.put(k, partial),
        "{label}: a CPU-less duplicate never downgrades"
    );
    assert_eq!(backend.get(&k), Some(full), "{label}: upgraded in place");
    assert_eq!(backend.len(), 1, "{label}: still one distinct record");

    // The change journal: an upsert log with a resumable cursor.
    let (all, gen) = backend.read_since(0);
    assert_eq!(gen, backend.generation(), "{label}: cursor == generation");
    assert!(
        all.iter().all(|(key, _)| *key == k),
        "{label}: journal only knows the one key"
    );
    assert!(
        all.iter().all(|(_, o)| o.same_bits(&full)),
        "{label}: every journal entry resolves to the current value"
    );
    let k2 = paper_key(AppId::EximParse, 12, 7, 1);
    assert!(backend.put(k2, RepOutcome::time_only(9.0)));
    let (fresh, gen2) = backend.read_since(gen);
    assert_eq!(fresh.len(), 1, "{label}: cursor resumes after {gen}");
    assert_eq!(fresh[0].0, k2, "{label}: only the new key streams");
    assert!(gen2 > gen, "{label}: generation is monotonic");

    backend.flush().unwrap();
    assert_eq!(backend.pending(), 0, "{label}: flush drains the buffer");
    backend.refresh().unwrap();
    assert_eq!(backend.len(), 2, "{label}: refresh never loses records");
}

/// The capped-compaction portion of the contract: eviction trims to the
/// cap but paper-plane repetitions are pinned, whatever the pressure.
fn check_eviction_contract(backend: &dyn StoreBackend, label: &str) {
    for rep in 0..4 {
        backend.put(
            paper_key(AppId::Grep, 20, 10, rep),
            RepOutcome::full(100.0 + rep as f64, 7.0),
        );
    }
    for i in 0..200 {
        backend.put(filler_key(i), RepOutcome::full(10.0 + i as f64, 1.0));
    }
    backend.flush().unwrap();
    let pass = backend.compact().unwrap();
    assert!(pass.compacted, "{label}: cap pressure forces a rewrite");
    let st = backend.stats();
    assert!(st.evicted > 100, "{label}: filler evicted: {st}");
    assert!(st.bytes <= 2048, "{label}: trimmed under the cap: {st}");
    for rep in 0..4 {
        assert!(
            backend.lookup(&paper_key(AppId::Grep, 20, 10, rep)).is_some(),
            "{label}: paper-plane rep {rep} pinned through eviction"
        );
    }
    let (records, _) = backend.read_since(0);
    assert_eq!(
        records.len(),
        backend.len(),
        "{label}: read_since skips evicted journal keys"
    );
}

#[test]
fn file_backend_honors_core_contract() {
    let dir = scratch("core");
    let backend = FileBackend::new(&dir, None, true);
    check_core_contract(&backend, "file");
    drop(backend);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn memory_backend_honors_core_contract() {
    check_core_contract(&MemoryBackend::new(None), "memory");
}

#[test]
fn file_backend_evicts_to_cap_but_pins_paper_plane() {
    let dir = scratch("evict");
    let backend = FileBackend::new(&dir, Some(2048), true);
    check_eviction_contract(&backend, "file");
    drop(backend);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn memory_backend_evicts_to_cap_but_pins_paper_plane() {
    check_eviction_contract(&MemoryBackend::new(Some(2048)), "memory");
}

/// Odd `f64` bit patterns (NaN payloads, infinities, signed zero,
/// subnormals) survive both backends bit-identically — the property the
/// warm-start guarantee rests on.
#[test]
fn backends_answer_bit_identically() {
    let dir = scratch("bits");
    let file = FileBackend::new(&dir, None, true);
    let mem = MemoryBackend::new(None);
    let weird = [
        f64::from_bits(0x7FF8_0000_0000_BEEF), // NaN with a payload
        f64::NEG_INFINITY,
        -0.0,
        5e-324, // smallest positive subnormal
        123.456,
    ];
    for (i, t) in weird.into_iter().enumerate() {
        let k = paper_key(AppId::Grep, 30, 15, i as u32);
        let o = RepOutcome::full(t, t);
        file.put(k, o);
        mem.put(k, o);
    }
    file.flush().unwrap();
    for (i, t) in weird.into_iter().enumerate() {
        let k = paper_key(AppId::Grep, 30, 15, i as u32);
        let a = file.get(&k).expect("file backend holds the record");
        let b = mem.get(&k).expect("memory backend holds the record");
        assert!(a.same_bits(&b), "rep {i}: backends disagree");
        assert_eq!(a.time_s.to_bits(), t.to_bits(), "rep {i}: exact bits");
    }
    drop(file);

    // And the file backend round-trips those bits through disk.
    let reopened = FileBackend::new(&dir, None, true);
    for (i, t) in weird.into_iter().enumerate() {
        let k = paper_key(AppId::Grep, 30, 15, i as u32);
        let got = reopened.lookup(&k).expect("persisted");
        assert!(
            got.same_bits(&RepOutcome::full(t, t)),
            "rep {i}: disk round-trip changed bits"
        );
    }
    drop(reopened);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Where the backends legitimately differ: `flush` makes the file
/// backend's records durable across instances, while a fresh memory
/// backend always starts empty.
#[test]
fn flush_persists_file_backend_and_memory_is_ephemeral() {
    let dir = scratch("persist");
    let k = paper_key(AppId::WordCount, 8, 4, 0);
    let o = RepOutcome::full(55.5, 5.5);
    {
        let backend = FileBackend::new(&dir, None, true);
        backend.put(k, o);
        backend.flush().unwrap();
    }
    let reopened = FileBackend::new(&dir, None, true);
    assert_eq!(reopened.get(&k), Some(o), "file backend persists");
    drop(reopened);

    let mem = MemoryBackend::new(None);
    mem.put(k, o);
    mem.flush().unwrap();
    drop(mem);
    let fresh = MemoryBackend::new(None);
    assert_eq!(fresh.get(&k), None, "memory backend leaves nothing behind");
    let _ = std::fs::remove_dir_all(&dir);
}
