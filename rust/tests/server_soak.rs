//! Server robustness under sustained and adversarial connections:
//! bounded handle tracking across many short-lived clients, and request
//! framing across read timeouts.
//!
//! These run in CI under a bounded-time profile (pinned test threads,
//! total budget well under a minute) — see `.github/workflows/ci.yml`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mrtuner::coordinator::client::Client;
use mrtuner::coordinator::{
    ModelRegistry, PipelinedClient, PredictionService, Server, ServiceConfig,
};
use mrtuner::model::features::NUM_FEATURES;
use mrtuner::model::regression::{FitBackend, RegressionModel, RustSolverBackend};

fn flat_model(app: &str, base: f64) -> RegressionModel {
    let mut coeffs = [0.0; NUM_FEATURES];
    coeffs[0] = base;
    RegressionModel { app_name: app.into(), coeffs, trained_on: 20 }
}

fn start_service() -> Arc<PredictionService> {
    let mut reg = ModelRegistry::new();
    reg.insert(flat_model("wordcount", 400.0));
    Arc::new(PredictionService::start(
        || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
        reg,
        ServiceConfig::default(),
    ))
}

/// The accept loop used to push every connection handle into a `Vec` it
/// only drained at shutdown — unbounded growth under sustained traffic.
/// Handles are now reaped every accept iteration, so a soak of
/// short-lived connections must leave the tracked set near zero.
#[test]
fn soak_short_lived_connections_keep_handle_count_bounded() {
    let svc = start_service();
    let server = Server::start("127.0.0.1:0", svc).unwrap();
    let addr = server.addr.to_string();

    let rounds = 80;
    for i in 0..rounds {
        let mut c = Client::connect(&addr).unwrap();
        let got = c.predict("wordcount", 5 + (i % 36), 5).unwrap();
        assert!(got.is_finite());
        // Dropping the client closes the connection; its handler thread
        // exits on the next read (EOF or 200 ms timeout).
        drop(c);
        // The tracked set may lag by the handlers still draining their
        // read timeout, but it must stay far below the total opened.
        assert!(
            server.tracked_connections() <= 16,
            "round {i}: {} tracked handles — unbounded growth",
            server.tracked_connections()
        );
    }
    // After the soak, handlers wind down and the reaper empties the set.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let tracked = server.tracked_connections();
        if tracked == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "{tracked} handles still tracked after soak"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// A request written in two halves separated by more than the server's
/// 200 ms read timeout: the timeout lands mid-line, and the old handler
/// cleared its buffer on every loop pass — silently discarding the first
/// half and corrupting the stream framing.  The partial read must
/// survive the timeout.
#[test]
fn request_split_across_read_timeout_is_not_discarded() {
    let svc = start_service();
    let server = Server::start("127.0.0.1:0", Arc::clone(&svc)).unwrap();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let request =
        "{\"op\":\"predict\",\"app\":\"wordcount\",\"mappers\":20,\"reducers\":5}\n";
    let (head, tail) = request.split_at(request.len() / 2);
    writer.write_all(head.as_bytes()).unwrap();
    writer.flush().unwrap();
    // Well past the 200 ms read timeout: the handler sees WouldBlock
    // with half a request buffered.
    std::thread::sleep(Duration::from_millis(350));
    writer.write_all(tail.as_bytes()).unwrap();
    writer.flush().unwrap();

    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "split request dropped: {line}");
    assert!(line.contains("\"predicted_s\":400"), "{line}");

    // Framing is intact: a second, whole request on the same connection
    // gets exactly one well-formed response.
    writer.write_all(request.as_bytes()).unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(line2.contains("\"ok\":true"), "{line2}");

    // And a request split into many tiny writes still parses as one.
    for chunk in request.as_bytes().chunks(7) {
        writer.write_all(chunk).unwrap();
        writer.flush().unwrap();
        std::thread::sleep(Duration::from_millis(30));
    }
    let mut line3 = String::new();
    reader.read_line(&mut line3).unwrap();
    assert!(line3.contains("\"ok\":true"), "{line3}");
}

/// A client streaming bytes with no newline must not grow the handler's
/// buffer without bound (the price of preserving partial reads): past
/// the server's line cap it gets one error reply and a hang-up.
#[test]
fn oversized_request_line_is_rejected_not_buffered_forever() {
    let svc = start_service();
    let server = Server::start("127.0.0.1:0", svc).unwrap();
    let stream = TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // Well past the 64 KB cap, no newline anywhere.  The server hangs
    // up once the cap trips, so a late write error here is expected.
    let blob = vec![b'x'; 128 * 1024];
    let _ = writer.write_all(&blob);
    let _ = writer.flush();

    // The server answers with a protocol error and closes — but the
    // close may race ahead of the reply (TCP reset with unread bytes
    // in flight), so the reply is best-effort; termination is the
    // contract under test.
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) | Err(_) => {} // hang-up won the race
        Ok(_) => assert!(line.contains("too long"), "{line}"),
    }
    // Either way the handler must terminate (bounded buffer, no
    // forever-growing connection): the tracked set drains to zero.
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.tracked_connections() != 0 {
        assert!(
            Instant::now() < deadline,
            "oversize-line handler still alive"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Binary-protocol churn: threads opening/closing pipelined
/// connections (each with its own server-side writer thread) must stay
/// correct and leave the tracked handle set bounded, exactly like the
/// JSON-lines soak.
#[test]
fn soak_binary_pipelined_churn_stays_correct_and_bounded() {
    let svc = start_service();
    let server = Server::start("127.0.0.1:0", svc).unwrap();
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..4u32 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..10u32 {
                let mut c = PipelinedClient::connect(&addr).unwrap();
                let reqs: Vec<(String, u32, u32)> = (0..30u32)
                    .map(|i| ("wordcount".to_string(), 5 + ((t + i) % 36), 5))
                    .collect();
                for r in c.predict_many(&reqs, 8).unwrap() {
                    let p = r.unwrap();
                    assert_eq!(p.seconds, 400.0);
                    assert_eq!(p.version, 1);
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        server.tracked_connections() < 20,
        "{} tracked after binary churn",
        server.tracked_connections()
    );
}

/// Parallel churn: several threads each opening/closing many
/// connections while predicting — the soak test's concurrent cousin,
/// bounding both correctness (every reply right) and handle growth.
#[test]
fn soak_parallel_churn_stays_correct_and_bounded() {
    let svc = start_service();
    let server = Server::start("127.0.0.1:0", svc).unwrap();
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..4u32 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..15u32 {
                let mut c = Client::connect(&addr).unwrap();
                let p = c
                    .predict_versioned("wordcount", 5 + ((t * 15 + i) % 36), 5)
                    .unwrap();
                assert_eq!(p.seconds, 400.0);
                assert_eq!(p.version, 1);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // 60 connections came and went; the tracked set must not have kept
    // them all (4 live at a time + reaping lag is generously < 20).
    assert!(
        server.tracked_connections() < 20,
        "{} tracked after churn",
        server.tracked_connections()
    );
}
