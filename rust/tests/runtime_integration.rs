//! Integration: the PJRT artifact backend must agree with the pure-Rust
//! baseline solver on fits and predictions — this is the contract that
//! lets the coordinator treat the AOT path as a drop-in production
//! backend for the paper's Eqn. 6.
//!
//! Requires `make artifacts`; tests are skipped (with a loud message) if
//! the artifacts have not been built.

use mrtuner::model::features::NUM_FEATURES;
use mrtuner::model::regression::{FitBackend, RustSolverBackend};
use mrtuner::runtime::{artifacts, XlaBackend};
use mrtuner::util::rng::Rng;

fn xla_backend() -> Option<XlaBackend> {
    let dir = artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(XlaBackend::load_default().expect("load artifacts"))
}

fn paper_grid(rng: &mut Rng, n: usize) -> Vec<[f64; 2]> {
    (0..n)
        .map(|_| {
            [
                rng.range_u64(5, 41) as f64,
                rng.range_u64(5, 41) as f64,
            ]
        })
        .collect()
}

fn surface(p: &[f64; 2]) -> f64 {
    let x = p[0] / 40.0;
    let y = p[1] / 40.0;
    420.0 - 260.0 * x + 310.0 * x * x - 120.0 * x * x * x + 28.0 * y + 55.0 * y * y
}

#[test]
fn fit_agrees_with_rust_solver() {
    let Some(mut xla) = xla_backend() else { return };
    let mut rust = RustSolverBackend;
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed);
        let n = rng.range_usize(10, 65);
        let params = paper_grid(&mut rng, n);
        let times: Vec<f64> = params
            .iter()
            .map(|p| surface(p) * rng.lognormal(0.05))
            .collect();
        let w = vec![1.0; n];
        let a = xla.fit(&params, &times, &w).expect("xla fit");
        let b = rust.fit(&params, &times, &w).expect("rust fit");
        for i in 0..NUM_FEATURES {
            let scale = b[i].abs().max(1.0);
            assert!(
                (a[i] - b[i]).abs() / scale < 1e-8,
                "seed {seed} coeff {i}: xla {} vs rust {}",
                a[i],
                b[i]
            );
        }
    }
}

#[test]
fn predict_agrees_with_cpu_evaluation() {
    let Some(mut xla) = xla_backend() else { return };
    let mut rng = Rng::new(77);
    let coeffs: [f64; NUM_FEATURES] =
        std::array::from_fn(|_| rng.range_f64(-300.0, 500.0));
    // Cover: empty batch boundary (1 row), exact batch, multi-chunk.
    for n in [1usize, 63, 64, 65, 200] {
        let params = paper_grid(&mut rng, n);
        let got = xla.predict(&coeffs, &params).expect("xla predict");
        assert_eq!(got.len(), n);
        let mut rust = RustSolverBackend;
        let want = rust.predict(&coeffs, &params).unwrap();
        for i in 0..n {
            assert!(
                (got[i] - want[i]).abs() < 1e-9 * want[i].abs().max(1.0),
                "n={n} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn fit_weights_and_padding_are_exact() {
    let Some(mut xla) = xla_backend() else { return };
    let mut rng = Rng::new(5);
    let params = paper_grid(&mut rng, 12);
    let times: Vec<f64> = params.iter().map(surface).collect();

    // (a) exact-fit property on in-family data
    let w = vec![1.0; 12];
    let coeffs = xla.fit(&params, &times, &w).unwrap();
    let preds = xla.predict(&coeffs, &params).unwrap();
    for (p, t) in preds.iter().zip(&times) {
        assert!((p - t).abs() / t < 1e-5, "{p} vs {t}");
    }

    // (b) weight-5 mean rows == five repetitions (paper's averaging);
    // 12 settings x 5 reps = 60 rows fits the 64-row artifact.
    let mut all_p = Vec::new();
    let mut all_t = Vec::new();
    for p in &params {
        for _ in 0..5 {
            all_p.push(*p);
            all_t.push(surface(p) * rng.lognormal(0.02));
        }
    }
    // means with weight 5
    let means: Vec<f64> = (0..12)
        .map(|i| all_t[5 * i..5 * i + 5].iter().sum::<f64>() / 5.0)
        .collect();
    let a = xla.fit(&all_p, &all_t, &vec![1.0; 60]).unwrap();
    let b = xla.fit(&params, &means, &vec![5.0; 12]).unwrap();
    for i in 0..NUM_FEATURES {
        let scale = a[i].abs().max(1.0);
        assert!((a[i] - b[i]).abs() / scale < 1e-7, "coeff {i}");
    }
}

#[test]
fn fit_rejects_oversized_and_degenerate_inputs() {
    let Some(mut xla) = xla_backend() else { return };
    let rows = xla.runtime.manifest.fit_rows;
    let too_many = vec![[10.0, 10.0]; rows + 1];
    let times = vec![100.0; rows + 1];
    let w = vec![1.0; rows + 1];
    assert!(xla.fit(&too_many, &times, &w).unwrap_err().contains("exceeds"));

    assert!(xla
        .fit(&[[10.0, 10.0]], &[100.0], &[0.0])
        .unwrap_err()
        .contains("all-zero"));

    assert!(xla.fit(&[[10.0, 10.0]], &[100.0, 2.0], &[1.0]).is_err());
}

#[test]
fn runtime_counters_track_executions() {
    let Some(mut xla) = xla_backend() else { return };
    let before_fit = xla.runtime.fit_calls.get();
    let before_pred = xla.runtime.predict_calls.get();
    let params = vec![[20.0, 5.0], [10.0, 10.0], [40.0, 40.0], [5.0, 5.0]];
    let times = vec![500.0, 620.0, 520.0, 760.0];
    let coeffs = xla.fit(&params, &times, &[1.0; 4]).unwrap();
    xla.predict(&coeffs, &vec![[20.0, 5.0]; 130]).unwrap();
    assert_eq!(xla.runtime.fit_calls.get(), before_fit + 1);
    // 130 rows at batch 64 -> 3 chunks.
    assert_eq!(xla.runtime.predict_calls.get(), before_pred + 3);
}
