//! Binary wire protocol v2 for the prediction server.
//!
//! The legacy protocol (v1) is one JSON object per line — simple, but a
//! parse + format per request caps throughput far below the serving
//! target.  Protocol v2 is length-prefixed binary with **pipelining**:
//! a client may keep many requests in flight on one connection, and
//! every response carries the id of the request it answers, so replies
//! need not arrive in submission order.
//!
//! The codec reuses the profile store's v3 idioms (`profiler::store`):
//! an ASCII magic + little-endian version preamble, length-prefixed
//! frames, and raw little-endian bit round-trips for every `u64`/`f64`.
//!
//! ```text
//! preamble (client -> server, once):  "MRTW" u32le_version(=2)
//! frame:    u32le_len | u64le_request_id | u8_tag | body
//!           (len counts everything after itself: 9 + body bytes)
//!
//! request tags                        response tags (high bit set)
//!   0x01 PREDICT  u16le_app_len,        0x80 OK      predict: f64le_seconds,
//!        app_utf8, u32le_mappers,                    u64le_version
//!        u32le_reducers                              json op: utf8 JSON text
//!   0x02 JSON     utf8 JSON text      0x81 ERR     utf8 message (this
//!        (same object as the legacy               request failed; the
//!        line protocol)                           connection lives on)
//!                                     0x82 SHED    empty (admission control
//!                                                  dropped the request)
//!                                     0x83 GOAWAY  utf8 reason; request id
//!                                                  0; the server hangs up
//!                                                  after sending it
//! ```
//!
//! The server autodetects the protocol from the first byte of a
//! connection: `M` (the preamble magic) selects binary, anything else —
//! `{` or whitespace in practice — falls through to the legacy JSON
//! line protocol, so existing clients keep working unchanged.
//!
//! Framing robustness is part of the contract: a decoder must survive
//! arbitrary byte-split delivery (partial frames are kept, never
//! discarded), and must refuse oversize or structurally impossible
//! frames as [`WireError::Corrupt`] rather than desync or panic —
//! property-tested in `rust/tests/wire_protocol.rs`.

use super::service::Prediction;

/// Magic prefix of the binary-protocol preamble (the store uses `MRTS`;
/// the wire uses `MRTW`).
pub const WIRE_MAGIC: [u8; 4] = *b"MRTW";

/// Wire protocol version carried in the preamble.  Version 1 is the
/// (implicit) JSON line protocol; the binary protocol starts at 2.
pub const WIRE_VERSION: u32 = 2;

/// Preamble length: magic + little-endian u32 version.
pub const PREAMBLE_LEN: usize = 8;

/// Frame header past the length prefix: request id + tag byte.
pub const FRAME_HEADER_LEN: usize = 9;

/// Largest frame body+header the codec accepts — same bound as the JSON
/// protocol's line cap, so neither protocol lets a client (or a
/// corrupted peer) grow a connection buffer without bound.
pub const MAX_FRAME_LEN: usize = 64 * 1024;

/// Request: predict one `(app, mappers, reducers)` setting.
pub const REQ_PREDICT: u8 = 0x01;
/// Request: any legacy JSON op (`models`, `model_info`, `retrain`,
/// `health`, even `predict`) tunneled as its JSON object text.
pub const REQ_JSON: u8 = 0x02;
/// Response: success (body depends on the request tag).
pub const RESP_OK: u8 = 0x80;
/// Response: this request failed; body is the error message.  The
/// connection stays usable — errors are isolated per request.
pub const RESP_ERR: u8 = 0x81;
/// Response: admission control shed this request before it reached a
/// worker.  Retry later, ideally with backoff.
pub const RESP_SHED: u8 = 0x82;
/// Response: the server is hanging up; body is the reason.  Carries
/// request id 0 (it answers the connection, not one request).  This is
/// the typed replacement for the silent hang-up the JSON protocol gives
/// an out-of-protocol client.
pub const RESP_GOAWAY: u8 = 0x83;

/// Why a frame (or preamble) failed to decode.
#[derive(Clone, Debug, PartialEq)]
pub enum WireError {
    /// Structurally invalid bytes: bad magic, impossible length,
    /// unknown tag, truncated body.  The stream cannot be trusted past
    /// this point — the peer should GOAWAY/close, not resync.
    Corrupt(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Corrupt(m) => write!(f, "corrupt frame: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One decoded frame: request id, tag byte, raw body.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    /// Client-chosen request id echoed by the response (0 for GOAWAY).
    pub id: u64,
    /// One of the `REQ_*` / `RESP_*` tag constants.
    pub tag: u8,
    /// Tag-specific payload.
    pub body: Vec<u8>,
}

/// Little-endian decode helpers.  Short input zero-pads instead of
/// panicking: every caller length-checks first (frame and body lengths
/// are validated before decoding), so the pad never shows through — it
/// just keeps the hot path free of slice-index panics by construction.
fn u16le(b: &[u8]) -> u16 {
    let mut arr = [0u8; 2];
    for (dst, src) in arr.iter_mut().zip(b) {
        *dst = *src;
    }
    u16::from_le_bytes(arr)
}

fn u32le(b: &[u8]) -> u32 {
    let mut arr = [0u8; 4];
    for (dst, src) in arr.iter_mut().zip(b) {
        *dst = *src;
    }
    u32::from_le_bytes(arr)
}

fn u64le(b: &[u8]) -> u64 {
    let mut arr = [0u8; 8];
    for (dst, src) in arr.iter_mut().zip(b) {
        *dst = *src;
    }
    u64::from_le_bytes(arr)
}

/// Append the connection preamble (`MRTW` + version) to `buf`.
pub fn encode_preamble(buf: &mut Vec<u8>) {
    buf.extend_from_slice(&WIRE_MAGIC);
    buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
}

/// Validate a connection preamble.
pub fn check_preamble(bytes: &[u8; PREAMBLE_LEN]) -> Result<(), WireError> {
    if !bytes.starts_with(&WIRE_MAGIC) {
        return Err(WireError::Corrupt(format!(
            "bad preamble magic {:02x?}",
            bytes.get(..4).unwrap_or_default()
        )));
    }
    let version = u32le(bytes.get(4..).unwrap_or_default());
    if version != WIRE_VERSION {
        return Err(WireError::Corrupt(format!(
            "unsupported wire version {version} (this build speaks \
             {WIRE_VERSION})"
        )));
    }
    Ok(())
}

/// Append one frame (`len | id | tag | body`) to `buf`.
///
/// Panics if the body would exceed [`MAX_FRAME_LEN`] — encoders own
/// their payloads and never legitimately produce one that large.
pub fn encode_frame(buf: &mut Vec<u8>, id: u64, tag: u8, body: &[u8]) {
    let len = FRAME_HEADER_LEN + body.len();
    assert!(len <= MAX_FRAME_LEN, "frame body too large: {} bytes", body.len());
    buf.extend_from_slice(&(len as u32).to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.push(tag);
    buf.extend_from_slice(body);
}

/// Append a PREDICT request frame.
pub fn encode_predict_req(
    buf: &mut Vec<u8>,
    id: u64,
    app: &str,
    mappers: u32,
    reducers: u32,
) {
    let mut body = Vec::with_capacity(2 + app.len() + 8);
    body.extend_from_slice(&(app.len() as u16).to_le_bytes());
    body.extend_from_slice(app.as_bytes());
    body.extend_from_slice(&mappers.to_le_bytes());
    body.extend_from_slice(&reducers.to_le_bytes());
    encode_frame(buf, id, REQ_PREDICT, &body);
}

/// Decode a PREDICT request body into `(app, mappers, reducers)`.
pub fn decode_predict_req(
    body: &[u8],
) -> Result<(String, u32, u32), WireError> {
    if body.len() < 2 {
        return Err(WireError::Corrupt("predict body shorter than app length".into()));
    }
    let app_len = u16le(body) as usize;
    let want = 2 + app_len + 8;
    if body.len() != want {
        return Err(WireError::Corrupt(format!(
            "predict body is {} bytes, expected {want}",
            body.len()
        )));
    }
    let app_bytes = body.get(2..2 + app_len).ok_or_else(|| {
        WireError::Corrupt("predict body shorter than app length".into())
    })?;
    let app = std::str::from_utf8(app_bytes)
        .map_err(|_| WireError::Corrupt("app name is not UTF-8".into()))?
        .to_string();
    let m = u32le(body.get(2 + app_len..).unwrap_or_default());
    let r = u32le(body.get(2 + app_len + 4..).unwrap_or_default());
    Ok((app, m, r))
}

/// Append a JSON-op request frame (`text` is the JSON object the legacy
/// line protocol would have sent, minus the newline).
pub fn encode_json_req(buf: &mut Vec<u8>, id: u64, text: &str) {
    encode_frame(buf, id, REQ_JSON, text.as_bytes());
}

/// Append an OK response to a PREDICT request: raw little-endian bits
/// of the predicted seconds, then the serving model version.
pub fn encode_predict_ok(buf: &mut Vec<u8>, id: u64, p: &Prediction) {
    let mut body = Vec::with_capacity(16);
    body.extend_from_slice(&p.seconds.to_bits().to_le_bytes());
    body.extend_from_slice(&p.version.to_le_bytes());
    encode_frame(buf, id, RESP_OK, &body);
}

/// Decode an OK response to a PREDICT request.
pub fn decode_predict_ok(body: &[u8]) -> Result<Prediction, WireError> {
    if body.len() != 16 {
        return Err(WireError::Corrupt(format!(
            "predict OK body is {} bytes, expected 16",
            body.len()
        )));
    }
    Ok(Prediction {
        seconds: f64::from_bits(u64le(body)),
        version: u64le(body.get(8..).unwrap_or_default()),
    })
}

/// Append an OK response carrying JSON text (answers a JSON-op frame).
pub fn encode_json_ok(buf: &mut Vec<u8>, id: u64, text: &str) {
    encode_frame(buf, id, RESP_OK, text.as_bytes());
}

/// Append a per-request ERR response.
pub fn encode_err(buf: &mut Vec<u8>, id: u64, msg: &str) {
    encode_frame(buf, id, RESP_ERR, msg.as_bytes());
}

/// Append a SHED response (admission control dropped request `id`).
pub fn encode_shed(buf: &mut Vec<u8>, id: u64) {
    encode_frame(buf, id, RESP_SHED, &[]);
}

/// Append a GOAWAY frame (the server hangs up after writing it).
pub fn encode_goaway(buf: &mut Vec<u8>, reason: &str) {
    // Bound the reason so the frame always encodes.
    let msg = reason.as_bytes();
    let take = msg.len().min(MAX_FRAME_LEN - FRAME_HEADER_LEN);
    encode_frame(buf, 0, RESP_GOAWAY, msg.get(..take).unwrap_or(msg));
}

/// Incremental frame decoder: feed bytes as they arrive (in any split),
/// pop complete frames as they become decodable.  Partial frames stay
/// buffered across feeds — byte-split delivery can never desync the
/// stream.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Bytes of `buf` already consumed by popped frames (compacted
    /// lazily so popping is O(frame), not O(buffer)).
    pos: usize,
}

impl FrameReader {
    /// A decoder with an empty buffer.
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Buffer newly received bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        // Compact before growing: keeps the buffer bounded by
        // MAX_FRAME_LEN + one feed's worth of bytes.
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet decoded into a popped frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Pop the next complete frame, if one is fully buffered.
    ///
    /// `Ok(None)` means "need more bytes"; [`WireError::Corrupt`] means
    /// the stream is broken (impossible length or unknown tag) and the
    /// connection should be terminated — there is no resync.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, WireError> {
        let avail = self.buf.get(self.pos..).unwrap_or_default();
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32le(avail) as usize;
        if !(FRAME_HEADER_LEN..=MAX_FRAME_LEN).contains(&len) {
            return Err(WireError::Corrupt(format!(
                "frame length {len} outside [{FRAME_HEADER_LEN}, \
                 {MAX_FRAME_LEN}]"
            )));
        }
        if avail.len() < 4 + len {
            return Ok(None);
        }
        let id = u64le(avail.get(4..12).unwrap_or_default());
        let tag = avail.get(12).copied().unwrap_or(0);
        if !matches!(
            tag,
            REQ_PREDICT | REQ_JSON | RESP_OK | RESP_ERR | RESP_SHED
                | RESP_GOAWAY
        ) {
            return Err(WireError::Corrupt(format!("unknown tag {tag:#04x}")));
        }
        let body = avail
            .get(FRAME_HEADER_LEN + 4..4 + len)
            .unwrap_or_default()
            .to_vec();
        self.pos += 4 + len;
        Ok(Some(Frame { id, tag, body }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_round_trip() {
        let mut buf = Vec::new();
        encode_predict_req(&mut buf, 42, "wordcount", 20, 5);
        let mut fr = FrameReader::new();
        fr.feed(&buf);
        let f = fr.next_frame().unwrap().unwrap();
        assert_eq!(f.id, 42);
        assert_eq!(f.tag, REQ_PREDICT);
        assert_eq!(
            decode_predict_req(&f.body).unwrap(),
            ("wordcount".into(), 20, 5)
        );
        assert!(fr.next_frame().unwrap().is_none());
    }

    #[test]
    fn predict_ok_bits_survive() {
        let p = Prediction { seconds: 512.437_291_8, version: 7 };
        let mut buf = Vec::new();
        encode_predict_ok(&mut buf, 9, &p);
        let mut fr = FrameReader::new();
        fr.feed(&buf);
        let f = fr.next_frame().unwrap().unwrap();
        let got = decode_predict_ok(&f.body).unwrap();
        assert_eq!(got.seconds.to_bits(), p.seconds.to_bits());
        assert_eq!(got.version, 7);
    }

    #[test]
    fn byte_split_feeds_never_desync() {
        let mut buf = Vec::new();
        for i in 0..10u64 {
            encode_predict_req(&mut buf, i, "exim", 10 + i as u32, 5);
        }
        for chunk in [1usize, 2, 3, 7, 13] {
            let mut fr = FrameReader::new();
            let mut ids = Vec::new();
            for piece in buf.chunks(chunk) {
                fr.feed(piece);
                while let Some(f) = fr.next_frame().unwrap() {
                    ids.push(f.id);
                }
            }
            assert_eq!(ids, (0..10).collect::<Vec<_>>(), "chunk {chunk}");
            assert_eq!(fr.pending_bytes(), 0);
        }
    }

    #[test]
    fn oversize_and_tiny_lengths_are_corrupt() {
        for len in [0u32, 1, 8, (MAX_FRAME_LEN + 1) as u32, u32::MAX] {
            let mut fr = FrameReader::new();
            fr.feed(&len.to_le_bytes());
            fr.feed(&[0u8; 16]);
            assert!(
                matches!(fr.next_frame(), Err(WireError::Corrupt(_))),
                "len {len} must be corrupt"
            );
        }
    }

    #[test]
    fn unknown_tag_is_corrupt() {
        let mut buf = Vec::new();
        encode_frame(&mut buf, 1, REQ_PREDICT, &[0, 0, 0, 0, 0, 0, 0, 0, 0, 0]);
        buf[12] = 0x7f; // clobber the tag
        let mut fr = FrameReader::new();
        fr.feed(&buf);
        assert!(matches!(fr.next_frame(), Err(WireError::Corrupt(_))));
    }

    #[test]
    fn preamble_round_trip_and_rejections() {
        let mut buf = Vec::new();
        encode_preamble(&mut buf);
        let arr: [u8; PREAMBLE_LEN] = buf[..].try_into().unwrap();
        check_preamble(&arr).unwrap();
        let mut bad_magic = arr;
        bad_magic[0] = b'X';
        assert!(check_preamble(&bad_magic).is_err());
        let mut bad_version = arr;
        bad_version[4] = 99;
        assert!(check_preamble(&bad_version).is_err());
    }

    #[test]
    fn malformed_predict_bodies_are_corrupt() {
        assert!(decode_predict_req(&[]).is_err());
        assert!(decode_predict_req(&[5, 0]).is_err()); // truncated
        let mut buf = Vec::new();
        encode_predict_req(&mut buf, 1, "grep", 1, 1);
        // Body with one byte chopped off.
        let mut fr = FrameReader::new();
        fr.feed(&buf);
        let f = fr.next_frame().unwrap().unwrap();
        assert!(decode_predict_req(&f.body[..f.body.len() - 1]).is_err());
        assert!(decode_predict_ok(&[1, 2, 3]).is_err());
    }
}
