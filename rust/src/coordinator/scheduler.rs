//! Predicted-time-aware job scheduling — the paper's motivating use case.
//!
//! Given a queue of submitted jobs (each an `(app, M, R)` setting), a
//! FIFO cluster runs them in arrival order; a *smart* scheduler uses the
//! fitted models to order them shortest-predicted-first (SJF), minimizing
//! mean job completion time.  `evaluate_order` replays an order on the
//! simulated cluster to measure the real benefit (the gap between
//! predicted-SJF and oracle-SJF is the cost of prediction error).

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::mr::{run_job, JobConfig};
use crate::util::stats;

/// A job waiting in the submission queue.
#[derive(Clone, Copy, Debug)]
pub struct JobRequest {
    pub app: AppId,
    pub num_mappers: u32,
    pub num_reducers: u32,
    /// Seed for its eventual execution (a distinct wall-clock run).
    pub seed: u64,
}

/// Arrival order (identity permutation).
pub fn fifo_order(jobs: &[JobRequest]) -> Vec<usize> {
    (0..jobs.len()).collect()
}

/// Shortest-predicted-job-first order, using per-app predictions
/// `predict(app, m, r) -> seconds`.  Ties break by arrival order
/// (stable sort), unknown-model jobs go last in arrival order.
pub fn sjf_order<F>(jobs: &[JobRequest], mut predict: F) -> Vec<usize>
where
    F: FnMut(&JobRequest) -> Option<f64>,
{
    let mut keyed: Vec<(usize, Option<f64>)> =
        jobs.iter().enumerate().map(|(i, j)| (i, predict(j))).collect();
    keyed.sort_by(|a, b| match (&a.1, &b.1) {
        (Some(x), Some(y)) => x.partial_cmp(y).unwrap().then(a.0.cmp(&b.0)),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => a.0.cmp(&b.0),
    });
    keyed.into_iter().map(|(i, _)| i).collect()
}

/// Outcome of replaying a schedule on the simulated cluster (jobs run
/// back-to-back, whole-cluster occupancy, as on the paper's testbed).
#[derive(Clone, Debug)]
pub struct ScheduleOutcome {
    /// Completion time of each job in *submission index* order.
    pub completion_s: Vec<f64>,
    pub makespan_s: f64,
    pub mean_completion_s: f64,
}

/// Execute `jobs` in `order` and measure completion times.
pub fn evaluate_order(
    cluster: &Cluster,
    jobs: &[JobRequest],
    order: &[usize],
) -> ScheduleOutcome {
    assert_eq!(jobs.len(), order.len());
    let mut completion = vec![0.0; jobs.len()];
    let mut clock = 0.0;
    for &idx in order {
        let j = &jobs[idx];
        let config = JobConfig::paper_default(j.num_mappers, j.num_reducers)
            .with_seed(j.seed);
        let res = run_job(cluster, &j.app.profile(), &config);
        clock += res.total_time_s;
        completion[idx] = clock;
    }
    ScheduleOutcome {
        makespan_s: clock,
        mean_completion_s: stats::mean(&completion),
        completion_s: completion,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs() -> Vec<JobRequest> {
        // Long (WordCount) first so FIFO is bad for mean completion.
        vec![
            JobRequest { app: AppId::WordCount, num_mappers: 5, num_reducers: 40, seed: 1 },
            JobRequest { app: AppId::Grep, num_mappers: 20, num_reducers: 5, seed: 2 },
            JobRequest { app: AppId::EximParse, num_mappers: 20, num_reducers: 5, seed: 3 },
            JobRequest { app: AppId::WordCount, num_mappers: 20, num_reducers: 5, seed: 4 },
            JobRequest { app: AppId::Grep, num_mappers: 10, num_reducers: 10, seed: 5 },
        ]
    }

    #[test]
    fn fifo_is_identity() {
        assert_eq!(fifo_order(&jobs()), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sjf_sorts_by_prediction() {
        let js = jobs();
        let order = sjf_order(&js, |j| {
            Some(match j.app {
                AppId::WordCount => 500.0,
                AppId::EximParse => 250.0,
                AppId::Grep => 100.0,
            })
        });
        // Greps first (arrival order 1 then 4), exim, then wordcounts.
        assert_eq!(order, vec![1, 4, 2, 0, 3]);
    }

    #[test]
    fn unknown_models_go_last() {
        let js = jobs();
        let order = sjf_order(&js, |j| {
            (j.app != AppId::Grep).then_some(300.0)
        });
        assert_eq!(&order[3..], &[1, 4], "unpredictable jobs last, stable");
    }

    #[test]
    fn sjf_beats_fifo_on_mean_completion() {
        let cluster = Cluster::paper_cluster();
        let js = jobs();
        let fifo = evaluate_order(&cluster, &js, &fifo_order(&js));
        // Oracle SJF (predict with the simulator itself).
        let order = sjf_order(&js, |j| {
            let config = JobConfig::paper_default(j.num_mappers, j.num_reducers)
                .with_seed(j.seed);
            Some(run_job(&cluster, &j.app.profile(), &config).total_time_s)
        });
        let sjf = evaluate_order(&cluster, &js, &order);
        // Makespan identical (same work), mean completion strictly better.
        assert!((sjf.makespan_s - fifo.makespan_s).abs() < 1e-6);
        assert!(
            sjf.mean_completion_s < fifo.mean_completion_s,
            "sjf {} vs fifo {}",
            sjf.mean_completion_s,
            fifo.mean_completion_s
        );
    }

    #[test]
    fn completion_times_indexed_by_submission() {
        let cluster = Cluster::paper_cluster();
        let js = jobs();
        let out = evaluate_order(&cluster, &js, &fifo_order(&js));
        // FIFO: completion times increase in submission order.
        for w in out.completion_s.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert_eq!(out.makespan_s, *out.completion_s.last().unwrap());
    }
}
