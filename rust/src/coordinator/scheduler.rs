//! Predicted-time-aware job scheduling — the paper's motivating use case.
//!
//! Given a queue of submitted jobs (each an `(app, M, R)` setting), a
//! FIFO cluster runs them in arrival order; a *smart* scheduler uses the
//! fitted models to order them shortest-predicted-first (SJF), minimizing
//! mean job completion time.  `evaluate_order` replays an order on the
//! simulated cluster to measure the real benefit (the gap between
//! predicted-SJF and oracle-SJF is the cost of prediction error).

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::mr::{run_job, JobConfig};
use crate::profiler::{CampaignExecutor, ExecutorStats, ExperimentSpec, RepJob};
use crate::util::stats;

/// A job waiting in the submission queue.
#[derive(Clone, Copy, Debug)]
pub struct JobRequest {
    /// Application to run.
    pub app: AppId,
    /// Requested map-task count.
    pub num_mappers: u32,
    /// Requested reduce-task count.
    pub num_reducers: u32,
    /// Seed for its eventual execution (a distinct wall-clock run).
    pub seed: u64,
}

impl JobRequest {
    fn spec(&self) -> ExperimentSpec {
        ExperimentSpec::new(self.app, self.num_mappers, self.num_reducers)
    }

    /// The executor work item for this job's what-if simulation: one rep
    /// of its setting, in a session keyed by the job's own seed.
    fn rep_job(&self) -> RepJob {
        RepJob::paper(self.spec(), 0, self.seed)
    }
}

/// Arrival order (identity permutation).
pub fn fifo_order(jobs: &[JobRequest]) -> Vec<usize> {
    (0..jobs.len()).collect()
}

/// Shortest-predicted-job-first order, using per-app predictions
/// `predict(app, m, r) -> seconds`.  Ties break by arrival order
/// (stable sort), unknown-model jobs go last in arrival order.
///
/// A non-finite prediction (a degenerate fit can produce NaN or infinite
/// coefficients) is treated as unknown-model rather than fed to the
/// comparator — sorting on it used to panic the scheduler.
pub fn sjf_order<F>(jobs: &[JobRequest], predict: F) -> Vec<usize>
where
    F: FnMut(&JobRequest) -> Option<f64>,
{
    let times: Vec<Option<f64>> = jobs.iter().map(predict).collect();
    sjf_order_from_times(&times)
}

/// Shortest-first order from precomputed per-job predictions (submission
/// order; `None` = no model).  Same tie-break and non-finite handling as
/// [`sjf_order`], which delegates here.
pub fn sjf_order_from_times(times: &[Option<f64>]) -> Vec<usize> {
    let mut keyed: Vec<(usize, Option<f64>)> = times
        .iter()
        .enumerate()
        .map(|(i, t)| (i, t.filter(|t| t.is_finite())))
        .collect();
    keyed.sort_by(|a, b| match (&a.1, &b.1) {
        (Some(x), Some(y)) => x.total_cmp(y).then(a.0.cmp(&b.0)),
        (Some(_), None) => std::cmp::Ordering::Less,
        (None, Some(_)) => std::cmp::Ordering::Greater,
        (None, None) => a.0.cmp(&b.0),
    });
    keyed.into_iter().map(|(i, _)| i).collect()
}

/// Predict each job's duration against the **live** serving registry
/// (through the batching service, so a queue costs one coalesced cycle).
/// `None` where the service has no model for the app (or the request
/// failed) — those jobs schedule last, like any unknown-model job.
pub fn predicted_times_live(
    service: &crate::coordinator::PredictionService,
    jobs: &[JobRequest],
) -> Vec<Option<f64>> {
    // Fan the queue out asynchronously first so the batcher can coalesce
    // it, then collect in submission order.
    let rxs: Vec<_> = jobs
        .iter()
        .map(|j| {
            service.predict_async(j.app.name(), j.num_mappers, j.num_reducers)
        })
        .collect();
    rxs.into_iter()
        .map(|rx| match rx {
            Ok(rx) => match rx.recv() {
                Ok(Ok(p)) => Some(p.seconds),
                _ => None,
            },
            Err(_) => None,
        })
        .collect()
}

/// SJF order against the live registry: every re-plan reads the models
/// *currently* installed, so a hot-swapped refit (a new application
/// published, a tightened fit) changes the very next schedule — no
/// restart, no stale plan.
pub fn sjf_order_live(
    service: &crate::coordinator::PredictionService,
    jobs: &[JobRequest],
) -> Vec<usize> {
    sjf_order_from_times(&predicted_times_live(service, jobs))
}

/// Outcome of replaying a schedule on the simulated cluster (jobs run
/// back-to-back, whole-cluster occupancy, as on the paper's testbed).
#[derive(Clone, Debug)]
pub struct ScheduleOutcome {
    /// Completion time of each job in *submission index* order.
    pub completion_s: Vec<f64>,
    /// Time when the last job finishes.
    pub makespan_s: f64,
    /// Mean job completion time (the SJF objective).
    pub mean_completion_s: f64,
}

/// Debug-check that `order` visits every job exactly once — a duplicate
/// or missing index silently corrupts completion times otherwise.
fn debug_assert_permutation(order: &[usize], n: usize) {
    debug_assert_eq!(order.len(), n);
    debug_assert!(
        {
            let mut seen = vec![false; n];
            order
                .iter()
                .all(|&i| i < n && !std::mem::replace(&mut seen[i], true))
        },
        "order must be a permutation of 0..{n}, got {order:?}"
    );
}

/// Prefix-sum `times` along `order` into a [`ScheduleOutcome`] — the one
/// replay rule shared by [`evaluate_order`] and [`what_if`], so the
/// planner and the measurement can never optimize different objectives.
fn replay(times: &[f64], order: &[usize]) -> ScheduleOutcome {
    let mut completion = vec![0.0; times.len()];
    let mut clock = 0.0;
    for &idx in order {
        clock += times[idx];
        completion[idx] = clock;
    }
    ScheduleOutcome {
        makespan_s: clock,
        mean_completion_s: stats::mean(&completion),
        completion_s: completion,
    }
}

/// Execute `jobs` in `order` and measure completion times.  Each job's
/// duration is simulated from its own `seed` (a private layout), exactly
/// as before contexts existed.
pub fn evaluate_order(
    cluster: &Cluster,
    jobs: &[JobRequest],
    order: &[usize],
) -> ScheduleOutcome {
    assert_eq!(jobs.len(), order.len());
    debug_assert_permutation(order, jobs.len());
    let times: Vec<f64> = jobs
        .iter()
        .map(|j| {
            let config = JobConfig::paper_default(j.num_mappers, j.num_reducers)
                .with_seed(j.seed);
            run_job(cluster, &j.app.profile(), &config).total_time_s
        })
        .collect();
    replay(&times, order)
}

/// Simulated duration of each job (submission order), via the profiling
/// executor: durations fan out over its worker pool and are cached, so
/// evaluating many candidate orders costs **one simulation per job,
/// total** — the what-if path the smarter scheduler needs.
pub fn predicted_times(
    executor: &CampaignExecutor,
    cluster: &Cluster,
    jobs: &[JobRequest],
) -> Vec<f64> {
    let items: Vec<RepJob> = jobs.iter().map(|j| j.rep_job()).collect();
    executor.run_reps(cluster, &items)
}

/// Replay a candidate `order` from the executor's cached per-job times
/// (jobs run back-to-back, whole-cluster occupancy).  The first call
/// simulates every job once; every further order for the same queue is
/// pure arithmetic on cache hits.
///
/// Durations come from the executor's *profiling protocol* — session
/// layout plus a `mix`-derived run seed — so they form one internally
/// consistent what-if universe across orders, but they are not the same
/// draws as [`evaluate_order`], which re-simulates each job from its raw
/// `seed` with a private layout.  Use `what_if` to compare candidate
/// orders cheaply; use `evaluate_order` to measure the realized benefit
/// of the order you picked.
pub fn what_if(
    executor: &CampaignExecutor,
    cluster: &Cluster,
    jobs: &[JobRequest],
    order: &[usize],
) -> ScheduleOutcome {
    assert_eq!(jobs.len(), order.len());
    debug_assert_permutation(order, jobs.len());
    replay(&predicted_times(executor, cluster, jobs), order)
}

/// [`what_if`] plus the executor's combined counters — how many of the
/// replayed durations were simulated fresh vs answered from the
/// in-memory cache or the persistent profile store.  Schedulers sharing
/// a store across processes use this to confirm their what-ifs are
/// warm-started rather than silently re-simulating the queue.
pub fn what_if_with_stats(
    executor: &CampaignExecutor,
    cluster: &Cluster,
    jobs: &[JobRequest],
    order: &[usize],
) -> (ScheduleOutcome, ExecutorStats) {
    let outcome = what_if(executor, cluster, jobs, order);
    (outcome, executor.stats())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs() -> Vec<JobRequest> {
        // Long (WordCount) first so FIFO is bad for mean completion.
        vec![
            JobRequest { app: AppId::WordCount, num_mappers: 5, num_reducers: 40, seed: 1 },
            JobRequest { app: AppId::Grep, num_mappers: 20, num_reducers: 5, seed: 2 },
            JobRequest { app: AppId::EximParse, num_mappers: 20, num_reducers: 5, seed: 3 },
            JobRequest { app: AppId::WordCount, num_mappers: 20, num_reducers: 5, seed: 4 },
            JobRequest { app: AppId::Grep, num_mappers: 10, num_reducers: 10, seed: 5 },
        ]
    }

    #[test]
    fn fifo_is_identity() {
        assert_eq!(fifo_order(&jobs()), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sjf_sorts_by_prediction() {
        let js = jobs();
        let order = sjf_order(&js, |j| {
            Some(match j.app {
                AppId::WordCount => 500.0,
                AppId::EximParse => 250.0,
                AppId::Grep => 100.0,
            })
        });
        // Greps first (arrival order 1 then 4), exim, then wordcounts.
        assert_eq!(order, vec![1, 4, 2, 0, 3]);
    }

    #[test]
    fn unknown_models_go_last() {
        let js = jobs();
        let order = sjf_order(&js, |j| {
            (j.app != AppId::Grep).then_some(300.0)
        });
        assert_eq!(&order[3..], &[1, 4], "unpredictable jobs last, stable");
    }

    #[test]
    fn non_finite_predictions_are_unknown_not_a_panic() {
        let js = jobs();
        // A degenerate fit: NaN for Grep, +inf for Exim, finite times for
        // WordCount.  This used to panic in the sort comparator.
        let order = sjf_order(&js, |j| {
            Some(match j.app {
                AppId::WordCount => 300.0,
                AppId::EximParse => f64::INFINITY,
                AppId::Grep => f64::NAN,
            })
        });
        // Finite predictions first (tie → arrival order), the non-finite
        // ones stable-last exactly like unknown models.
        assert_eq!(order, vec![0, 3, 1, 2, 4]);
    }

    #[test]
    fn live_replanning_follows_a_hot_swap() {
        use crate::coordinator::{ModelRegistry, PredictionService, ServiceConfig};
        use crate::model::features::NUM_FEATURES;
        use crate::model::regression::{FitBackend, RegressionModel, RustSolverBackend};

        let flat = |app: &str, base: f64| {
            let mut coeffs = [0.0; NUM_FEATURES];
            coeffs[0] = base;
            RegressionModel { app_name: app.into(), coeffs, trained_on: 20 }
        };
        let mut reg = ModelRegistry::new();
        reg.insert(flat("wordcount", 100.0));
        reg.insert(flat("exim", 200.0));
        let svc = PredictionService::start(
            || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
            reg,
            ServiceConfig::default(),
        );
        let js = jobs();
        // Grep has no model: its jobs (1 and 4) go last; wordcount (100s)
        // sorts before exim (200s).
        let before = sjf_order_live(&svc, &js);
        assert_eq!(&before[3..], &[1, 4], "unknown-model jobs last");
        assert_eq!(before[..3], [0, 3, 2]);
        // Hot-swap: grep appears, wordcount gets much slower.  The very
        // next re-plan reflects both — no restart.
        svc.install_model(flat("grep", 10.0));
        svc.install_model(flat("wordcount", 500.0));
        let after = sjf_order_live(&svc, &js);
        assert_eq!(after, vec![1, 4, 2, 0, 3]);
        // And the times feeding the plan are the live registry's.
        let times = predicted_times_live(&svc, &js);
        assert_eq!(times[1], Some(10.0));
        assert_eq!(times[0], Some(500.0));
    }

    #[test]
    fn sjf_beats_fifo_on_mean_completion() {
        let cluster = Cluster::paper_cluster();
        let js = jobs();
        let fifo = evaluate_order(&cluster, &js, &fifo_order(&js));
        // Oracle SJF (predict with the simulator itself).
        let order = sjf_order(&js, |j| {
            let config = JobConfig::paper_default(j.num_mappers, j.num_reducers)
                .with_seed(j.seed);
            Some(run_job(&cluster, &j.app.profile(), &config).total_time_s)
        });
        let sjf = evaluate_order(&cluster, &js, &order);
        // Makespan identical (same work), mean completion strictly better.
        assert!((sjf.makespan_s - fifo.makespan_s).abs() < 1e-6);
        assert!(
            sjf.mean_completion_s < fifo.mean_completion_s,
            "sjf {} vs fifo {}",
            sjf.mean_completion_s,
            fifo.mean_completion_s
        );
    }

    #[test]
    fn what_if_orders_share_one_simulation_per_job() {
        let cluster = Cluster::paper_cluster();
        let js = jobs();
        let exec = CampaignExecutor::new(2);
        let fifo = what_if(&exec, &cluster, &js, &fifo_order(&js));
        assert_eq!(exec.cache_misses(), js.len() as u64, "one sim per job");
        // SJF from the same cached predictions.
        let times = predicted_times(&exec, &cluster, &js);
        let order = sjf_order(&js, |j| {
            let idx = js
                .iter()
                .position(|k| k.seed == j.seed)
                .expect("job present");
            Some(times[idx])
        });
        let sjf = what_if(&exec, &cluster, &js, &order);
        // No further simulation happened: every replay was a cache hit.
        assert_eq!(exec.cache_misses(), js.len() as u64);
        assert!(exec.cache_hits() >= 2 * js.len() as u64);
        // Same work, same makespan; SJF no worse on mean completion.
        assert!((sjf.makespan_s - fifo.makespan_s).abs() < 1e-9);
        assert!(sjf.mean_completion_s <= fifo.mean_completion_s + 1e-9);
    }

    #[test]
    fn what_if_with_stats_reports_counters() {
        let cluster = Cluster::paper_cluster();
        let js = jobs();
        let exec = CampaignExecutor::new(2);
        let (a, st1) = what_if_with_stats(&exec, &cluster, &js, &fifo_order(&js));
        assert_eq!(st1.simulated, js.len() as u64);
        assert!(!st1.store_attached);
        let (b, st2) = what_if_with_stats(&exec, &cluster, &js, &fifo_order(&js));
        assert_eq!(st2.simulated, js.len() as u64, "replay is pure cache");
        assert!(st2.mem_hits >= js.len() as u64);
        assert_eq!(a.completion_s, b.completion_s);
    }

    #[test]
    fn what_if_is_deterministic_across_executors() {
        let cluster = Cluster::paper_cluster();
        let js = jobs();
        let a = what_if(&CampaignExecutor::serial(), &cluster, &js, &fifo_order(&js));
        let b = what_if(&CampaignExecutor::new(4), &cluster, &js, &fifo_order(&js));
        assert_eq!(a.completion_s, b.completion_s);
    }

    #[test]
    fn completion_times_indexed_by_submission() {
        let cluster = Cluster::paper_cluster();
        let js = jobs();
        let out = evaluate_order(&cluster, &js, &fifo_order(&js));
        // FIFO: completion times increase in submission order.
        for w in out.completion_s.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert_eq!(out.makespan_s, *out.completion_s.last().unwrap());
    }
}
