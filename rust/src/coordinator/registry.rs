//! Fitted-model store: one regression model per application per platform.
//!
//! The paper is explicit that models do not transfer across applications
//! or platforms (§I); the registry therefore keys strictly by application
//! name, and a missing entry is an error rather than a fallback.
//!
//! Entries are **versioned**: every publish bumps a per-application
//! monotonic counter and records fit diagnostics, so the serving layer
//! can hot-swap a refit atomically (under its `RwLock`) while in-flight
//! batches finish on the version they started with and every response
//! names the version that produced it.

use std::collections::BTreeMap;
use std::path::Path;

use crate::model::RegressionModel;
use crate::util::json::{parse, Json};

/// A registered model plus its serving metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelEntry {
    /// The fitted per-application model (carries `trained_on`).
    pub model: RegressionModel,
    /// Per-application version, starting at 1 and bumped by every
    /// publish — strictly monotonic for the registry's lifetime, so
    /// observed versions order refits.
    pub version: u64,
    /// Root-mean-square residual of the fit on its own training rows
    /// (seconds).  `NaN` when unknown, e.g. for models installed without
    /// fit diagnostics.
    pub fit_rmse: f64,
}

impl ModelEntry {
    /// Serialize entry metadata alongside the model fields.  A `NaN`
    /// `fit_rmse` is omitted (hand-rolled JSON has no NaN literal).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("app", Json::Str(self.model.app_name.clone())),
            ("coeffs", Json::from_f64_slice(&self.model.coeffs)),
            ("trained_on", Json::Num(self.model.trained_on as f64)),
            ("version", Json::Num(self.version as f64)),
        ];
        if self.fit_rmse.is_finite() {
            pairs.push(("fit_rmse", Json::Num(self.fit_rmse)));
        }
        Json::obj(pairs)
    }

    /// Rebuild from [`ModelEntry::to_json`] output.  Files written before
    /// entries were versioned load as version 1 with unknown `fit_rmse`.
    pub fn from_json(v: &Json) -> Result<ModelEntry, String> {
        let model = RegressionModel::from_json(v)?;
        let version = match v.get("version") {
            Some(j) => j.as_u64().ok_or("version must be integer")?,
            None => 1,
        };
        let fit_rmse = v
            .get("fit_rmse")
            .and_then(|j| j.as_f64())
            .unwrap_or(f64::NAN);
        Ok(ModelEntry { model, version, fit_rmse })
    }
}

/// Thread-compatible model registry (wrap in `RwLock` for sharing).
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, ModelEntry>,
    /// Last version assigned per application — kept separately from the
    /// live entries so removing an app and publishing it again continues
    /// its version sequence instead of restarting at 1 (clients order
    /// refits by observed version).
    last_versions: BTreeMap<String, u64>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Insert (or replace) the model for its application without fit
    /// diagnostics.  Shorthand for [`ModelRegistry::publish`] with an
    /// unknown RMSE; the entry still gets the next version.
    pub fn insert(&mut self, model: RegressionModel) {
        self.publish(model, f64::NAN);
    }

    /// Publish a (re)fitted model: the entry replaces any predecessor and
    /// carries the next per-application version plus the fit's training
    /// RMSE.  Returns the version assigned.  Versions survive
    /// [`ModelRegistry::remove`]: re-publishing a removed app continues
    /// its sequence.
    pub fn publish(&mut self, model: RegressionModel, fit_rmse: f64) -> u64 {
        let name = model.app_name.clone();
        let version = self.last_versions.get(&name).copied().unwrap_or(0) + 1;
        self.last_versions.insert(name.clone(), version);
        self.models.insert(name, ModelEntry { model, version, fit_rmse });
        version
    }

    /// The model for `app`, if one was uploaded.
    pub fn get(&self, app: &str) -> Option<&RegressionModel> {
        self.models.get(app).map(|e| &e.model)
    }

    /// The full entry (model + version + diagnostics) for `app`.
    pub fn entry(&self, app: &str) -> Option<&ModelEntry> {
        self.models.get(app)
    }

    /// Remove and return the model for `app`.  The app's version counter
    /// is retained, so a later publish continues the sequence.
    pub fn remove(&mut self, app: &str) -> Option<RegressionModel> {
        self.models.remove(app).map(|e| e.model)
    }

    /// Registered application names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// Whether the registry holds no models.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Serialize every entry as a JSON array.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.models.values().map(|e| e.to_json()).collect())
    }

    /// Rebuild a registry from [`ModelRegistry::to_json`] output (or from
    /// a pre-versioning file of bare models, which load as version 1).
    pub fn from_json(v: &Json) -> Result<ModelRegistry, String> {
        let mut reg = ModelRegistry::new();
        for item in v.as_arr().ok_or("registry must be a JSON array")? {
            let entry = ModelEntry::from_json(item)?;
            let name = entry.model.app_name.clone();
            reg.last_versions.insert(name.clone(), entry.version);
            reg.models.insert(name, entry);
        }
        Ok(reg)
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load from a file written by [`ModelRegistry::save`].
    pub fn load(path: &Path) -> Result<ModelRegistry, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        ModelRegistry::from_json(&parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::features::NUM_FEATURES;

    fn model(name: &str) -> RegressionModel {
        RegressionModel {
            app_name: name.into(),
            coeffs: [1.0; NUM_FEATURES],
            trained_on: 20,
        }
    }

    #[test]
    fn insert_get_remove() {
        let mut r = ModelRegistry::new();
        assert!(r.is_empty());
        r.insert(model("wordcount"));
        r.insert(model("exim"));
        assert_eq!(r.len(), 2);
        assert!(r.get("wordcount").is_some());
        assert!(r.get("teragen").is_none());
        assert_eq!(r.names(), vec!["exim", "wordcount"]);
        assert!(r.remove("exim").is_some());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn insert_replaces() {
        let mut r = ModelRegistry::new();
        r.insert(model("wc"));
        let mut m2 = model("wc");
        m2.trained_on = 99;
        r.insert(m2);
        assert_eq!(r.len(), 1);
        assert_eq!(r.get("wc").unwrap().trained_on, 99);
    }

    #[test]
    fn publish_versions_are_monotonic_per_app() {
        let mut r = ModelRegistry::new();
        assert_eq!(r.publish(model("wc"), 1.5), 1);
        assert_eq!(r.publish(model("wc"), 1.25), 2);
        assert_eq!(r.publish(model("grep"), 0.5), 1, "versions are per-app");
        assert_eq!(r.publish(model("wc"), 1.0), 3);
        let e = r.entry("wc").unwrap();
        assert_eq!(e.version, 3);
        assert_eq!(e.fit_rmse, 1.0);
        assert_eq!(e.model.trained_on, 20);
        // `insert` participates in the same version sequence.
        r.insert(model("wc"));
        let e = r.entry("wc").unwrap();
        assert_eq!(e.version, 4);
        assert!(e.fit_rmse.is_nan());
    }

    #[test]
    fn remove_does_not_reset_the_version_sequence() {
        let mut r = ModelRegistry::new();
        assert_eq!(r.publish(model("wc"), 1.0), 1);
        assert_eq!(r.publish(model("wc"), 1.0), 2);
        assert!(r.remove("wc").is_some());
        assert!(r.get("wc").is_none());
        // Re-registering continues the sequence — a client that cached
        // version 2 must never see a fresher model labeled 1.
        assert_eq!(r.publish(model("wc"), 1.0), 3);
        // And the sequence survives a JSON round-trip.
        let mut back = ModelRegistry::from_json(&r.to_json()).unwrap();
        assert_eq!(back.publish(model("wc"), 1.0), 4);
    }

    #[test]
    fn json_round_trip() {
        let mut r = ModelRegistry::new();
        r.publish(model("a"), 2.5);
        r.publish(model("a"), 2.25);
        r.insert(model("b"));
        let back = ModelRegistry::from_json(&r.to_json()).unwrap();
        assert_eq!(back.names(), r.names());
        assert_eq!(back.get("a"), r.get("a"));
        assert_eq!(back.entry("a").unwrap().version, 2);
        assert_eq!(back.entry("a").unwrap().fit_rmse, 2.25);
        assert_eq!(back.entry("b").unwrap().version, 1);
        assert!(back.entry("b").unwrap().fit_rmse.is_nan());
    }

    #[test]
    fn pre_versioning_files_load_as_version_one() {
        // A registry file written before entries carried versions.
        let j = parse(
            r#"[{"app":"wc","coeffs":[1,1,1,1,1,1,1],"trained_on":20}]"#,
        )
        .unwrap();
        let r = ModelRegistry::from_json(&j).unwrap();
        let e = r.entry("wc").unwrap();
        assert_eq!(e.version, 1);
        assert!(e.fit_rmse.is_nan());
    }

    #[test]
    fn file_round_trip() {
        let mut r = ModelRegistry::new();
        r.insert(model("wordcount"));
        let path = std::env::temp_dir().join("mrtuner_test_registry.json");
        r.save(&path).unwrap();
        let back = ModelRegistry::load(&path).unwrap();
        assert_eq!(back.names(), r.names());
        std::fs::remove_file(&path).ok();
    }
}
