//! Fitted-model store: one regression model per application per platform.
//!
//! The paper is explicit that models do not transfer across applications
//! or platforms (§I); the registry therefore keys strictly by application
//! name, and a missing entry is an error rather than a fallback.

use std::collections::BTreeMap;
use std::path::Path;

use crate::model::RegressionModel;
use crate::util::json::{parse, Json};

/// Thread-compatible model registry (wrap in `RwLock` for sharing).
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, RegressionModel>,
}

impl ModelRegistry {
    /// Empty registry.
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Insert (or replace) the model for its application.
    pub fn insert(&mut self, model: RegressionModel) {
        self.models.insert(model.app_name.clone(), model);
    }

    /// The model for `app`, if one was uploaded.
    pub fn get(&self, app: &str) -> Option<&RegressionModel> {
        self.models.get(app)
    }

    /// Remove and return the model for `app`.
    pub fn remove(&mut self, app: &str) -> Option<RegressionModel> {
        self.models.remove(app)
    }

    /// Registered application names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// Whether the registry holds no models.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Serialize every model as a JSON array.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.models.values().map(|m| m.to_json()).collect())
    }

    /// Rebuild a registry from [`ModelRegistry::to_json`] output.
    pub fn from_json(v: &Json) -> Result<ModelRegistry, String> {
        let mut reg = ModelRegistry::new();
        for item in v.as_arr().ok_or("registry must be a JSON array")? {
            reg.insert(RegressionModel::from_json(item)?);
        }
        Ok(reg)
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load from a file written by [`ModelRegistry::save`].
    pub fn load(path: &Path) -> Result<ModelRegistry, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        ModelRegistry::from_json(&parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::features::NUM_FEATURES;

    fn model(name: &str) -> RegressionModel {
        RegressionModel {
            app_name: name.into(),
            coeffs: [1.0; NUM_FEATURES],
            trained_on: 20,
        }
    }

    #[test]
    fn insert_get_remove() {
        let mut r = ModelRegistry::new();
        assert!(r.is_empty());
        r.insert(model("wordcount"));
        r.insert(model("exim"));
        assert_eq!(r.len(), 2);
        assert!(r.get("wordcount").is_some());
        assert!(r.get("sort").is_none());
        assert_eq!(r.names(), vec!["exim", "wordcount"]);
        assert!(r.remove("exim").is_some());
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn insert_replaces() {
        let mut r = ModelRegistry::new();
        r.insert(model("wc"));
        let mut m2 = model("wc");
        m2.trained_on = 99;
        r.insert(m2);
        assert_eq!(r.len(), 1);
        assert_eq!(r.get("wc").unwrap().trained_on, 99);
    }

    #[test]
    fn json_round_trip() {
        let mut r = ModelRegistry::new();
        r.insert(model("a"));
        r.insert(model("b"));
        let back = ModelRegistry::from_json(&r.to_json()).unwrap();
        assert_eq!(back.names(), r.names());
        assert_eq!(back.get("a"), r.get("a"));
    }

    #[test]
    fn file_round_trip() {
        let mut r = ModelRegistry::new();
        r.insert(model("wordcount"));
        let path = std::env::temp_dir().join("mrtuner_test_registry.json");
        r.save(&path).unwrap();
        let back = ModelRegistry::load(&path).unwrap();
        assert_eq!(back.names(), r.names());
        std::fs::remove_file(&path).ok();
    }
}
