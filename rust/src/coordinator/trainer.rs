//! Online retraining: close the profile → model loop.
//!
//! The paper's pipeline is profile → model → predict (Fig. 2), and the
//! authors' companion work on CPU-usage prediction (arXiv:1203.4054,
//! refined in arXiv:1303.3632) observes that these linear models are
//! cheap to refit as new profiling samples arrive.  This module acts on
//! that: a [`Trainer`] *tails* the persistent
//! [`ProfileStore`](crate::profiler::ProfileStore) — re-scanning the
//! store directory for records appended by other sessions and reading
//! its own journal since the last generation — folds fresh paper-plane
//! repetitions into per-application training state, refits through the
//! incremental [`FitAccumulator`], and publishes each refit as a new
//! **versioned** model into the serving registry
//! ([`PredictionService::publish_model`], an atomic hot-swap under the
//! registry's `RwLock`).
//!
//! A server started against a warm store therefore serves every
//! application the store has ever profiled, and picks up newly profiled
//! applications (and tightened fits of old ones) on the next retrain —
//! without restart.
//!
//! **Exactness:** a refit is not an approximation.  Per setting the
//! trainer keeps every rep outcome (keyed `(session, rep)`, so means are
//! computed over a deterministic order), and the accumulator path is
//! bit-identical to a from-scratch
//! [`RegressionModel::fit_dataset`] over the same per-setting mean rows
//! in the same (sorted) order — asserted end-to-end in
//! `rust/tests/trainer_loop.rs`.
//!
//! **Multi-target:** the trainer tails the store *once* and fits one
//! regression per [`Target`] — total time (the source paper), total CPU
//! seconds (arXiv 1203.4054), shuffle bytes (arXiv 1206.2016) — through
//! the same accumulator, publishing a versioned model **set** per app.
//! The time model keeps the plain app name, so legacy single-target
//! clients keep resolving the identical registry entry bit-identically;
//! the others publish under `app@target` names.  Reps migrated from
//! older store formats lack some figures; a target's fit uses exactly
//! the reps that carry its value, and is skipped (not failed) while too
//! few settings do.

use std::collections::BTreeMap;
use std::path::Path;

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::model::features::{evaluate, NUM_FEATURES};
use crate::model::regression::{FitAccumulator, RegressionModel};
use crate::model::Target;
use crate::mr::RepOutcome;
use crate::profiler::{cluster_fingerprint, ProfileStore, StoreKey};

use super::service::PredictionService;

/// Per-application training state: every paper-plane repetition seen so
/// far, grouped by setting.  Rep outcomes key by `(session seed, rep)`
/// so iteration order — and therefore every mean — is deterministic
/// whatever order records arrived in.
#[derive(Clone, Debug, Default)]
struct AppState {
    /// `(M, R)` → `(base_seed, rep)` → observed rep outcome.
    reps: BTreeMap<(u32, u32), BTreeMap<(u64, u32), RepOutcome>>,
    /// Whether new reps arrived since the last successful refit.
    dirty: bool,
}

/// One refit produced by a [`Trainer::poll`].
#[derive(Clone, Debug)]
pub struct Refit {
    /// Application the model was refit for.
    pub app: AppId,
    /// Modeled output this regression fits.
    pub target: Target,
    /// The freshly fitted model (`trained_on` = distinct settings;
    /// `app_name` = the target-qualified registry name).
    pub model: RegressionModel,
    /// Root-mean-square residual on the training rows, in the target's
    /// unit (seconds or bytes).
    pub fit_rmse: f64,
}

/// Everything one [`Trainer::poll`] learned and produced.
#[derive(Clone, Debug, Default)]
pub struct TrainerReport {
    /// Store records newly discovered by this poll (all clusters/planes,
    /// before filtering).
    pub new_records: u64,
    /// Refits ready to publish, in application order.
    pub refits: Vec<Refit>,
    /// Store generation after the poll (diagnostics).
    pub generation: u64,
}

/// Summary of a [`Trainer::retrain`]: the poll plus what was published.
#[derive(Clone, Debug, Default)]
pub struct RetrainSummary {
    /// Store records newly discovered by the poll.
    pub new_records: u64,
    /// `(model name, assigned version)` for every hot-swapped refit —
    /// the plain app name for the time model, `app@target` otherwise.
    pub published: Vec<(String, u64)>,
}

/// The trainer: profile-store tailer + incremental refitter.
///
/// Synchronous by design — [`Trainer::poll`] does one bounded unit of
/// work — so the serving layer decides the cadence: the CLI's
/// `serve --retrain-every N` drives it from a background thread, and the
/// server's `retrain` op drives it on demand.  Wrap in a `Mutex` to
/// share between the two.
///
/// The trainer is format-agnostic: `refresh`/`read_since` fold in
/// whatever other sessions flushed — binary v3 segments and legacy JSONL
/// alike — and paper-plane reps are *pinned* against the store's
/// size-capped eviction precisely so a tailing trainer never loses
/// training data between two polls.
///
/// ```
/// use mrtuner::cluster::Cluster;
/// use mrtuner::coordinator::Trainer;
///
/// let dir = std::env::temp_dir()
///     .join(format!("mrtuner_doc_trainer_{}", std::process::id()));
/// let _ = std::fs::remove_dir_all(&dir);
/// let cluster = Cluster::paper_cluster();
/// let mut trainer = Trainer::open(&dir, &cluster).unwrap();
/// // An empty store: nothing to ingest, nothing to refit — the loop is
/// // driven entirely by what profiling campaigns append later.
/// let report = trainer.poll().unwrap();
/// assert_eq!(report.new_records, 0);
/// assert!(report.refits.is_empty());
/// # drop(trainer);
/// # let _ = std::fs::remove_dir_all(&dir);
/// ```
pub struct Trainer {
    store: ProfileStore,
    cluster_fp: u64,
    generation: u64,
    min_settings: usize,
    apps: BTreeMap<AppId, AppState>,
}

impl Trainer {
    /// Trainer over an already-open store, training models for `cluster`
    /// (records keyed under any other cluster fingerprint are ignored —
    /// the paper's models do not transfer across platforms, §I).
    pub fn new(store: ProfileStore, cluster: &Cluster) -> Trainer {
        Trainer {
            store,
            cluster_fp: cluster_fingerprint(cluster),
            generation: 0,
            // A cubic per-parameter basis has NUM_FEATURES unknowns;
            // refuse to publish fits with fewer distinct settings.
            min_settings: NUM_FEATURES,
            apps: BTreeMap::new(),
        }
    }

    /// Open the store at `dir` (without compacting — the trainer is a
    /// reader; profiling sessions own compaction) and build a trainer
    /// over it.
    pub fn open(dir: &Path, cluster: &Cluster) -> Result<Trainer, String> {
        Ok(Trainer::new(ProfileStore::peek(dir)?, cluster))
    }

    /// Minimum distinct settings before an application is fit at all.
    pub fn min_settings(&self) -> usize {
        self.min_settings
    }

    /// Store generation the trainer has ingested up to.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Shards behind the trainer's profile store (surfaced by the serve
    /// health endpoint).
    pub fn store_shards(&self) -> usize {
        self.store.shard_count()
    }

    /// One tail-and-refit cycle: re-scan the store directory for records
    /// other sessions appended, ingest everything past the trainer's
    /// cursor, and refit every application that gained data.  Returns
    /// the refits *without* publishing them (that is
    /// [`Trainer::retrain`]), so the core loop is testable against a
    /// bare store.
    pub fn poll(&mut self) -> Result<TrainerReport, String> {
        self.store.refresh()?;
        let (fresh, generation) = self.store.read_since(self.generation);
        self.generation = generation;
        let mut new_records = 0u64;
        for (key, outcome) in fresh {
            new_records += 1;
            if !self.wanted(&key) {
                continue;
            }
            let state = self.apps.entry(key.app).or_default();
            // Plain insert: a record upgraded in place (CPU or byte
            // figures filled in by a re-simulation) reappears in the
            // journal and overwrites its partial predecessor here.
            state
                .reps
                .entry((key.num_mappers, key.num_reducers))
                .or_default()
                .insert((key.base_seed, key.rep), outcome);
            state.dirty = true;
        }
        let mut refits = Vec::new();
        for (app, state) in &mut self.apps {
            if !state.dirty || state.reps.len() < self.min_settings {
                continue;
            }
            let mut clean = true;
            for target in Target::all() {
                match fit_app(*app, target, state, self.min_settings) {
                    Ok(Some(refit)) => refits.push(refit),
                    // Too few settings carry this target's value (e.g. a
                    // pure pre-v4 store has no byte counters): skip, and
                    // don't hold the app dirty over it.
                    Ok(None) => {}
                    // A degenerate system for one target must not stall
                    // the loop for the others; leave the app dirty so
                    // the next poll (with more data) retries.
                    Err(e) => {
                        clean = false;
                        eprintln!(
                            "trainer: refit of {} ({target}) skipped: {e}",
                            app.name()
                        );
                    }
                }
            }
            if clean {
                state.dirty = false;
            }
        }
        Ok(TrainerReport { new_records, refits, generation })
    }

    /// Poll once and hot-swap every refit into `service` as a new model
    /// version.  The swap is atomic per application: requests already
    /// batched against the old coefficients finish on the old version,
    /// later ones see the new.
    pub fn retrain(
        &mut self,
        service: &PredictionService,
    ) -> Result<RetrainSummary, String> {
        let report = self.poll()?;
        let mut published = Vec::new();
        for refit in report.refits {
            let name = refit.model.app_name.clone();
            let version = service.publish_model(refit.model, refit.fit_rmse);
            published.push((name, version));
        }
        Ok(RetrainSummary { new_records: report.new_records, published })
    }

    /// Whether a store record feeds this trainer: right cluster, and on
    /// the paper plane (the 2-parameter model's home; extended-sweep
    /// records model different inputs and would bias the fit).
    fn wanted(&self, key: &StoreKey) -> bool {
        key.cluster == self.cluster_fp
            && key.input_gb_bits == StoreKey::PAPER_INPUT_GB.to_bits()
            && key.block_mb == StoreKey::PAPER_BLOCK_MB
    }
}

/// Fit one `(application, target)` regression from the retained
/// per-setting reps: per-setting mean rows in sorted `(M, R)` order
/// through the rank-1 accumulator — the order and arithmetic a
/// from-scratch [`RegressionModel::fit_dataset`] over the same rows
/// would use, so the result is bit-identical to it.  For `TimeS` (every
/// rep carries a time) that makes the fit bit-identical to the pre-
/// multi-target trainer's.
///
/// A setting contributes a row when at least one of its reps carries the
/// target's value (the mean is over exactly those reps); returns
/// `Ok(None)` when fewer than `min_settings` settings do.
fn fit_app(
    app: AppId,
    target: Target,
    state: &AppState,
    min_settings: usize,
) -> Result<Option<Refit>, String> {
    let mut acc = FitAccumulator::new();
    let mut params = Vec::with_capacity(state.reps.len());
    let mut means = Vec::with_capacity(state.reps.len());
    for (&(m, r), reps) in &state.reps {
        let values: Vec<f64> =
            reps.values().filter_map(|o| target.value(o)).collect();
        if values.is_empty() {
            continue;
        }
        let mean = crate::util::stats::mean(&values);
        let row = [m as f64, r as f64];
        acc.add_row(&row, mean, 1.0);
        params.push(row);
        means.push(mean);
    }
    if means.len() < min_settings {
        return Ok(None);
    }
    let coeffs = acc.solve()?;
    let mut sq = 0.0;
    for (p, &t) in params.iter().zip(&means) {
        let e = evaluate(&coeffs, p) - t;
        sq += e * e;
    }
    let fit_rmse = (sq / means.len() as f64).sqrt();
    Ok(Some(Refit {
        app,
        target,
        model: RegressionModel {
            app_name: target.qualified(app.name()),
            coeffs,
            trained_on: means.len(),
        },
        fit_rmse,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::regression::RustSolverBackend;
    use crate::profiler::{CampaignExecutor, Dataset, ExperimentSpec};

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_trainer_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// Settings spanning enough of the grid to identify the cubic.
    fn settings(app: AppId) -> Vec<ExperimentSpec> {
        let mut out = Vec::new();
        for m in [5u32, 12, 19, 26, 33, 40] {
            for r in [5u32, 22, 40] {
                out.push(ExperimentSpec::new(app, m, r));
            }
        }
        out
    }

    #[test]
    fn poll_fits_store_contents_and_tracks_generation() {
        let dir = tmp_dir("poll");
        let cluster = Cluster::paper_cluster();
        {
            let exec = CampaignExecutor::new(2)
                .with_store(ProfileStore::open(&dir).unwrap());
            exec.run_specs(&cluster, &settings(AppId::WordCount), 2, 11);
        }
        let mut trainer = Trainer::open(&dir, &cluster).unwrap();
        let report = trainer.poll().unwrap();
        assert_eq!(report.new_records, 36, "18 settings x 2 reps");
        // Fresh simulations carry every figure: one refit per target.
        assert_eq!(report.refits.len(), 3);
        let targets: Vec<Target> =
            report.refits.iter().map(|r| r.target).collect();
        assert_eq!(targets, Target::all().to_vec());
        for refit in &report.refits {
            assert_eq!(refit.app, AppId::WordCount);
            assert_eq!(refit.model.trained_on, 18);
            assert!(refit.fit_rmse.is_finite());
            assert_eq!(
                refit.model.app_name,
                refit.target.qualified("wordcount")
            );
        }
        assert_eq!(report.refits[0].model.app_name, "wordcount");
        // Nothing new: the next poll is a no-op.
        let again = trainer.poll().unwrap();
        assert_eq!(again.new_records, 0);
        assert!(again.refits.is_empty());
        drop(trainer);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refit_matches_from_scratch_fit_dataset_exactly() {
        let dir = tmp_dir("exact");
        let cluster = Cluster::paper_cluster();
        let specs = settings(AppId::Grep);
        let results = {
            let exec = CampaignExecutor::new(2)
                .with_store(ProfileStore::open(&dir).unwrap());
            exec.run_specs(&cluster, &specs, 3, 7)
        };
        // From-scratch fit over the same reps: per-setting mean rows in
        // sorted (M, R) order — exactly the trainer's construction.
        let mut rows: Vec<(ExperimentSpec, f64)> = results
            .iter()
            .map(|r| (r.spec, r.mean_time_s))
            .collect();
        rows.sort_by_key(|(s, _)| (s.num_mappers, s.num_reducers));
        let mut ds = Dataset {
            app_name: "grep".into(),
            params: Vec::new(),
            times: Vec::new(),
        };
        for (spec, mean) in &rows {
            ds.push(spec, *mean);
        }
        let scratch =
            RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).unwrap();

        let mut trainer = Trainer::open(&dir, &cluster).unwrap();
        let report = trainer.poll().unwrap();
        let refit = &report.refits[0];
        assert_eq!(refit.target, Target::TimeS, "time model fits first");
        for i in 0..NUM_FEATURES {
            assert!(
                (refit.model.coeffs[i] - scratch.coeffs[i]).abs() < 1e-9,
                "coeff {i}: {} vs {}",
                refit.model.coeffs[i],
                scratch.coeffs[i]
            );
        }
        drop(trainer);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn too_few_settings_do_not_publish_a_model() {
        let dir = tmp_dir("thin");
        let cluster = Cluster::paper_cluster();
        {
            let exec = CampaignExecutor::serial()
                .with_store(ProfileStore::open(&dir).unwrap());
            // Three settings < NUM_FEATURES: not identifiable.
            let specs: Vec<ExperimentSpec> = settings(AppId::WordCount)
                .into_iter()
                .take(3)
                .collect();
            exec.run_specs(&cluster, &specs, 2, 11);
        }
        let mut trainer = Trainer::open(&dir, &cluster).unwrap();
        let report = trainer.poll().unwrap();
        assert_eq!(report.new_records, 6);
        assert!(report.refits.is_empty(), "below min_settings");
        drop(trainer);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn records_from_other_clusters_are_ignored() {
        let dir = tmp_dir("cluster");
        let cluster = Cluster::paper_cluster();
        let mut other = Cluster::paper_cluster();
        for n in &mut other.nodes {
            n.spec.map_slots += 2;
        }
        {
            let exec = CampaignExecutor::serial()
                .with_store(ProfileStore::open(&dir).unwrap());
            exec.run_specs(&other, &settings(AppId::WordCount), 1, 11);
        }
        let mut trainer = Trainer::open(&dir, &cluster).unwrap();
        let report = trainer.poll().unwrap();
        assert_eq!(report.new_records, 18, "seen in the journal");
        assert!(report.refits.is_empty(), "but trained on none of them");
        drop(trainer);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
