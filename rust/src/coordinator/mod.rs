//! The serving coordinator: the paper's pipeline as an always-on service.
//!
//! The paper motivates its model with smarter cluster scheduling: "The
//! answers ... can be applied to efficient managing of incoming jobs to a
//! cluster/cloud by making scheduler smarter" (§III).  This module builds
//! that system:
//!
//! * [`registry`] — fitted per-application models (Fig. 2b "upload φ_i's
//!   individual model");
//! * [`service`] — a threaded prediction service with **dynamic request
//!   batching**: concurrent predictions coalesce into single PJRT
//!   executions of the predict artifact (fixed 64-row batches);
//! * [`server`] / [`client`] / [`wire`] — a TCP serving surface with
//!   two protocols behind first-byte autodetection: the legacy
//!   line-delimited JSON protocol, and a pipelined length-prefixed
//!   binary protocol whose predict frames are micro-batched through a
//!   bounded queue with load shedding;
//! * [`scheduler`] — a predicted-time-aware (SJF) job scheduler evaluated
//!   against FIFO on the simulated cluster;
//! * [`trainer`] — online retraining: tails the persistent profile
//!   store, refits incrementally, and hot-swaps versioned models into
//!   the live registry (the profile → model loop, closed).
//!
//! Rust owns the event loop and process lifecycle; Python never runs here.

pub mod client;
pub mod registry;
pub mod scheduler;
pub mod server;
pub mod service;
pub mod trainer;
pub mod wire;

pub use registry::{ModelEntry, ModelRegistry};
pub use scheduler::{
    evaluate_order, fifo_order, predicted_times, predicted_times_live,
    sjf_order, sjf_order_from_times, sjf_order_live, what_if,
    what_if_with_stats, JobRequest,
};
pub use client::{Client, ClientError, PipelinedClient};
pub use server::{Server, ServeOptions};
pub use service::{
    BatchItem, Prediction, PredictionService, ServiceConfig, ServiceMetrics,
};
pub use trainer::{Refit, RetrainSummary, Trainer, TrainerReport};
