//! Blocking TCP client for the prediction server.
//!
//! Failures are **typed** ([`ClientError`]): a transport failure, a
//! server-reported error, and a malformed reply are different bugs with
//! different fixes, and the old stringly-typed path (worse, its
//! `unwrap_or(0.0)` on missing fields) let a truncated reply read as "0
//! seconds predicted".  Every field the client consumes is now required
//! and validated.

use std::collections::HashMap;
use std::fmt;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::json::{parse, Json};

use super::service::Prediction;
use super::wire;

/// Why a client call failed.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientError {
    /// Transport failure: connect, write, read, or connection closed.
    Io(String),
    /// The server answered `ok:false` with this message (protocol-level
    /// error: unknown app, bad request, retrain failure ...).
    Server(String),
    /// The server's reply was syntactically or structurally invalid — a
    /// truncated line, missing field, or non-finite number.  These used
    /// to be silently mapped to `0.0`.
    Malformed(String),
    /// The server hung up deliberately with a GOAWAY frame carrying this
    /// reason (binary protocol only).  Distinguishes a server-initiated
    /// protocol hang-up from transport loss — the JSON protocol's
    /// oversize-line hang-up could only surface as an ambiguous
    /// [`ClientError::Io`]/[`ClientError::Malformed`].
    GoAway(String),
    /// Admission control shed this request before a worker saw it
    /// (binary protocol only).  The connection is fine; retry with
    /// backoff.
    Shed,
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io error: {e}"),
            ClientError::Server(e) => write!(f, "server error: {e}"),
            ClientError::Malformed(e) => write!(f, "malformed response: {e}"),
            ClientError::GoAway(e) => write!(f, "server goaway: {e}"),
            ClientError::Shed => write!(f, "request shed by admission control"),
        }
    }
}

impl std::error::Error for ClientError {}

/// Server-side outcome of a `retrain` request.
#[derive(Clone, Debug, PartialEq)]
pub struct RetrainReply {
    /// Store records newly discovered by the server's poll.
    pub new_records: u64,
    /// `(application, new version)` for every hot-swapped refit.
    pub refits: Vec<(String, u64)>,
}

/// Metadata of one served model, from `model_info`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInfoReply {
    /// Application the model serves.
    pub app: String,
    /// Registry version currently live.
    pub version: u64,
    /// Distinct settings the fit used.
    pub trained_on: u64,
    /// Training RMSE in seconds (absent for models installed without
    /// diagnostics).
    pub fit_rmse: Option<f64>,
    /// Fitted coefficients in feature order.
    pub coeffs: Vec<f64>,
}

/// A connected client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

fn io_err(e: impl fmt::Display) -> ClientError {
    ClientError::Io(e.to_string())
}

/// Extract a required `f64` field (via the shared [`Json::req`]
/// helpers), additionally rejecting non-finite values.
fn req_f64(resp: &Json, key: &str) -> Result<f64, ClientError> {
    let v = resp
        .req(key)
        .and_then(|j| {
            j.as_f64()
                .ok_or_else(|| format!("field '{key}' must be a number"))
        })
        .map_err(ClientError::Malformed)?;
    if v.is_finite() {
        Ok(v)
    } else {
        Err(ClientError::Malformed(format!("field '{key}' is not finite")))
    }
}

/// Extract a required integer field via the shared [`Json::req_u64`].
fn req_u64(resp: &Json, key: &str) -> Result<u64, ClientError> {
    resp.req_u64(key).map_err(ClientError::Malformed)
}

impl Client {
    /// Connect to a running prediction server.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    fn round_trip(&mut self, req: &Json) -> Result<Json, ClientError> {
        self.writer
            .write_all(format!("{req}\n").as_bytes())
            .map_err(io_err)?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(io_err)?;
        if n == 0 {
            return Err(ClientError::Io("server closed the connection".into()));
        }
        if !line.ends_with('\n') {
            // EOF mid-line: the reply was cut off, not merely empty.
            return Err(ClientError::Malformed(format!(
                "truncated reply: {line:?}"
            )));
        }
        let resp = parse(line.trim()).map_err(ClientError::Malformed)?;
        match resp.get("ok").and_then(|v| v.as_bool()) {
            Some(true) => Ok(resp),
            Some(false) => Err(ClientError::Server(
                resp.get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("unknown server error")
                    .to_string(),
            )),
            None => Err(ClientError::Malformed(
                "'ok' field missing or not a bool".into(),
            )),
        }
    }

    /// Predict total execution time for an `(app, M, R)` setting.
    pub fn predict(
        &mut self,
        app: &str,
        mappers: u32,
        reducers: u32,
    ) -> Result<f64, ClientError> {
        self.predict_versioned(app, mappers, reducers).map(|p| p.seconds)
    }

    /// [`Client::predict`] plus the serving model's version (the same
    /// [`Prediction`] the in-process service returns) — lets callers
    /// confirm which refit answered after a `retrain`.
    pub fn predict_versioned(
        &mut self,
        app: &str,
        mappers: u32,
        reducers: u32,
    ) -> Result<Prediction, ClientError> {
        let req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            ("app", Json::Str(app.into())),
            ("mappers", Json::Num(mappers as f64)),
            ("reducers", Json::Num(reducers as f64)),
        ]);
        let resp = self.round_trip(&req)?;
        Ok(Prediction {
            seconds: req_f64(&resp, "predicted_s")?,
            version: req_u64(&resp, "version")?,
        })
    }

    /// Predict one of `app`'s modeled outputs by target name (`time_s`,
    /// `cpu_s`, `shuffle_bytes`) via the request's optional `target`
    /// field.  Equivalent to predicting against the target-qualified
    /// model name; the prediction's unit follows the target.
    pub fn predict_target(
        &mut self,
        app: &str,
        target: &str,
        mappers: u32,
        reducers: u32,
    ) -> Result<Prediction, ClientError> {
        let req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            ("app", Json::Str(app.into())),
            ("target", Json::Str(target.into())),
            ("mappers", Json::Num(mappers as f64)),
            ("reducers", Json::Num(reducers as f64)),
        ]);
        let resp = self.round_trip(&req)?;
        Ok(Prediction {
            seconds: req_f64(&resp, "predicted_s")?,
            version: req_u64(&resp, "version")?,
        })
    }

    /// List applications with installed models.
    pub fn models(&mut self) -> Result<Vec<String>, ClientError> {
        let req = Json::obj(vec![("op", Json::Str("models".into()))]);
        let resp = self.round_trip(&req)?;
        let arr = resp.get("models").and_then(|v| v.as_arr()).ok_or_else(
            || ClientError::Malformed("'models' missing or not an array".into()),
        )?;
        arr.iter()
            .map(|x| {
                x.as_str().map(str::to_string).ok_or_else(|| {
                    ClientError::Malformed(
                        "'models' entry is not a string".into(),
                    )
                })
            })
            .collect()
    }

    /// Ask the server to tail its profile store and hot-swap refit
    /// models (`retrain` op; requires the server to have a trainer).
    pub fn retrain(&mut self) -> Result<RetrainReply, ClientError> {
        let req = Json::obj(vec![("op", Json::Str("retrain".into()))]);
        let resp = self.round_trip(&req)?;
        let arr = resp.get("refits").and_then(|v| v.as_arr()).ok_or_else(
            || ClientError::Malformed("'refits' missing or not an array".into()),
        )?;
        let mut refits = Vec::with_capacity(arr.len());
        for item in arr {
            let app = item
                .get("app")
                .and_then(|a| a.as_str())
                .ok_or_else(|| {
                    ClientError::Malformed("refit entry missing 'app'".into())
                })?
                .to_string();
            refits.push((app, req_u64(item, "version")?));
        }
        Ok(RetrainReply {
            new_records: req_u64(&resp, "new_records")?,
            refits,
        })
    }

    /// Metadata (version, row count, fit RMSE, coefficients) of the
    /// model currently serving `app`.
    pub fn model_info(
        &mut self,
        app: &str,
    ) -> Result<ModelInfoReply, ClientError> {
        let req = Json::obj(vec![
            ("op", Json::Str("model_info".into())),
            ("app", Json::Str(app.into())),
        ]);
        let resp = self.round_trip(&req)?;
        let coeffs = resp
            .get("coeffs")
            .and_then(|v| v.to_f64_vec().ok())
            .ok_or_else(|| {
                ClientError::Malformed(
                    "'coeffs' missing or not a number array".into(),
                )
            })?;
        Ok(ModelInfoReply {
            app: resp
                .get("app")
                .and_then(|a| a.as_str())
                .ok_or_else(|| {
                    ClientError::Malformed("'app' missing".into())
                })?
                .to_string(),
            version: req_u64(&resp, "version")?,
            trained_on: req_u64(&resp, "trained_on")?,
            // fit_rmse is genuinely optional (unknown for hand-installed
            // models) — but when present it must be a finite number.
            fit_rmse: match resp.get("fit_rmse") {
                None => None,
                Some(_) => Some(req_f64(&resp, "fit_rmse")?),
            },
            coeffs,
        })
    }

    /// Service health counters: (requests, batches, mean batch size).
    /// Every field is required — a reply missing one is
    /// [`ClientError::Malformed`], where it used to read as zero.
    pub fn health(&mut self) -> Result<(u64, u64, f64), ClientError> {
        let req = Json::obj(vec![("op", Json::Str("health".into()))]);
        let resp = self.round_trip(&req)?;
        Ok((
            req_u64(&resp, "requests")?,
            req_u64(&resp, "batches")?,
            req_f64(&resp, "mean_batch")?,
        ))
    }
}

/// What one pipelined request resolved to.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    /// A predict request succeeded.
    Predict(Prediction),
    /// A tunneled JSON op answered with this (raw) JSON object.
    Json(Json),
    /// The server failed this one request; the connection lives on.
    Err(String),
    /// Admission control shed this request.
    Shed,
}

/// What kind of response body a submitted request id expects.
#[derive(Clone, Copy, Debug)]
enum ReqKind {
    Predict,
    Json,
}

/// Pipelined binary-protocol client: submit many requests, flush once,
/// then collect responses by request id (they may arrive out of order
/// in principle; the current server preserves submission order within a
/// connection).
///
/// ```no_run
/// # use mrtuner::coordinator::client::PipelinedClient;
/// let mut c = PipelinedClient::connect("127.0.0.1:4500").unwrap();
/// let reqs: Vec<(String, u32, u32)> =
///     (1..=40).map(|m| ("wordcount".to_string(), m, 5)).collect();
/// let replies = c.predict_many(&reqs, 32).unwrap();
/// # let _ = replies;
/// ```
pub struct PipelinedClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    frames: wire::FrameReader,
    out: Vec<u8>,
    next_id: u64,
    kinds: HashMap<u64, ReqKind>,
}

impl PipelinedClient {
    /// Connect and send the binary-protocol preamble.
    pub fn connect(addr: &str) -> std::io::Result<PipelinedClient> {
        let stream = TcpStream::connect(addr)?;
        let mut writer = stream.try_clone()?;
        let mut hello = Vec::with_capacity(wire::PREAMBLE_LEN);
        wire::encode_preamble(&mut hello);
        writer.write_all(&hello)?;
        Ok(PipelinedClient {
            reader: BufReader::new(stream),
            writer,
            frames: wire::FrameReader::new(),
            out: Vec::with_capacity(4 * 1024),
            next_id: 1,
            kinds: HashMap::new(),
        })
    }

    fn fresh_id(&mut self, kind: ReqKind) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.kinds.insert(id, kind);
        id
    }

    /// Buffer a predict request; returns its request id.  Nothing is
    /// written until [`PipelinedClient::flush`].
    pub fn submit_predict(
        &mut self,
        app: &str,
        mappers: u32,
        reducers: u32,
    ) -> u64 {
        let id = self.fresh_id(ReqKind::Predict);
        wire::encode_predict_req(&mut self.out, id, app, mappers, reducers);
        id
    }

    /// Buffer a tunneled JSON op (same object the line protocol sends);
    /// returns its request id.
    pub fn submit_json(&mut self, req: &Json) -> u64 {
        let id = self.fresh_id(ReqKind::Json);
        wire::encode_json_req(&mut self.out, id, &req.to_string());
        id
    }

    /// Write every buffered request in one syscall.
    pub fn flush(&mut self) -> Result<(), ClientError> {
        if self.out.is_empty() {
            return Ok(());
        }
        self.writer.write_all(&self.out).map_err(io_err)?;
        self.out.clear();
        Ok(())
    }

    /// Block until the next response frame arrives; returns
    /// `(request id, reply)`.  A GOAWAY frame (which answers the
    /// connection, not a request) surfaces as
    /// [`ClientError::GoAway`].
    pub fn recv(&mut self) -> Result<(u64, Reply), ClientError> {
        loop {
            let frame = self
                .frames
                .next_frame()
                .map_err(|e| ClientError::Malformed(e.to_string()))?;
            if let Some(f) = frame {
                return self.interpret(f);
            }
            let available = self.reader.fill_buf().map_err(io_err)?;
            if available.is_empty() {
                return Err(ClientError::Io(
                    "server closed the connection".into(),
                ));
            }
            let n = available.len();
            self.frames.feed(available);
            self.reader.consume(n);
        }
    }

    fn interpret(
        &mut self,
        f: wire::Frame,
    ) -> Result<(u64, Reply), ClientError> {
        let text = |body: &[u8]| String::from_utf8_lossy(body).into_owned();
        match f.tag {
            wire::RESP_GOAWAY => Err(ClientError::GoAway(text(&f.body))),
            wire::RESP_SHED => {
                self.kinds.remove(&f.id);
                Ok((f.id, Reply::Shed))
            }
            wire::RESP_ERR => {
                self.kinds.remove(&f.id);
                Ok((f.id, Reply::Err(text(&f.body))))
            }
            wire::RESP_OK => match self.kinds.remove(&f.id) {
                Some(ReqKind::Predict) => {
                    let p = wire::decode_predict_ok(&f.body)
                        .map_err(|e| ClientError::Malformed(e.to_string()))?;
                    Ok((f.id, Reply::Predict(p)))
                }
                Some(ReqKind::Json) => {
                    let v = parse(text(&f.body).trim())
                        .map_err(ClientError::Malformed)?;
                    Ok((f.id, Reply::Json(v)))
                }
                None => Err(ClientError::Malformed(format!(
                    "response for unknown request id {}",
                    f.id
                ))),
            },
            other => Err(ClientError::Malformed(format!(
                "server sent request tag {other:#04x}"
            ))),
        }
    }

    /// Run `reqs` through the pipeline keeping up to `window` requests
    /// in flight; per-request outcomes come back in input order (a shed
    /// request is [`ClientError::Shed`], a server-side failure is
    /// [`ClientError::Server`] — both isolated to their request).
    pub fn predict_many(
        &mut self,
        reqs: &[(String, u32, u32)],
        window: usize,
    ) -> Result<Vec<Result<Prediction, ClientError>>, ClientError> {
        let window = window.max(1);
        let mut out: Vec<Option<Result<Prediction, ClientError>>> =
            reqs.iter().map(|_| None).collect();
        let mut id_to_idx: HashMap<u64, usize> = HashMap::new();
        let mut next = 0usize;
        let mut done = 0usize;
        while done < reqs.len() {
            while next < reqs.len() && id_to_idx.len() < window {
                let (app, m, r) = &reqs[next];
                let id = self.submit_predict(app, *m, *r);
                id_to_idx.insert(id, next);
                next += 1;
            }
            self.flush()?;
            let (id, reply) = self.recv()?;
            let idx = id_to_idx.remove(&id).ok_or_else(|| {
                ClientError::Malformed(format!("unknown request id {id}"))
            })?;
            out[idx] = Some(match reply {
                Reply::Predict(p) => Ok(p),
                Reply::Err(e) => Err(ClientError::Server(e)),
                Reply::Shed => Err(ClientError::Shed),
                Reply::Json(_) => {
                    return Err(ClientError::Malformed(
                        "json reply to a predict request".into(),
                    ))
                }
            });
            done += 1;
        }
        Ok(out.into_iter().map(|o| o.expect("all replies seen")).collect())
    }

    /// One tunneled JSON op, request-response (no other requests may be
    /// outstanding).  `ok:false` replies surface as
    /// [`ClientError::Server`], like [`Client`]'s methods.
    pub fn json_op(&mut self, req: &Json) -> Result<Json, ClientError> {
        let id = self.submit_json(req);
        self.flush()?;
        let (got, reply) = self.recv()?;
        if got != id {
            return Err(ClientError::Malformed(format!(
                "reply for id {got}, expected {id}"
            )));
        }
        match reply {
            Reply::Json(resp) => {
                match resp.get("ok").and_then(|v| v.as_bool()) {
                    Some(true) => Ok(resp),
                    Some(false) => Err(ClientError::Server(
                        resp.get("error")
                            .and_then(|e| e.as_str())
                            .unwrap_or("unknown server error")
                            .to_string(),
                    )),
                    None => Err(ClientError::Malformed(
                        "'ok' field missing or not a bool".into(),
                    )),
                }
            }
            Reply::Err(e) => Err(ClientError::Server(e)),
            Reply::Shed => Err(ClientError::Shed),
            Reply::Predict(_) => Err(ClientError::Malformed(
                "predict reply to a json op".into(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// A one-shot fake server: accepts one connection, reads one line,
    /// writes `reply` verbatim (no newline added), and closes.
    fn fake_server(reply: &'static str) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut buf = [0u8; 1024];
            use std::io::Read;
            let _ = stream.read(&mut buf);
            stream.write_all(reply.as_bytes()).unwrap();
            // Dropping the stream closes it mid-line.
        });
        addr
    }

    #[test]
    fn truncated_reply_is_malformed_not_zero() {
        // Cut off mid-number, no trailing newline.
        let addr = fake_server(r#"{"ok":true,"predicted_s":51"#);
        let mut c = Client::connect(&addr).unwrap();
        match c.predict("wordcount", 20, 5) {
            Err(ClientError::Malformed(msg)) => {
                assert!(msg.contains("truncated"), "{msg}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn missing_field_is_malformed_not_zero() {
        let addr = fake_server("{\"ok\":true}\n");
        let mut c = Client::connect(&addr).unwrap();
        match c.predict("wordcount", 20, 5) {
            Err(ClientError::Malformed(msg)) => {
                assert!(msg.contains("predicted_s"), "{msg}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn missing_health_fields_are_malformed_not_zero() {
        // The old client read this as (0, 0, 0.0).
        let addr = fake_server("{\"ok\":true,\"requests\":3}\n");
        let mut c = Client::connect(&addr).unwrap();
        match c.health() {
            Err(ClientError::Malformed(msg)) => {
                assert!(msg.contains("batches"), "{msg}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn server_error_is_typed() {
        let addr = fake_server("{\"ok\":false,\"error\":\"no model\"}\n");
        let mut c = Client::connect(&addr).unwrap();
        assert_eq!(
            c.predict("x", 1, 1),
            Err(ClientError::Server("no model".into()))
        );
    }

    #[test]
    fn closed_connection_is_io() {
        let addr = fake_server("");
        let mut c = Client::connect(&addr).unwrap();
        match c.predict("x", 1, 1) {
            Err(ClientError::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn error_display_names_the_kind() {
        assert!(ClientError::Io("x".into()).to_string().contains("io"));
        assert!(ClientError::Server("x".into()).to_string().contains("server"));
        assert!(ClientError::Malformed("x".into())
            .to_string()
            .contains("malformed"));
        assert!(ClientError::GoAway("x".into()).to_string().contains("goaway"));
        assert!(ClientError::Shed.to_string().contains("shed"));
    }
}
