//! Blocking TCP client for the prediction server.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::json::{parse, Json};

/// A connected client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a running prediction server.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    fn round_trip(&mut self, req: &Json) -> Result<Json, String> {
        self.writer
            .write_all(format!("{req}\n").as_bytes())
            .map_err(|e| e.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        let resp = parse(line.trim())?;
        if resp.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            return Err(resp
                .get("error")
                .and_then(|e| e.as_str())
                .unwrap_or("unknown server error")
                .to_string());
        }
        Ok(resp)
    }

    /// Predict total execution time for an `(app, M, R)` setting.
    pub fn predict(&mut self, app: &str, mappers: u32, reducers: u32) -> Result<f64, String> {
        let req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            ("app", Json::Str(app.into())),
            ("mappers", Json::Num(mappers as f64)),
            ("reducers", Json::Num(reducers as f64)),
        ]);
        self.round_trip(&req)?
            .get("predicted_s")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| "malformed response".to_string())
    }

    /// List applications with installed models.
    pub fn models(&mut self) -> Result<Vec<String>, String> {
        let req = Json::obj(vec![("op", Json::Str("models".into()))]);
        Ok(self
            .round_trip(&req)?
            .get("models")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default())
    }

    /// Service health counters: (requests, batches, mean batch size).
    pub fn health(&mut self) -> Result<(u64, u64, f64), String> {
        let req = Json::obj(vec![("op", Json::Str("health".into()))]);
        let resp = self.round_trip(&req)?;
        let g = |k: &str| resp.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        Ok((g("requests") as u64, g("batches") as u64, g("mean_batch")))
    }
}
