//! Blocking TCP client for the prediction server.
//!
//! Failures are **typed** ([`ClientError`]): a transport failure, a
//! server-reported error, and a malformed reply are different bugs with
//! different fixes, and the old stringly-typed path (worse, its
//! `unwrap_or(0.0)` on missing fields) let a truncated reply read as "0
//! seconds predicted".  Every field the client consumes is now required
//! and validated.

use std::fmt;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::util::json::{parse, Json};

use super::service::Prediction;

/// Why a client call failed.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientError {
    /// Transport failure: connect, write, read, or connection closed.
    Io(String),
    /// The server answered `ok:false` with this message (protocol-level
    /// error: unknown app, bad request, retrain failure ...).
    Server(String),
    /// The server's reply was syntactically or structurally invalid — a
    /// truncated line, missing field, or non-finite number.  These used
    /// to be silently mapped to `0.0`.
    Malformed(String),
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io error: {e}"),
            ClientError::Server(e) => write!(f, "server error: {e}"),
            ClientError::Malformed(e) => write!(f, "malformed response: {e}"),
        }
    }
}

impl std::error::Error for ClientError {}

/// Server-side outcome of a `retrain` request.
#[derive(Clone, Debug, PartialEq)]
pub struct RetrainReply {
    /// Store records newly discovered by the server's poll.
    pub new_records: u64,
    /// `(application, new version)` for every hot-swapped refit.
    pub refits: Vec<(String, u64)>,
}

/// Metadata of one served model, from `model_info`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInfoReply {
    /// Application the model serves.
    pub app: String,
    /// Registry version currently live.
    pub version: u64,
    /// Distinct settings the fit used.
    pub trained_on: u64,
    /// Training RMSE in seconds (absent for models installed without
    /// diagnostics).
    pub fit_rmse: Option<f64>,
    /// Fitted coefficients in feature order.
    pub coeffs: Vec<f64>,
}

/// A connected client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

fn io_err(e: impl fmt::Display) -> ClientError {
    ClientError::Io(e.to_string())
}

/// Extract a required `f64` field (via the shared [`Json::req`]
/// helpers), additionally rejecting non-finite values.
fn req_f64(resp: &Json, key: &str) -> Result<f64, ClientError> {
    let v = resp
        .req(key)
        .and_then(|j| {
            j.as_f64()
                .ok_or_else(|| format!("field '{key}' must be a number"))
        })
        .map_err(ClientError::Malformed)?;
    if v.is_finite() {
        Ok(v)
    } else {
        Err(ClientError::Malformed(format!("field '{key}' is not finite")))
    }
}

/// Extract a required integer field via the shared [`Json::req_u64`].
fn req_u64(resp: &Json, key: &str) -> Result<u64, ClientError> {
    resp.req_u64(key).map_err(ClientError::Malformed)
}

impl Client {
    /// Connect to a running prediction server.
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    fn round_trip(&mut self, req: &Json) -> Result<Json, ClientError> {
        self.writer
            .write_all(format!("{req}\n").as_bytes())
            .map_err(io_err)?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(io_err)?;
        if n == 0 {
            return Err(ClientError::Io("server closed the connection".into()));
        }
        if !line.ends_with('\n') {
            // EOF mid-line: the reply was cut off, not merely empty.
            return Err(ClientError::Malformed(format!(
                "truncated reply: {line:?}"
            )));
        }
        let resp = parse(line.trim()).map_err(ClientError::Malformed)?;
        match resp.get("ok").and_then(|v| v.as_bool()) {
            Some(true) => Ok(resp),
            Some(false) => Err(ClientError::Server(
                resp.get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("unknown server error")
                    .to_string(),
            )),
            None => Err(ClientError::Malformed(
                "'ok' field missing or not a bool".into(),
            )),
        }
    }

    /// Predict total execution time for an `(app, M, R)` setting.
    pub fn predict(
        &mut self,
        app: &str,
        mappers: u32,
        reducers: u32,
    ) -> Result<f64, ClientError> {
        self.predict_versioned(app, mappers, reducers).map(|p| p.seconds)
    }

    /// [`Client::predict`] plus the serving model's version (the same
    /// [`Prediction`] the in-process service returns) — lets callers
    /// confirm which refit answered after a `retrain`.
    pub fn predict_versioned(
        &mut self,
        app: &str,
        mappers: u32,
        reducers: u32,
    ) -> Result<Prediction, ClientError> {
        let req = Json::obj(vec![
            ("op", Json::Str("predict".into())),
            ("app", Json::Str(app.into())),
            ("mappers", Json::Num(mappers as f64)),
            ("reducers", Json::Num(reducers as f64)),
        ]);
        let resp = self.round_trip(&req)?;
        Ok(Prediction {
            seconds: req_f64(&resp, "predicted_s")?,
            version: req_u64(&resp, "version")?,
        })
    }

    /// List applications with installed models.
    pub fn models(&mut self) -> Result<Vec<String>, ClientError> {
        let req = Json::obj(vec![("op", Json::Str("models".into()))]);
        let resp = self.round_trip(&req)?;
        let arr = resp.get("models").and_then(|v| v.as_arr()).ok_or_else(
            || ClientError::Malformed("'models' missing or not an array".into()),
        )?;
        arr.iter()
            .map(|x| {
                x.as_str().map(str::to_string).ok_or_else(|| {
                    ClientError::Malformed(
                        "'models' entry is not a string".into(),
                    )
                })
            })
            .collect()
    }

    /// Ask the server to tail its profile store and hot-swap refit
    /// models (`retrain` op; requires the server to have a trainer).
    pub fn retrain(&mut self) -> Result<RetrainReply, ClientError> {
        let req = Json::obj(vec![("op", Json::Str("retrain".into()))]);
        let resp = self.round_trip(&req)?;
        let arr = resp.get("refits").and_then(|v| v.as_arr()).ok_or_else(
            || ClientError::Malformed("'refits' missing or not an array".into()),
        )?;
        let mut refits = Vec::with_capacity(arr.len());
        for item in arr {
            let app = item
                .get("app")
                .and_then(|a| a.as_str())
                .ok_or_else(|| {
                    ClientError::Malformed("refit entry missing 'app'".into())
                })?
                .to_string();
            refits.push((app, req_u64(item, "version")?));
        }
        Ok(RetrainReply {
            new_records: req_u64(&resp, "new_records")?,
            refits,
        })
    }

    /// Metadata (version, row count, fit RMSE, coefficients) of the
    /// model currently serving `app`.
    pub fn model_info(
        &mut self,
        app: &str,
    ) -> Result<ModelInfoReply, ClientError> {
        let req = Json::obj(vec![
            ("op", Json::Str("model_info".into())),
            ("app", Json::Str(app.into())),
        ]);
        let resp = self.round_trip(&req)?;
        let coeffs = resp
            .get("coeffs")
            .and_then(|v| v.to_f64_vec().ok())
            .ok_or_else(|| {
                ClientError::Malformed(
                    "'coeffs' missing or not a number array".into(),
                )
            })?;
        Ok(ModelInfoReply {
            app: resp
                .get("app")
                .and_then(|a| a.as_str())
                .ok_or_else(|| {
                    ClientError::Malformed("'app' missing".into())
                })?
                .to_string(),
            version: req_u64(&resp, "version")?,
            trained_on: req_u64(&resp, "trained_on")?,
            // fit_rmse is genuinely optional (unknown for hand-installed
            // models) — but when present it must be a finite number.
            fit_rmse: match resp.get("fit_rmse") {
                None => None,
                Some(_) => Some(req_f64(&resp, "fit_rmse")?),
            },
            coeffs,
        })
    }

    /// Service health counters: (requests, batches, mean batch size).
    /// Every field is required — a reply missing one is
    /// [`ClientError::Malformed`], where it used to read as zero.
    pub fn health(&mut self) -> Result<(u64, u64, f64), ClientError> {
        let req = Json::obj(vec![("op", Json::Str("health".into()))]);
        let resp = self.round_trip(&req)?;
        Ok((
            req_u64(&resp, "requests")?,
            req_u64(&resp, "batches")?,
            req_f64(&resp, "mean_batch")?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// A one-shot fake server: accepts one connection, reads one line,
    /// writes `reply` verbatim (no newline added), and closes.
    fn fake_server(reply: &'static str) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let mut buf = [0u8; 1024];
            use std::io::Read;
            let _ = stream.read(&mut buf);
            stream.write_all(reply.as_bytes()).unwrap();
            // Dropping the stream closes it mid-line.
        });
        addr
    }

    #[test]
    fn truncated_reply_is_malformed_not_zero() {
        // Cut off mid-number, no trailing newline.
        let addr = fake_server(r#"{"ok":true,"predicted_s":51"#);
        let mut c = Client::connect(&addr).unwrap();
        match c.predict("wordcount", 20, 5) {
            Err(ClientError::Malformed(msg)) => {
                assert!(msg.contains("truncated"), "{msg}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn missing_field_is_malformed_not_zero() {
        let addr = fake_server("{\"ok\":true}\n");
        let mut c = Client::connect(&addr).unwrap();
        match c.predict("wordcount", 20, 5) {
            Err(ClientError::Malformed(msg)) => {
                assert!(msg.contains("predicted_s"), "{msg}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn missing_health_fields_are_malformed_not_zero() {
        // The old client read this as (0, 0, 0.0).
        let addr = fake_server("{\"ok\":true,\"requests\":3}\n");
        let mut c = Client::connect(&addr).unwrap();
        match c.health() {
            Err(ClientError::Malformed(msg)) => {
                assert!(msg.contains("batches"), "{msg}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn server_error_is_typed() {
        let addr = fake_server("{\"ok\":false,\"error\":\"no model\"}\n");
        let mut c = Client::connect(&addr).unwrap();
        assert_eq!(
            c.predict("x", 1, 1),
            Err(ClientError::Server("no model".into()))
        );
    }

    #[test]
    fn closed_connection_is_io() {
        let addr = fake_server("");
        let mut c = Client::connect(&addr).unwrap();
        match c.predict("x", 1, 1) {
            Err(ClientError::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn error_display_names_the_kind() {
        assert!(ClientError::Io("x".into()).to_string().contains("io"));
        assert!(ClientError::Server("x".into()).to_string().contains("server"));
        assert!(ClientError::Malformed("x".into())
            .to_string()
            .contains("malformed"));
    }
}
