//! The prediction service: dynamic batching over the predict artifact.
//!
//! Concurrent callers block on [`PredictionService::predict`]; a worker
//! thread drains the request queue, groups requests by application, and
//! issues **one backend execution per (app, cycle)** — on the PJRT backend
//! that is a single 64-row predict-artifact call, amortizing dispatch cost
//! across callers exactly like a vLLM-style router batches decode steps.
//!
//! Batching policy: take the first request (blocking), then keep draining
//! until either `max_batch` requests are queued or `max_wait` has elapsed
//! since the first one.  Both knobs are in [`ServiceConfig`] and are
//! swept by `rust/benches/perf_hotpath.rs`.
//!
//! Models hot-swap: [`PredictionService::publish_model`] replaces an
//! application's entry atomically under the registry `RwLock`, so a batch
//! that already resolved its coefficients finishes on the old version
//! while every later batch sees the new one — each [`Prediction`] names
//! the version that served it, and per-caller observed versions are
//! monotonic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

use crate::model::regression::FitBackend;

use super::registry::{ModelEntry, ModelRegistry};

/// Service tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Maximum requests coalesced into one backend call (the predict
    /// artifact's fixed row count is the natural setting).
    pub max_batch: usize,
    /// How long the batcher waits for stragglers after the first request.
    pub max_wait: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { max_batch: 64, max_wait: Duration::from_micros(500) }
    }
}

/// Service counters (all monotonic).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Predictions requested.
    pub requests: AtomicU64,
    /// Backend executions (each serves one coalesced batch).
    pub batches: AtomicU64,
    /// Backend calls that returned an error.
    pub backend_errors: AtomicU64,
    /// Requests rejected before reaching a backend (unknown application).
    /// These never produce a batch, so they are excluded from
    /// [`ServiceMetrics::mean_batch_size`].
    pub rejected: AtomicU64,
    /// Requests dropped by server admission control (bounded queue full)
    /// before reaching the service at all.  Shed requests get a typed
    /// SHED response; they never increment `requests`.
    pub shed: AtomicU64,
    /// Largest batch coalesced so far.
    pub max_batch_seen: AtomicU64,
    /// Times the registry lock was found poisoned and recovered.  A
    /// panicking worker poisons the `RwLock`, but the registry itself is
    /// always consistent (swaps are single `BTreeMap` inserts), so the
    /// service recovers — and clears the poison — instead of failing
    /// every later request.  Because recovery clears the flag, this
    /// counts panic *incidents* (± racing observers), not every lock
    /// acquisition after one.
    pub lock_poisoned: AtomicU64,
}

impl ServiceMetrics {
    /// Mean *served* requests per backend call — the batching
    /// amortization factor.  Rejected (unknown-app) requests increment
    /// `requests` but never cost a backend call; counting them here used
    /// to overstate amortization.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            let req = self.requests.load(Ordering::Relaxed);
            let rej = self.rejected.load(Ordering::Relaxed);
            req.saturating_sub(rej) as f64 / b as f64
        }
    }
}

/// One served prediction: the predicted total time and the version of
/// the application model that produced it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    /// Predicted total execution time, seconds.
    pub seconds: f64,
    /// Registry version of the model that served the request.
    pub version: u64,
}

/// One request of a synchronous server-side batch (see
/// [`PredictionService::predict_batch`]).
#[derive(Clone, Debug)]
pub struct BatchItem {
    /// Application to predict for.
    pub app: String,
    /// Number of map tasks.
    pub mappers: u32,
    /// Number of reduce tasks.
    pub reducers: u32,
}

enum Msg {
    Predict(PredictReq),
    Shutdown,
}

struct PredictReq {
    app: String,
    params: [f64; 2],
    resp: Sender<Result<Prediction, String>>,
}

/// Lock the registry for reading, recovering from poison (see
/// [`ServiceMetrics::lock_poisoned`]).  The poison flag is cleared so
/// one panic is counted once, not on every later acquisition.
fn registry_read<'a>(
    registry: &'a RwLock<ModelRegistry>,
    metrics: &ServiceMetrics,
) -> RwLockReadGuard<'a, ModelRegistry> {
    match registry.read() {
        Ok(guard) => guard,
        Err(poisoned) => {
            metrics.lock_poisoned.fetch_add(1, Ordering::Relaxed);
            registry.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// Lock the registry for writing, recovering from poison.
fn registry_write<'a>(
    registry: &'a RwLock<ModelRegistry>,
    metrics: &ServiceMetrics,
) -> RwLockWriteGuard<'a, ModelRegistry> {
    match registry.write() {
        Ok(guard) => guard,
        Err(poisoned) => {
            metrics.lock_poisoned.fetch_add(1, Ordering::Relaxed);
            registry.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// Handle to the running service.  Cloneable; dropping the last handle
/// shuts the worker down.
pub struct PredictionService {
    tx: Sender<Msg>,
    registry: Arc<RwLock<ModelRegistry>>,
    /// Live service counters (shared with the worker thread).
    pub metrics: Arc<ServiceMetrics>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Start the service over any fitting backend (the PJRT
    /// [`crate::runtime::XlaBackend`] in production; the pure-Rust solver
    /// in tests and artifact-less environments).
    ///
    /// The backend is built *inside* the worker thread via `factory`
    /// because PJRT handles are not `Send` (the `xla` crate wraps them in
    /// `Rc`); constructing on the owning thread keeps them thread-local
    /// for their whole life.
    pub fn start<F>(
        factory: F,
        registry: ModelRegistry,
        config: ServiceConfig,
    ) -> PredictionService
    where
        F: FnOnce() -> Box<dyn FitBackend> + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let registry = Arc::new(RwLock::new(registry));
        let metrics = Arc::new(ServiceMetrics::default());
        let worker = {
            let registry = Arc::clone(&registry);
            let metrics = Arc::clone(&metrics);
            std::thread::spawn(move || {
                let backend = factory();
                worker_loop(backend, rx, registry, metrics, config)
            })
        };
        PredictionService { tx, registry, metrics, worker: Some(worker) }
    }

    fn enqueue(
        &self,
        app: &str,
        num_mappers: u32,
        num_reducers: u32,
    ) -> Result<Receiver<Result<Prediction, String>>, String> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Msg::Predict(PredictReq {
                app: app.to_string(),
                params: [num_mappers as f64, num_reducers as f64],
                resp: rtx,
            }))
            .map_err(|_| "service stopped".to_string())?;
        Ok(rrx)
    }

    /// Blocking single prediction (seconds only; see
    /// [`PredictionService::predict_versioned`] for the serving version).
    pub fn predict(
        &self,
        app: &str,
        num_mappers: u32,
        num_reducers: u32,
    ) -> Result<f64, String> {
        self.predict_versioned(app, num_mappers, num_reducers)
            .map(|p| p.seconds)
    }

    /// Blocking single prediction, with the model version that served it.
    pub fn predict_versioned(
        &self,
        app: &str,
        num_mappers: u32,
        num_reducers: u32,
    ) -> Result<Prediction, String> {
        let rrx = self.enqueue(app, num_mappers, num_reducers)?;
        rrx.recv().map_err(|_| "service dropped request".to_string())?
    }

    /// Fire a prediction without blocking; the result arrives on the
    /// returned receiver.  This is what lets callers build big concurrent
    /// batches from one thread (used by the benches and the server).
    pub fn predict_async(
        &self,
        app: &str,
        num_mappers: u32,
        num_reducers: u32,
    ) -> Result<Receiver<Result<Prediction, String>>, String> {
        self.enqueue(app, num_mappers, num_reducers)
    }

    /// Resolve a whole batch of requests synchronously on the calling
    /// thread — the server-side micro-batching path.
    ///
    /// Like the queued worker ([`PredictionService::predict`]), the
    /// batch is grouped by application and each group resolves its
    /// `(coefficients, version)` pair in **one registry read**, so every
    /// request of a group is served by a single consistent model even
    /// when a [`PredictionService::publish_model`] hot-swap lands
    /// mid-batch, and successive batches observe monotonically
    /// non-decreasing versions.  Predictions are the canonical
    /// polynomial evaluation ([`crate::model::features::evaluate`]) —
    /// bit-identical to the queued path on the default backend, which
    /// is why the JSON and binary server protocols answer with exactly
    /// the same bits.
    ///
    /// Results are returned in input order.  Metrics accounting matches
    /// the queued path: `requests` counts every item, `rejected` the
    /// unknown-app items, and `batches` one per app group that reached
    /// evaluation.
    pub fn predict_batch(
        &self,
        items: &[BatchItem],
    ) -> Vec<Result<Prediction, String>> {
        let m = &self.metrics;
        m.requests.fetch_add(items.len() as u64, Ordering::Relaxed);
        m.max_batch_seen.fetch_max(items.len() as u64, Ordering::Relaxed);
        let mut out: Vec<Result<Prediction, String>> = items
            .iter()
            .map(|_| Err("batch slot unfilled (service bug)".to_string()))
            .collect();
        let mut by_app: std::collections::BTreeMap<&str, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, item) in items.iter().enumerate() {
            by_app.entry(item.app.as_str()).or_default().push(i);
        }
        for (app, idxs) in by_app {
            let looked_up = {
                let reg = registry_read(&self.registry, m);
                reg.entry(app).map(|e| (e.model.coeffs, e.version))
            };
            match looked_up {
                None => {
                    m.rejected.fetch_add(idxs.len() as u64, Ordering::Relaxed);
                    for i in idxs {
                        if let Some(slot) = out.get_mut(i) {
                            *slot = Err(format!(
                                "no model for application '{app}'"
                            ));
                        }
                    }
                }
                Some((coeffs, version)) => {
                    m.batches.fetch_add(1, Ordering::Relaxed);
                    for i in idxs {
                        let Some(item) = items.get(i) else { continue };
                        let params =
                            [item.mappers as f64, item.reducers as f64];
                        let seconds =
                            crate::model::features::evaluate(&coeffs, &params);
                        if let Some(slot) = out.get_mut(i) {
                            *slot = Ok(Prediction { seconds, version });
                        }
                    }
                }
            }
        }
        out
    }

    /// Install or replace an application model without fit diagnostics.
    pub fn install_model(&self, model: crate::model::RegressionModel) {
        self.publish_model(model, f64::NAN);
    }

    /// Publish a (re)fitted model into the live registry — the atomic
    /// hot-swap: in-flight batches that already resolved their
    /// coefficients finish on the old version, every later batch sees
    /// the new one.  Returns the version assigned.
    pub fn publish_model(
        &self,
        model: crate::model::RegressionModel,
        fit_rmse: f64,
    ) -> u64 {
        registry_write(&self.registry, &self.metrics).publish(model, fit_rmse)
    }

    /// The registry entry (model + version + diagnostics) for `app`.
    pub fn model_info(&self, app: &str) -> Option<ModelEntry> {
        registry_read(&self.registry, &self.metrics).entry(app).cloned()
    }

    /// Names of the currently installed models.
    pub fn model_names(&self) -> Vec<String> {
        registry_read(&self.registry, &self.metrics).names()
    }

    #[cfg(test)]
    fn registry_handle(&self) -> Arc<RwLock<ModelRegistry>> {
        Arc::clone(&self.registry)
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    backend: Box<dyn FitBackend>,
    rx: Receiver<Msg>,
    registry: Arc<RwLock<ModelRegistry>>,
    metrics: Arc<ServiceMetrics>,
    config: ServiceConfig,
) {
    // Backend behind a Mutex only for interior mutability; single worker.
    let backend = Mutex::new(backend);
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(Msg::Predict(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + config.max_wait;
        while batch.len() < config.max_batch {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(Msg::Predict(r)) => batch.push(r),
                Ok(Msg::Shutdown) => {
                    serve_batch(&backend, &registry, &metrics, batch);
                    return;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        serve_batch(&backend, &registry, &metrics, batch);
    }
}

/// Lock the batching backend, recovering from poison the same way the
/// registry locks do (counted in [`ServiceMetrics::lock_poisoned`]).
fn backend_lock<'a>(
    backend: &'a Mutex<Box<dyn FitBackend>>,
    metrics: &ServiceMetrics,
) -> MutexGuard<'a, Box<dyn FitBackend>> {
    match backend.lock() {
        Ok(guard) => guard,
        Err(poisoned) => {
            metrics.lock_poisoned.fetch_add(1, Ordering::Relaxed);
            backend.clear_poison();
            poisoned.into_inner()
        }
    }
}

fn serve_batch(
    backend: &Mutex<Box<dyn FitBackend>>,
    registry: &Arc<RwLock<ModelRegistry>>,
    metrics: &Arc<ServiceMetrics>,
    batch: Vec<PredictReq>,
) {
    metrics.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
    metrics
        .max_batch_seen
        .fetch_max(batch.len() as u64, Ordering::Relaxed);

    // Group requests by application: one backend call per app.
    let mut by_app: std::collections::BTreeMap<String, Vec<PredictReq>> =
        std::collections::BTreeMap::new();
    for r in batch {
        by_app.entry(r.app.clone()).or_default().push(r);
    }
    for (app, reqs) in by_app {
        // Resolve (coefficients, version) in one registry read so the
        // whole app-batch is served by a single consistent model even if
        // a publish lands mid-cycle.
        let looked_up = {
            let reg = registry_read(registry, metrics);
            reg.entry(&app).map(|e| (e.model.coeffs, e.version))
        };
        let Some((coeffs, version)) = looked_up else {
            metrics.rejected.fetch_add(reqs.len() as u64, Ordering::Relaxed);
            for r in reqs {
                let _ = r
                    .resp
                    .send(Err(format!("no model for application '{app}'")));
            }
            continue;
        };
        let params: Vec<[f64; 2]> = reqs.iter().map(|r| r.params).collect();
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        match backend_lock(backend, metrics).predict(&coeffs, &params) {
            Ok(preds) => {
                for (r, p) in reqs.into_iter().zip(preds) {
                    let _ =
                        r.resp.send(Ok(Prediction { seconds: p, version }));
                }
            }
            Err(e) => {
                metrics.backend_errors.fetch_add(1, Ordering::Relaxed);
                for r in reqs {
                    let _ = r.resp.send(Err(e.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::features::{evaluate, NUM_FEATURES};
    use crate::model::regression::{RegressionModel, RustSolverBackend};

    fn test_model(app: &str) -> RegressionModel {
        let mut coeffs = [0.0; NUM_FEATURES];
        coeffs[0] = 100.0;
        coeffs[1] = 40.0; // 100 + 40*(m/40) = 100 + m
        coeffs[4] = -8.0;
        RegressionModel { app_name: app.into(), coeffs, trained_on: 20 }
    }

    fn service() -> PredictionService {
        let mut reg = ModelRegistry::new();
        reg.insert(test_model("wordcount"));
        PredictionService::start(
            || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
            reg,
            ServiceConfig::default(),
        )
    }

    #[test]
    fn predicts_through_the_batcher() {
        let svc = service();
        let got = svc.predict("wordcount", 20, 5).unwrap();
        let want = evaluate(&test_model("x").coeffs, &[20.0, 5.0]);
        assert!((got - want).abs() < 1e-12);
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unknown_app_is_error() {
        let svc = service();
        let err = svc.predict("teragen", 10, 10).unwrap_err();
        assert!(err.contains("no model"));
    }

    #[test]
    fn rejected_requests_do_not_inflate_mean_batch() {
        let svc = service();
        svc.predict("teragen", 10, 10).unwrap_err();
        svc.predict("teragen", 12, 10).unwrap_err();
        svc.predict("wordcount", 20, 5).unwrap();
        let m = &svc.metrics;
        assert_eq!(m.requests.load(Ordering::Relaxed), 3);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 2);
        assert_eq!(m.batches.load(Ordering::Relaxed), 1, "rejects cost no backend call");
        // One served request over one batch: the mean must be 1.0, not
        // the 3.0 the old requests/batches ratio reported.
        assert!((m.mean_batch_size() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_requests_coalesce() {
        let svc = service();
        // Fire 200 async requests from this thread, then collect.
        let rxs: Vec<_> = (0..200)
            .map(|i| svc.predict_async("wordcount", 5 + (i % 36), 5).unwrap())
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let got = rx.recv().unwrap().unwrap();
            let m = 5 + (i as u32 % 36);
            let want = evaluate(&test_model("x").coeffs, &[m as f64, 5.0]);
            assert!((got.seconds - want).abs() < 1e-12, "req {i}");
            assert_eq!(got.version, 1);
        }
        let batches = svc.metrics.batches.load(Ordering::Relaxed);
        assert!(batches < 200, "batching must coalesce: {batches} batches");
        assert!(svc.metrics.mean_batch_size() > 1.0);
        assert!(svc.metrics.max_batch_seen.load(Ordering::Relaxed) > 1);
    }

    #[test]
    fn predict_batch_matches_queued_path_bit_for_bit() {
        let svc = service();
        let items: Vec<BatchItem> = (0..50)
            .map(|i| BatchItem {
                app: if i % 5 == 4 { "nope".into() } else { "wordcount".into() },
                mappers: 5 + (i % 36),
                reducers: 5 + (i % 7),
            })
            .collect();
        let batch = svc.predict_batch(&items);
        assert_eq!(batch.len(), items.len());
        for (item, got) in items.iter().zip(&batch) {
            let queued =
                svc.predict_versioned(&item.app, item.mappers, item.reducers);
            match (got, queued) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.seconds.to_bits(), b.seconds.to_bits());
                    assert_eq!(a.version, b.version);
                }
                (Err(a), Err(b)) => assert_eq!(a, &b),
                other => panic!("paths disagree: {other:?}"),
            }
        }
        let m = &svc.metrics;
        // 50 batched + 50 queued requests, 10 rejected per path.
        assert_eq!(m.requests.load(Ordering::Relaxed), 100);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 20);
        assert_eq!(m.max_batch_seen.load(Ordering::Relaxed), 50);
        assert!(m.mean_batch_size() > 1.0);
    }

    #[test]
    fn predict_batch_group_is_version_consistent_across_swap() {
        let svc = service();
        // Whole-batch consistency: one registry read per app group means
        // every item of a group reports the same version.
        let items: Vec<BatchItem> = (0..8)
            .map(|i| BatchItem {
                app: "wordcount".into(),
                mappers: 10 + i,
                reducers: 5,
            })
            .collect();
        let before = svc.predict_batch(&items);
        svc.publish_model(test_model("wordcount"), 0.1);
        let after = svc.predict_batch(&items);
        let v1: Vec<u64> =
            before.iter().map(|r| r.as_ref().unwrap().version).collect();
        let v2: Vec<u64> =
            after.iter().map(|r| r.as_ref().unwrap().version).collect();
        assert!(v1.iter().all(|&v| v == 1), "{v1:?}");
        assert!(v2.iter().all(|&v| v == 2), "{v2:?}");
    }

    #[test]
    fn install_model_takes_effect() {
        let svc = service();
        assert!(svc.predict("grep", 10, 10).is_err());
        svc.install_model(test_model("grep"));
        assert!(svc.predict("grep", 10, 10).is_ok());
        assert_eq!(svc.model_names(), vec!["grep", "wordcount"]);
    }

    #[test]
    fn publish_bumps_served_version() {
        let svc = service();
        let p1 = svc.predict_versioned("wordcount", 20, 5).unwrap();
        assert_eq!(p1.version, 1);
        let mut refit = test_model("wordcount");
        refit.coeffs[0] += 50.0;
        let v = svc.publish_model(refit, 0.25);
        assert_eq!(v, 2);
        let p2 = svc.predict_versioned("wordcount", 20, 5).unwrap();
        assert_eq!(p2.version, 2);
        assert!((p2.seconds - p1.seconds - 50.0).abs() < 1e-9);
        let info = svc.model_info("wordcount").unwrap();
        assert_eq!(info.version, 2);
        assert_eq!(info.fit_rmse, 0.25);
        assert!(svc.model_info("nope").is_none());
    }

    #[test]
    fn poisoned_registry_recovers_and_is_counted() {
        let svc = service();
        // Panic while holding the write lock — the classic poisoner.
        let registry = svc.registry_handle();
        let _ = std::thread::spawn(move || {
            let _guard = registry.write().unwrap();
            panic!("poison the registry lock");
        })
        .join();
        // Every later call recovers instead of panicking ...
        let got = svc.predict("wordcount", 20, 5).unwrap();
        let want = evaluate(&test_model("x").coeffs, &[20.0, 5.0]);
        assert!((got - want).abs() < 1e-12);
        svc.install_model(test_model("grep"));
        assert_eq!(svc.model_names(), vec!["grep", "wordcount"]);
        // ... and the *incident* is counted exactly once: recovery
        // clears the poison, so the later calls above took the clean
        // path instead of re-counting the same panic forever.
        assert_eq!(svc.metrics.lock_poisoned.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn clean_shutdown_on_drop() {
        let svc = service();
        svc.predict("wordcount", 10, 10).unwrap();
        drop(svc); // must not hang
    }
}
