//! TCP server for the prediction service: legacy JSON lines plus the
//! pipelined binary protocol, behind first-byte autodetection.
//!
//! Legacy protocol (v1, one JSON object per line, response per line):
//!
//! ```text
//! -> {"op":"predict","app":"wordcount","mappers":20,"reducers":5}
//! <- {"ok":true,"predicted_s":512.4,"version":1}
//! -> {"op":"predict","app":"sort","mappers":20,"reducers":5,
//!     "target":"shuffle_bytes"}
//! <- {"ok":true,"predicted_s":8.6e9,"version":1,"target":"shuffle_bytes"}
//! -> {"op":"models"}
//! <- {"ok":true,"models":["exim","wordcount"]}
//! -> {"op":"model_info","app":"wordcount"}
//! <- {"ok":true,"app":"wordcount","version":2,"trained_on":20,
//!     "fit_rmse":1.25,"coeffs":[...]}
//! -> {"op":"retrain"}
//! <- {"ok":true,"new_records":180,"refits":[{"app":"grep","version":1}]}
//! -> {"op":"health"}
//! <- {"ok":true,"requests":123,"batches":17,"rejected":0,"shed":0,
//!     "lock_poisoned":0,"mean_batch":7.2}
//! ```
//!
//! Binary protocol (v2, [`super::wire`]): a connection whose first byte
//! is the preamble magic `M` speaks length-prefixed binary frames with
//! **pipelining** — many requests in flight, responses carrying request
//! ids.  Predict frames from every binary connection funnel into one
//! bounded MPSC queue drained by batch workers that resolve whole
//! batches through [`PredictionService::predict_batch`] (one atomic
//! `(coeffs, version)` registry read per app group), and a full queue
//! sheds load with typed SHED frames instead of queueing unboundedly —
//! the `shed` counter in `health` is the observability side of that
//! admission control.  See `docs/OPERATIONS.md` § "Serving at scale".
//!
//! One thread per connection remains the accept model (the request path
//! is bounded by the batch queue, not by connection concurrency at this
//! scale); binary connections additionally get a writer thread so
//! response encoding and `write` syscalls coalesce across pipelined
//! requests.  Finished connection handles are reaped every accept
//! iteration, so the tracked set stays bounded under sustained
//! short-lived traffic.
//!
//! `retrain` drives the online [`Trainer`]: it tails the profile store
//! and hot-swaps refit models into the registry, so a freshly profiled
//! application becomes predictable without restarting the server.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::model::Target;
use crate::util::json::{parse, Json};

use super::service::{BatchItem, Prediction, PredictionService};
use super::trainer::Trainer;
use super::wire;

/// Serving-path tuning knobs (binary-protocol batching + admission
/// control).  Defaults are production-shaped; benches sweep them.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Batch worker threads draining the predict queue.  The default of
    /// one preserves global FIFO batch order, which is what makes
    /// per-connection response versions monotonic across hot-swaps;
    /// more workers raise throughput for slow backends at the cost of
    /// cross-batch ordering.
    pub workers: usize,
    /// Bounded depth of the predict job queue.  When the queue is full,
    /// new predict batches are shed with typed SHED frames (admission
    /// control) rather than queued without bound.
    pub queue_depth: usize,
    /// Most predict requests a connection reader packs into one queued
    /// job (the micro-batch the workers resolve in one registry read).
    pub max_batch: usize,
    /// Artificial delay added before resolving each queued job — fault
    /// injection for benches and tests that need a deterministically
    /// backed-up queue to exercise load shedding.  Zero in production.
    pub batch_delay: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 1,
            queue_depth: 1024,
            max_batch: 512,
            batch_delay: Duration::ZERO,
        }
    }
}

/// One queued unit of server-side micro-batching: the predict requests
/// a connection reader drained in one pass, with the channel its writer
/// thread listens on.
struct BatchJob {
    reply: Sender<WriterMsg>,
    items: Vec<(u64, BatchItem)>,
}

/// Messages a binary connection's writer thread encodes onto the wire.
enum WriterMsg {
    /// Resolved predictions (request id, outcome), one frame each.
    Predicts(Vec<(u64, Result<Prediction, String>)>),
    /// A JSON-op response (request id, JSON text).
    Json(u64, String),
    /// A per-request error that never reached the service.
    Err(u64, String),
    /// Admission control shed these request ids.
    Shed(Vec<u64>),
    /// Terminal: write a GOAWAY frame, then shut the socket down.
    GoAway(String),
}

/// The shared batch queue plus its worker pool.
struct Batcher {
    tx: SyncSender<BatchJob>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Batcher {
    fn start(
        service: Arc<PredictionService>,
        opts: ServeOptions,
    ) -> Batcher {
        let (tx, rx) = sync_channel::<BatchJob>(opts.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..opts.workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let service = Arc::clone(&service);
                std::thread::spawn(move || batch_worker(rx, service, opts))
            })
            .collect();
        Batcher { tx, workers }
    }

    /// Drop the queue sender and join the workers (connection handlers
    /// holding sender clones must already be gone).
    fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Worker loop: take one job (plus whatever else is already queued, up
/// to the batch cap), resolve the combined batch in one
/// [`PredictionService::predict_batch`] call, and fan results back to
/// each connection's writer.
fn batch_worker(
    rx: Arc<Mutex<Receiver<BatchJob>>>,
    service: Arc<PredictionService>,
    opts: ServeOptions,
) {
    loop {
        // Hold the lock only while collecting; blocking recv under the
        // lock is fine — with one waiter per queue at a time, a job
        // wakes the holder, which releases the lock for the next.
        let jobs = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            let first = match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // queue closed: server shutting down
            };
            let mut total: usize = first.items.len();
            let mut jobs = vec![first];
            while total < opts.max_batch {
                match guard.try_recv() {
                    Ok(j) => {
                        total += j.items.len();
                        jobs.push(j);
                    }
                    Err(_) => break,
                }
            }
            jobs
        };
        if !opts.batch_delay.is_zero() {
            std::thread::sleep(opts.batch_delay);
        }
        let items: Vec<BatchItem> = jobs
            .iter()
            .flat_map(|j| j.items.iter().map(|(_, it)| it.clone()))
            .collect();
        let mut results = service.predict_batch(&items).into_iter();
        for job in jobs {
            let replies: Vec<(u64, Result<Prediction, String>)> = job
                .items
                .iter()
                .map(|(id, _)| {
                    let r = results.next().unwrap_or_else(|| {
                        Err("batch result missing (server bug)".to_string())
                    });
                    (*id, r)
                })
                .collect();
            // A dead connection just drops its replies.
            let _ = job.reply.send(WriterMsg::Predicts(replies));
        }
    }
}

/// A running TCP server.
pub struct Server {
    /// The bound address (useful with ephemeral ports).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    live_conns: Arc<AtomicUsize>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    batcher: Option<Batcher>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port) and serve
    /// requests against `service`, with no trainer (`retrain` is an
    /// error).
    pub fn start(
        addr: &str,
        service: Arc<PredictionService>,
    ) -> std::io::Result<Server> {
        Server::start_with(addr, service, None)
    }

    /// [`Server::start`], optionally wiring an online [`Trainer`] so the
    /// `retrain` op can tail the profile store and hot-swap models.
    pub fn start_with(
        addr: &str,
        service: Arc<PredictionService>,
        trainer: Option<Arc<Mutex<Trainer>>>,
    ) -> std::io::Result<Server> {
        Server::start_tuned(addr, service, trainer, ServeOptions::default())
    }

    /// [`Server::start_with`] with explicit serving-path tuning
    /// ([`ServeOptions`]: batch workers, queue depth, shed policy).
    pub fn start_tuned(
        addr: &str,
        service: Arc<PredictionService>,
        trainer: Option<Arc<Mutex<Trainer>>>,
        opts: ServeOptions,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let live_conns = Arc::new(AtomicUsize::new(0));
        let live = Arc::clone(&live_conns);
        let batcher = Batcher::start(Arc::clone(&service), opts);
        let batch_tx = batcher.tx.clone();
        let accept_thread = std::thread::spawn(move || {
            // Poll-accept so shutdown is prompt.
            listener.set_nonblocking(true).ok();
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !accept_stop.load(Ordering::Relaxed) {
                // Reap finished handlers *every* iteration — accepting or
                // idle — so sustained short-lived traffic cannot grow the
                // handle set without bound (it used to grow until
                // shutdown).
                conns.retain(|h| !h.is_finished());
                live.store(conns.len(), Ordering::Relaxed);
                match listener.accept() {
                    Ok((stream, _)) => {
                        let svc = Arc::clone(&service);
                        let tr = trainer.clone();
                        let cstop = Arc::clone(&accept_stop);
                        let btx = batch_tx.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ =
                                handle_conn(stream, svc, tr, cstop, btx, opts);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
            live.store(0, Ordering::Relaxed);
        });
        Ok(Server {
            addr: local,
            stop,
            live_conns,
            accept_thread: Some(accept_thread),
            batcher: Some(batcher),
        })
    }

    /// Connection-handler threads currently tracked by the accept loop
    /// (finished handlers are reaped each iteration).  Observability for
    /// the soak tests and the `serve` CLI.
    pub fn tracked_connections(&self) -> usize {
        self.live_conns.load(Ordering::Relaxed)
    }

    /// Stop accepting, drain connection threads, join the acceptor, and
    /// wind down the batch workers.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // All connection handlers are gone, so the workers' queue drains
        // and closes once the server's own sender drops.
        if let Some(b) = self.batcher.take() {
            b.shutdown();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Largest request line the server buffers.  Real requests are a few
/// hundred bytes; the cap exists so a client streaming bytes with no
/// newline cannot grow a handler's buffer without bound now that
/// partial reads survive timeouts.
const MAX_LINE_BYTES: usize = 64 * 1024;

/// Read one `\n`-terminated request into `buf`, which may already hold
/// a partial line from a previous timeout (partials are preserved, not
/// discarded).  Returns `Ok(true)` with the full line buffered,
/// `Ok(false)` on clean EOF.  A read timeout surfaces as
/// `WouldBlock`/`TimedOut` (caller retries, keeping `buf`); a line past
/// [`MAX_LINE_BYTES`] surfaces as `InvalidData`.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
) -> std::io::Result<bool> {
    loop {
        let available = match reader.fill_buf() {
            Ok(bytes) => bytes,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if available.is_empty() {
            return Ok(false); // client closed
        }
        let newline = available.iter().position(|&b| b == b'\n');
        let take = newline.map(|i| i + 1).unwrap_or(available.len());
        // mrlint: allow(panic_free) — take = newline_pos+1 or len, both ≤ available.len()
        buf.extend_from_slice(&available[..take]);
        reader.consume(take);
        if newline.is_some() {
            return Ok(true);
        }
        if buf.len() > MAX_LINE_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "request line too long",
            ));
        }
    }
}

/// Accept-side dispatch: peek the first byte to pick the protocol —
/// the binary preamble magic (`M`) selects frames, anything else (a
/// JSON object starts with `{`) falls through to the legacy line
/// protocol — then run the matching handler to connection end.
fn handle_conn(
    stream: TcpStream,
    service: Arc<PredictionService>,
    trainer: Option<Arc<Mutex<Trainer>>>,
    stop: Arc<AtomicBool>,
    batch_tx: SyncSender<BatchJob>,
    opts: ServeOptions,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream);
    let first = loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match reader.fill_buf() {
            Ok([]) => return Ok(()), // closed before a single byte
            Ok([first, ..]) => break *first,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::Interrupted
                        | std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    };
    if wire::WIRE_MAGIC.starts_with(&[first]) {
        handle_binary_conn(reader, service, trainer, stop, batch_tx, opts)
    } else {
        handle_json_conn(reader, service, trainer, stop)
    }
}

/// The legacy JSON line protocol, one request per line.
fn handle_json_conn(
    mut reader: BufReader<TcpStream>,
    service: Arc<PredictionService>,
    trainer: Option<Arc<Mutex<Trainer>>>,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    let mut writer = reader.get_ref().try_clone()?;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match read_line_bounded(&mut reader, &mut buf) {
            Ok(false) => return Ok(()), // client closed
            Ok(true) => {
                {
                    let line = String::from_utf8_lossy(&buf);
                    let trimmed = line.trim();
                    if !trimmed.is_empty() {
                        let resp =
                            dispatch(trimmed, &service, trainer.as_deref());
                        writer.write_all(resp.to_string().as_bytes())?;
                        writer.write_all(b"\n")?;
                    }
                }
                // One request fully consumed: only now is it safe to
                // drop the buffer.
                buf.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Read timeout while a request is mid-line: `buf` holds
                // the partial bytes already received, and clearing it
                // here (as this loop once did) silently discarded them —
                // corrupting the stream framing for a slow client.  Keep
                // the partial read; the next pass appends the rest.
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // Oversized request line: answer once, then hang up —
                // the client is outside the protocol.
                let resp = err("request line too long");
                let _ = writer.write_all(resp.to_string().as_bytes());
                let _ = writer.write_all(b"\n");
                return Ok(());
            }
            Err(e) => return Err(e),
        }
    }
}

/// Read exactly `n` bytes through the connection's read timeout,
/// preserving partial progress across timeouts.  `Ok(None)` on EOF.
fn read_exact_timeout(
    reader: &mut BufReader<TcpStream>,
    n: usize,
    stop: &AtomicBool,
) -> std::io::Result<Option<Vec<u8>>> {
    let mut got = Vec::with_capacity(n);
    while got.len() < n {
        if stop.load(Ordering::Relaxed) {
            return Ok(None);
        }
        let available = match reader.fill_buf() {
            Ok(bytes) => bytes,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::Interrupted
                        | std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) => return Err(e),
        };
        if available.is_empty() {
            return Ok(None);
        }
        let take = available.len().min(n - got.len());
        // mrlint: allow(panic_free) — take = min(available.len(), ..) ≤ available.len()
        got.extend_from_slice(&available[..take]);
        reader.consume(take);
    }
    Ok(Some(got))
}

/// The binary frame protocol: validate the preamble, spawn the writer
/// thread, then decode frames — predicts accumulate into micro-batch
/// jobs for the shared queue, JSON ops dispatch inline, corruption ends
/// the connection with a typed GOAWAY.
fn handle_binary_conn(
    mut reader: BufReader<TcpStream>,
    service: Arc<PredictionService>,
    trainer: Option<Arc<Mutex<Trainer>>>,
    stop: Arc<AtomicBool>,
    batch_tx: SyncSender<BatchJob>,
    opts: ServeOptions,
) -> std::io::Result<()> {
    let mut stream = reader.get_ref().try_clone()?;
    let preamble = match read_exact_timeout(
        &mut reader,
        wire::PREAMBLE_LEN,
        &stop,
    )? {
        Some(b) => b,
        None => return Ok(()),
    };
    // read_exact_timeout returned Some, so exactly PREAMBLE_LEN bytes;
    // a length mismatch is unreachable, treated as a silent hangup.
    let arr: [u8; wire::PREAMBLE_LEN] = match preamble.as_slice().try_into() {
        Ok(arr) => arr,
        Err(_) => return Ok(()),
    };
    if let Err(e) = wire::check_preamble(&arr) {
        // No writer thread yet: answer the bad handshake directly.
        let mut buf = Vec::new();
        wire::encode_goaway(&mut buf, &e.to_string());
        let _ = stream.write_all(&buf);
        return Ok(());
    }

    let (tx, rx) = std::sync::mpsc::channel::<WriterMsg>();
    let writer_thread = std::thread::spawn(move || writer_loop(stream, rx));

    let mut frames = wire::FrameReader::new();
    let mut pending: Vec<(u64, BatchItem)> = Vec::new();
    'conn: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // One read syscall can deliver many pipelined frames; drain them
        // all, then flush the accumulated predict batch as one job.
        let available = match reader.fill_buf() {
            Ok(bytes) => bytes,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::Interrupted
                        | std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue
            }
            Err(e) => {
                drop(tx);
                let _ = writer_thread.join();
                return Err(e);
            }
        };
        if available.is_empty() {
            break; // client closed
        }
        frames.feed(available);
        let consumed = available.len();
        reader.consume(consumed);
        loop {
            match frames.next_frame() {
                Ok(None) => break,
                Ok(Some(frame)) => {
                    if !handle_frame(
                        frame,
                        &service,
                        trainer.as_deref(),
                        &tx,
                        &mut pending,
                    ) {
                        break 'conn;
                    }
                    if pending.len() >= opts.max_batch {
                        submit_batch(
                            &batch_tx,
                            &tx,
                            &service,
                            &mut pending,
                        );
                    }
                }
                Err(e) => {
                    // Framing is unrecoverable: flush what parsed, then
                    // say goodbye with the typed frame the JSON protocol
                    // never had.
                    submit_batch(&batch_tx, &tx, &service, &mut pending);
                    let _ = tx.send(WriterMsg::GoAway(e.to_string()));
                    break 'conn;
                }
            }
        }
        submit_batch(&batch_tx, &tx, &service, &mut pending);
    }
    submit_batch(&batch_tx, &tx, &service, &mut pending);
    drop(tx);
    let _ = writer_thread.join();
    Ok(())
}

/// Route one decoded frame.  Returns `false` when the connection must
/// end (protocol misuse answered with GOAWAY).
fn handle_frame(
    frame: wire::Frame,
    service: &PredictionService,
    trainer: Option<&Mutex<Trainer>>,
    tx: &Sender<WriterMsg>,
    pending: &mut Vec<(u64, BatchItem)>,
) -> bool {
    match frame.tag {
        wire::REQ_PREDICT => match wire::decode_predict_req(&frame.body) {
            Ok((app, mappers, reducers)) => {
                pending.push((frame.id, BatchItem { app, mappers, reducers }));
            }
            Err(e) => {
                // Malformed body with intact framing: the error is
                // isolated to this request.
                let _ = tx.send(WriterMsg::Err(frame.id, e.to_string()));
            }
        },
        wire::REQ_JSON => {
            // Control-plane ops ride the legacy dispatcher; they are
            // rare and never block the predict queue.
            let resp = match std::str::from_utf8(&frame.body) {
                Ok(text) => dispatch(text.trim(), service, trainer),
                Err(_) => err("json op body is not UTF-8"),
            };
            let _ = tx.send(WriterMsg::Json(frame.id, resp.to_string()));
        }
        _ => {
            // A response tag sent at the server: protocol misuse.
            let _ = tx.send(WriterMsg::GoAway(format!(
                "client sent response tag {:#04x}",
                frame.tag
            )));
            return false;
        }
    }
    true
}

/// Enqueue the pending predict batch; a full queue sheds the whole job
/// with typed SHED frames and counts it (admission control).
fn submit_batch(
    batch_tx: &SyncSender<BatchJob>,
    reply: &Sender<WriterMsg>,
    service: &PredictionService,
    pending: &mut Vec<(u64, BatchItem)>,
) {
    if pending.is_empty() {
        return;
    }
    let items = std::mem::take(pending);
    match batch_tx
        .try_send(BatchJob { reply: reply.clone(), items })
    {
        Ok(()) => {}
        Err(TrySendError::Full(job)) => {
            service
                .metrics
                .shed
                .fetch_add(job.items.len() as u64, Ordering::Relaxed);
            let ids = job.items.iter().map(|(id, _)| *id).collect();
            let _ = reply.send(WriterMsg::Shed(ids));
        }
        Err(TrySendError::Disconnected(job)) => {
            // Server shutting down: answer what we can, typed.
            let ids = job.items.iter().map(|(id, _)| *id).collect();
            let _ = reply.send(WriterMsg::Shed(ids));
        }
    }
}

/// Writer thread: encode queued response messages, coalescing every
/// already-queued message into one buffer per `write` syscall.
fn writer_loop(mut stream: TcpStream, rx: Receiver<WriterMsg>) {
    let mut buf: Vec<u8> = Vec::with_capacity(16 * 1024);
    'out: while let Ok(first) = rx.recv() {
        buf.clear();
        let mut done = encode_msg(&mut buf, first);
        while !done {
            match rx.try_recv() {
                Ok(msg) => done = encode_msg(&mut buf, msg),
                Err(_) => break,
            }
        }
        if stream.write_all(&buf).is_err() {
            break 'out;
        }
        if done {
            break 'out;
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
}

/// Encode one writer message into `buf`; returns `true` for terminal
/// messages (GOAWAY), after which the connection closes.
fn encode_msg(buf: &mut Vec<u8>, msg: WriterMsg) -> bool {
    match msg {
        WriterMsg::Predicts(replies) => {
            for (id, outcome) in replies {
                match outcome {
                    Ok(p) => wire::encode_predict_ok(buf, id, &p),
                    Err(e) => wire::encode_err(buf, id, &e),
                }
            }
            false
        }
        WriterMsg::Json(id, text) => {
            wire::encode_json_ok(buf, id, &text);
            false
        }
        WriterMsg::Err(id, msg) => {
            wire::encode_err(buf, id, &msg);
            false
        }
        WriterMsg::Shed(ids) => {
            for id in ids {
                wire::encode_shed(buf, id);
            }
            false
        }
        WriterMsg::GoAway(reason) => {
            wire::encode_goaway(buf, &reason);
            true
        }
    }
}

fn err(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// Handle one request line (exposed for unit testing without sockets).
pub fn dispatch(
    line: &str,
    service: &PredictionService,
    trainer: Option<&Mutex<Trainer>>,
) -> Json {
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return err(&format!("bad json: {e}")),
    };
    match req.get("op").and_then(|o| o.as_str()) {
        Some("predict") => {
            let app = match req.get("app").and_then(|a| a.as_str()) {
                Some(a) => a,
                None => return err("predict requires 'app'"),
            };
            let m = req.get("mappers").and_then(|v| v.as_u64());
            let r = req.get("reducers").and_then(|v| v.as_u64());
            let (Some(m), Some(r)) = (m, r) else {
                return err("predict requires integer 'mappers' and 'reducers'");
            };
            // Optional multi-target selector: "target" names which of
            // the app's models answers, resolving to the same registry
            // entries the qualified-name path serves.  Absent means the
            // legacy time model — byte-for-byte the pre-multi-target
            // request and response.
            let target = match req.get("target").and_then(|t| t.as_str()) {
                None => None,
                Some(t) => match Target::parse(t) {
                    Ok(t) => Some(t),
                    Err(e) => return err(&e),
                },
            };
            let name = match target {
                Some(t) => t.qualified(app),
                None => app.to_string(),
            };
            // The same atomic (coeffs, version) batch path the binary
            // protocol's workers use — both protocols answer any predict
            // with exactly the same bits.
            let item =
                BatchItem { app: name, mappers: m as u32, reducers: r as u32 };
            match service.predict_batch(std::slice::from_ref(&item)).remove(0)
            {
                Ok(p) => {
                    let mut pairs = vec![
                        ("ok", Json::Bool(true)),
                        ("predicted_s", Json::Num(p.seconds)),
                        ("version", Json::Num(p.version as f64)),
                    ];
                    if let Some(t) = target {
                        pairs.push(("target", Json::Str(t.name().into())));
                    }
                    Json::obj(pairs)
                }
                Err(e) => err(&e),
            }
        }
        Some("models") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(
                    service.model_names().into_iter().map(Json::Str).collect(),
                ),
            ),
        ]),
        Some("model_info") => {
            let app = match req.get("app").and_then(|a| a.as_str()) {
                Some(a) => a,
                None => return err("model_info requires 'app'"),
            };
            match service.model_info(app) {
                None => err(&format!("no model for application '{app}'")),
                Some(entry) => {
                    let mut pairs = vec![
                        ("ok", Json::Bool(true)),
                        ("app", Json::Str(entry.model.app_name.clone())),
                        ("version", Json::Num(entry.version as f64)),
                        (
                            "trained_on",
                            Json::Num(entry.model.trained_on as f64),
                        ),
                        ("coeffs", Json::from_f64_slice(&entry.model.coeffs)),
                    ];
                    if entry.fit_rmse.is_finite() {
                        pairs.push(("fit_rmse", Json::Num(entry.fit_rmse)));
                    }
                    Json::obj(pairs)
                }
            }
        }
        Some("retrain") => match trainer {
            None => err(
                "no trainer attached (start the server with a profile store)",
            ),
            Some(t) => {
                // Recover from poison: the trainer's state is a plain
                // map of reps, safe to reuse after a panicked poll.
                let mut tr = match t.lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
                match tr.retrain(service) {
                    Ok(summary) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        (
                            "new_records",
                            Json::Num(summary.new_records as f64),
                        ),
                        (
                            "refits",
                            Json::Arr(
                                summary
                                    .published
                                    .iter()
                                    .map(|(name, version)| {
                                        Json::obj(vec![
                                            (
                                                "app",
                                                Json::Str(name.clone()),
                                            ),
                                            (
                                                "version",
                                                Json::Num(*version as f64),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                    Err(e) => err(&format!("retrain failed: {e}")),
                }
            }
        },
        Some("health") => {
            let m = &service.metrics;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                (
                    "requests",
                    Json::Num(m.requests.load(Ordering::Relaxed) as f64),
                ),
                (
                    "batches",
                    Json::Num(m.batches.load(Ordering::Relaxed) as f64),
                ),
                (
                    "rejected",
                    Json::Num(m.rejected.load(Ordering::Relaxed) as f64),
                ),
                ("shed", Json::Num(m.shed.load(Ordering::Relaxed) as f64)),
                (
                    "lock_poisoned",
                    Json::Num(m.lock_poisoned.load(Ordering::Relaxed) as f64),
                ),
                ("mean_batch", Json::Num(m.mean_batch_size())),
            ];
            // With a store-backed trainer attached, report how the
            // profile store is sharded (poisoned lock: field omitted;
            // the retrain path owns poison recovery).
            if let Some(t) = trainer {
                if let Ok(t) = t.lock() {
                    fields.push((
                        "store_shards",
                        Json::Num(t.store_shards() as f64),
                    ));
                }
            }
            Json::obj(fields)
        }
        Some(other) => err(&format!("unknown op '{other}'")),
        None => err("missing 'op'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::ModelRegistry;
    use crate::coordinator::service::ServiceConfig;
    use crate::model::features::NUM_FEATURES;
    use crate::model::regression::{FitBackend, RegressionModel, RustSolverBackend};

    fn service() -> PredictionService {
        let mut reg = ModelRegistry::new();
        reg.insert(RegressionModel {
            app_name: "wordcount".into(),
            coeffs: {
                let mut c = [0.0; NUM_FEATURES];
                c[0] = 400.0;
                c
            },
            trained_on: 20,
        });
        PredictionService::start(
            || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
            reg,
            ServiceConfig::default(),
        )
    }

    #[test]
    fn dispatch_predict() {
        let svc = service();
        let resp = dispatch(
            r#"{"op":"predict","app":"wordcount","mappers":20,"reducers":5}"#,
            &svc,
            None,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("predicted_s").unwrap().as_f64(), Some(400.0));
        assert_eq!(resp.get("version").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn dispatch_errors() {
        let svc = service();
        assert_eq!(
            dispatch("not json", &svc, None).get("ok").unwrap().as_bool(),
            Some(false)
        );
        assert_eq!(
            dispatch(
                r#"{"op":"predict","app":"nope","mappers":1,"reducers":1}"#,
                &svc,
                None
            )
            .get("ok")
            .unwrap()
            .as_bool(),
            Some(false)
        );
        let e = dispatch(r#"{"op":"predict","app":"wordcount"}"#, &svc, None);
        assert!(e.get("error").unwrap().as_str().unwrap().contains("mappers"));
        assert_eq!(
            dispatch(r#"{"op":"explode"}"#, &svc, None)
                .get("ok")
                .unwrap()
                .as_bool(),
            Some(false)
        );
    }

    #[test]
    fn dispatch_models_and_health() {
        let svc = service();
        let m = dispatch(r#"{"op":"models"}"#, &svc, None);
        assert_eq!(
            m.get("models").unwrap().as_arr().unwrap()[0].as_str(),
            Some("wordcount")
        );
        svc.predict("wordcount", 10, 10).unwrap();
        let h = dispatch(r#"{"op":"health"}"#, &svc, None);
        assert!(h.get("requests").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(h.get("lock_poisoned").unwrap().as_f64(), Some(0.0));
        assert_eq!(h.get("shed").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn dispatch_model_info() {
        let svc = service();
        let info =
            dispatch(r#"{"op":"model_info","app":"wordcount"}"#, &svc, None);
        assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(info.get("version").unwrap().as_u64(), Some(1));
        assert_eq!(info.get("trained_on").unwrap().as_u64(), Some(20));
        assert_eq!(
            info.get("coeffs").unwrap().as_arr().unwrap().len(),
            NUM_FEATURES
        );
        // Unknown RMSE (installed, not refit) is omitted, not NaN.
        assert!(info.get("fit_rmse").is_none());
        let missing =
            dispatch(r#"{"op":"model_info","app":"nope"}"#, &svc, None);
        assert_eq!(missing.get("ok").unwrap().as_bool(), Some(false));
        let noapp = dispatch(r#"{"op":"model_info"}"#, &svc, None);
        assert!(noapp
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("app"));
    }

    #[test]
    fn dispatch_retrain_without_trainer_is_error() {
        let svc = service();
        let resp = dispatch(r#"{"op":"retrain"}"#, &svc, None);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert!(resp
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("no trainer"));
    }
}
