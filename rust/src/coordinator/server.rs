//! Line-delimited JSON TCP server for the prediction service.
//!
//! Protocol (one JSON object per line, response per line):
//!
//! ```text
//! -> {"op":"predict","app":"wordcount","mappers":20,"reducers":5}
//! <- {"ok":true,"predicted_s":512.4}
//! -> {"op":"models"}
//! <- {"ok":true,"models":["exim","wordcount"]}
//! -> {"op":"health"}
//! <- {"ok":true,"requests":123,"batches":17,"mean_batch":7.2}
//! ```
//!
//! One thread per connection (the request path is bounded by the batcher,
//! not by connection concurrency at this scale).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::json::{parse, Json};

use super::service::PredictionService;

/// A running TCP server.
pub struct Server {
    /// The bound address (useful with ephemeral ports).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port) and serve
    /// requests against `service`.
    pub fn start(addr: &str, service: Arc<PredictionService>) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let accept_thread = std::thread::spawn(move || {
            // Poll-accept so shutdown is prompt.
            listener.set_nonblocking(true).ok();
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !accept_stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let svc = Arc::clone(&service);
                        let cstop = Arc::clone(&accept_stop);
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, svc, cstop);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// Stop accepting, drain connection threads, and join the acceptor.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Arc<PredictionService>,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                if line.trim().is_empty() {
                    continue;
                }
                let resp = dispatch(line.trim(), &service);
                writer.write_all(resp.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
    }
}

fn err(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// Handle one request line (exposed for unit testing without sockets).
pub fn dispatch(line: &str, service: &PredictionService) -> Json {
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return err(&format!("bad json: {e}")),
    };
    match req.get("op").and_then(|o| o.as_str()) {
        Some("predict") => {
            let app = match req.get("app").and_then(|a| a.as_str()) {
                Some(a) => a,
                None => return err("predict requires 'app'"),
            };
            let m = req.get("mappers").and_then(|v| v.as_u64());
            let r = req.get("reducers").and_then(|v| v.as_u64());
            let (Some(m), Some(r)) = (m, r) else {
                return err("predict requires integer 'mappers' and 'reducers'");
            };
            match service.predict(app, m as u32, r as u32) {
                Ok(p) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("predicted_s", Json::Num(p)),
                ]),
                Err(e) => err(&e),
            }
        }
        Some("models") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(
                    service.model_names().into_iter().map(Json::Str).collect(),
                ),
            ),
        ]),
        Some("health") => {
            let m = &service.metrics;
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "requests",
                    Json::Num(m.requests.load(Ordering::Relaxed) as f64),
                ),
                (
                    "batches",
                    Json::Num(m.batches.load(Ordering::Relaxed) as f64),
                ),
                (
                    "rejected",
                    Json::Num(m.rejected.load(Ordering::Relaxed) as f64),
                ),
                ("mean_batch", Json::Num(m.mean_batch_size())),
            ])
        }
        Some(other) => err(&format!("unknown op '{other}'")),
        None => err("missing 'op'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::ModelRegistry;
    use crate::coordinator::service::ServiceConfig;
    use crate::model::features::NUM_FEATURES;
    use crate::model::regression::{FitBackend, RegressionModel, RustSolverBackend};

    fn service() -> PredictionService {
        let mut reg = ModelRegistry::new();
        reg.insert(RegressionModel {
            app_name: "wordcount".into(),
            coeffs: {
                let mut c = [0.0; NUM_FEATURES];
                c[0] = 400.0;
                c
            },
            trained_on: 20,
        });
        PredictionService::start(
            || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
            reg,
            ServiceConfig::default(),
        )
    }

    #[test]
    fn dispatch_predict() {
        let svc = service();
        let resp = dispatch(
            r#"{"op":"predict","app":"wordcount","mappers":20,"reducers":5}"#,
            &svc,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("predicted_s").unwrap().as_f64(), Some(400.0));
    }

    #[test]
    fn dispatch_errors() {
        let svc = service();
        assert_eq!(
            dispatch("not json", &svc).get("ok").unwrap().as_bool(),
            Some(false)
        );
        assert_eq!(
            dispatch(r#"{"op":"predict","app":"nope","mappers":1,"reducers":1}"#, &svc)
                .get("ok")
                .unwrap()
                .as_bool(),
            Some(false)
        );
        let e = dispatch(r#"{"op":"predict","app":"wordcount"}"#, &svc);
        assert!(e.get("error").unwrap().as_str().unwrap().contains("mappers"));
        assert_eq!(
            dispatch(r#"{"op":"explode"}"#, &svc).get("ok").unwrap().as_bool(),
            Some(false)
        );
    }

    #[test]
    fn dispatch_models_and_health() {
        let svc = service();
        let m = dispatch(r#"{"op":"models"}"#, &svc);
        assert_eq!(
            m.get("models").unwrap().as_arr().unwrap()[0].as_str(),
            Some("wordcount")
        );
        svc.predict("wordcount", 10, 10).unwrap();
        let h = dispatch(r#"{"op":"health"}"#, &svc);
        assert!(h.get("requests").unwrap().as_f64().unwrap() >= 1.0);
    }
}
