//! Line-delimited JSON TCP server for the prediction service.
//!
//! Protocol (one JSON object per line, response per line):
//!
//! ```text
//! -> {"op":"predict","app":"wordcount","mappers":20,"reducers":5}
//! <- {"ok":true,"predicted_s":512.4,"version":1}
//! -> {"op":"models"}
//! <- {"ok":true,"models":["exim","wordcount"]}
//! -> {"op":"model_info","app":"wordcount"}
//! <- {"ok":true,"app":"wordcount","version":2,"trained_on":20,
//!     "fit_rmse":1.25,"coeffs":[...]}
//! -> {"op":"retrain"}
//! <- {"ok":true,"new_records":180,"refits":[{"app":"grep","version":1}]}
//! -> {"op":"health"}
//! <- {"ok":true,"requests":123,"batches":17,"rejected":0,
//!     "lock_poisoned":0,"mean_batch":7.2}
//! ```
//!
//! One thread per connection (the request path is bounded by the batcher,
//! not by connection concurrency at this scale).  Finished connection
//! handles are reaped every accept iteration, so the tracked set stays
//! bounded under sustained short-lived traffic.
//!
//! `retrain` drives the online [`Trainer`]: it tails the profile store
//! and hot-swaps refit models into the registry, so a freshly profiled
//! application becomes predictable without restarting the server.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::{parse, Json};

use super::service::PredictionService;
use super::trainer::Trainer;

/// A running TCP server.
pub struct Server {
    /// The bound address (useful with ephemeral ports).
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    live_conns: Arc<AtomicUsize>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port) and serve
    /// requests against `service`, with no trainer (`retrain` is an
    /// error).
    pub fn start(
        addr: &str,
        service: Arc<PredictionService>,
    ) -> std::io::Result<Server> {
        Server::start_with(addr, service, None)
    }

    /// [`Server::start`], optionally wiring an online [`Trainer`] so the
    /// `retrain` op can tail the profile store and hot-swap models.
    pub fn start_with(
        addr: &str,
        service: Arc<PredictionService>,
        trainer: Option<Arc<Mutex<Trainer>>>,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let live_conns = Arc::new(AtomicUsize::new(0));
        let live = Arc::clone(&live_conns);
        let accept_thread = std::thread::spawn(move || {
            // Poll-accept so shutdown is prompt.
            listener.set_nonblocking(true).ok();
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !accept_stop.load(Ordering::Relaxed) {
                // Reap finished handlers *every* iteration — accepting or
                // idle — so sustained short-lived traffic cannot grow the
                // handle set without bound (it used to grow until
                // shutdown).
                conns.retain(|h| !h.is_finished());
                live.store(conns.len(), Ordering::Relaxed);
                match listener.accept() {
                    Ok((stream, _)) => {
                        let svc = Arc::clone(&service);
                        let tr = trainer.clone();
                        let cstop = Arc::clone(&accept_stop);
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, svc, tr, cstop);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
            live.store(0, Ordering::Relaxed);
        });
        Ok(Server {
            addr: local,
            stop,
            live_conns,
            accept_thread: Some(accept_thread),
        })
    }

    /// Connection-handler threads currently tracked by the accept loop
    /// (finished handlers are reaped each iteration).  Observability for
    /// the soak tests and the `serve` CLI.
    pub fn tracked_connections(&self) -> usize {
        self.live_conns.load(Ordering::Relaxed)
    }

    /// Stop accepting, drain connection threads, and join the acceptor.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Largest request line the server buffers.  Real requests are a few
/// hundred bytes; the cap exists so a client streaming bytes with no
/// newline cannot grow a handler's buffer without bound now that
/// partial reads survive timeouts.
const MAX_LINE_BYTES: usize = 64 * 1024;

/// Read one `\n`-terminated request into `buf`, which may already hold
/// a partial line from a previous timeout (partials are preserved, not
/// discarded).  Returns `Ok(true)` with the full line buffered,
/// `Ok(false)` on clean EOF.  A read timeout surfaces as
/// `WouldBlock`/`TimedOut` (caller retries, keeping `buf`); a line past
/// [`MAX_LINE_BYTES`] surfaces as `InvalidData`.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
) -> std::io::Result<bool> {
    loop {
        let available = match reader.fill_buf() {
            Ok(bytes) => bytes,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if available.is_empty() {
            return Ok(false); // client closed
        }
        let newline = available.iter().position(|&b| b == b'\n');
        let take = newline.map(|i| i + 1).unwrap_or(available.len());
        buf.extend_from_slice(&available[..take]);
        reader.consume(take);
        if newline.is_some() {
            return Ok(true);
        }
        if buf.len() > MAX_LINE_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "request line too long",
            ));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    service: Arc<PredictionService>,
    trainer: Option<Arc<Mutex<Trainer>>>,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match read_line_bounded(&mut reader, &mut buf) {
            Ok(false) => return Ok(()), // client closed
            Ok(true) => {
                {
                    let line = String::from_utf8_lossy(&buf);
                    let trimmed = line.trim();
                    if !trimmed.is_empty() {
                        let resp =
                            dispatch(trimmed, &service, trainer.as_deref());
                        writer.write_all(resp.to_string().as_bytes())?;
                        writer.write_all(b"\n")?;
                    }
                }
                // One request fully consumed: only now is it safe to
                // drop the buffer.
                buf.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Read timeout while a request is mid-line: `buf` holds
                // the partial bytes already received, and clearing it
                // here (as this loop once did) silently discarded them —
                // corrupting the stream framing for a slow client.  Keep
                // the partial read; the next pass appends the rest.
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // Oversized request line: answer once, then hang up —
                // the client is outside the protocol.
                let resp = err("request line too long");
                let _ = writer.write_all(resp.to_string().as_bytes());
                let _ = writer.write_all(b"\n");
                return Ok(());
            }
            Err(e) => return Err(e),
        }
    }
}

fn err(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// Handle one request line (exposed for unit testing without sockets).
pub fn dispatch(
    line: &str,
    service: &PredictionService,
    trainer: Option<&Mutex<Trainer>>,
) -> Json {
    let req = match parse(line) {
        Ok(v) => v,
        Err(e) => return err(&format!("bad json: {e}")),
    };
    match req.get("op").and_then(|o| o.as_str()) {
        Some("predict") => {
            let app = match req.get("app").and_then(|a| a.as_str()) {
                Some(a) => a,
                None => return err("predict requires 'app'"),
            };
            let m = req.get("mappers").and_then(|v| v.as_u64());
            let r = req.get("reducers").and_then(|v| v.as_u64());
            let (Some(m), Some(r)) = (m, r) else {
                return err("predict requires integer 'mappers' and 'reducers'");
            };
            match service.predict_versioned(app, m as u32, r as u32) {
                Ok(p) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("predicted_s", Json::Num(p.seconds)),
                    ("version", Json::Num(p.version as f64)),
                ]),
                Err(e) => err(&e),
            }
        }
        Some("models") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(
                    service.model_names().into_iter().map(Json::Str).collect(),
                ),
            ),
        ]),
        Some("model_info") => {
            let app = match req.get("app").and_then(|a| a.as_str()) {
                Some(a) => a,
                None => return err("model_info requires 'app'"),
            };
            match service.model_info(app) {
                None => err(&format!("no model for application '{app}'")),
                Some(entry) => {
                    let mut pairs = vec![
                        ("ok", Json::Bool(true)),
                        ("app", Json::Str(entry.model.app_name.clone())),
                        ("version", Json::Num(entry.version as f64)),
                        (
                            "trained_on",
                            Json::Num(entry.model.trained_on as f64),
                        ),
                        ("coeffs", Json::from_f64_slice(&entry.model.coeffs)),
                    ];
                    if entry.fit_rmse.is_finite() {
                        pairs.push(("fit_rmse", Json::Num(entry.fit_rmse)));
                    }
                    Json::obj(pairs)
                }
            }
        }
        Some("retrain") => match trainer {
            None => err(
                "no trainer attached (start the server with a profile store)",
            ),
            Some(t) => {
                // Recover from poison: the trainer's state is a plain
                // map of reps, safe to reuse after a panicked poll.
                let mut tr = match t.lock() {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
                match tr.retrain(service) {
                    Ok(summary) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        (
                            "new_records",
                            Json::Num(summary.new_records as f64),
                        ),
                        (
                            "refits",
                            Json::Arr(
                                summary
                                    .published
                                    .iter()
                                    .map(|(app, version)| {
                                        Json::obj(vec![
                                            (
                                                "app",
                                                Json::Str(
                                                    app.name().to_string(),
                                                ),
                                            ),
                                            (
                                                "version",
                                                Json::Num(*version as f64),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                    Err(e) => err(&format!("retrain failed: {e}")),
                }
            }
        },
        Some("health") => {
            let m = &service.metrics;
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "requests",
                    Json::Num(m.requests.load(Ordering::Relaxed) as f64),
                ),
                (
                    "batches",
                    Json::Num(m.batches.load(Ordering::Relaxed) as f64),
                ),
                (
                    "rejected",
                    Json::Num(m.rejected.load(Ordering::Relaxed) as f64),
                ),
                (
                    "lock_poisoned",
                    Json::Num(m.lock_poisoned.load(Ordering::Relaxed) as f64),
                ),
                ("mean_batch", Json::Num(m.mean_batch_size())),
            ])
        }
        Some(other) => err(&format!("unknown op '{other}'")),
        None => err("missing 'op'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::ModelRegistry;
    use crate::coordinator::service::ServiceConfig;
    use crate::model::features::NUM_FEATURES;
    use crate::model::regression::{FitBackend, RegressionModel, RustSolverBackend};

    fn service() -> PredictionService {
        let mut reg = ModelRegistry::new();
        reg.insert(RegressionModel {
            app_name: "wordcount".into(),
            coeffs: {
                let mut c = [0.0; NUM_FEATURES];
                c[0] = 400.0;
                c
            },
            trained_on: 20,
        });
        PredictionService::start(
            || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
            reg,
            ServiceConfig::default(),
        )
    }

    #[test]
    fn dispatch_predict() {
        let svc = service();
        let resp = dispatch(
            r#"{"op":"predict","app":"wordcount","mappers":20,"reducers":5}"#,
            &svc,
            None,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(resp.get("predicted_s").unwrap().as_f64(), Some(400.0));
        assert_eq!(resp.get("version").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn dispatch_errors() {
        let svc = service();
        assert_eq!(
            dispatch("not json", &svc, None).get("ok").unwrap().as_bool(),
            Some(false)
        );
        assert_eq!(
            dispatch(
                r#"{"op":"predict","app":"nope","mappers":1,"reducers":1}"#,
                &svc,
                None
            )
            .get("ok")
            .unwrap()
            .as_bool(),
            Some(false)
        );
        let e = dispatch(r#"{"op":"predict","app":"wordcount"}"#, &svc, None);
        assert!(e.get("error").unwrap().as_str().unwrap().contains("mappers"));
        assert_eq!(
            dispatch(r#"{"op":"explode"}"#, &svc, None)
                .get("ok")
                .unwrap()
                .as_bool(),
            Some(false)
        );
    }

    #[test]
    fn dispatch_models_and_health() {
        let svc = service();
        let m = dispatch(r#"{"op":"models"}"#, &svc, None);
        assert_eq!(
            m.get("models").unwrap().as_arr().unwrap()[0].as_str(),
            Some("wordcount")
        );
        svc.predict("wordcount", 10, 10).unwrap();
        let h = dispatch(r#"{"op":"health"}"#, &svc, None);
        assert!(h.get("requests").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(h.get("lock_poisoned").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn dispatch_model_info() {
        let svc = service();
        let info =
            dispatch(r#"{"op":"model_info","app":"wordcount"}"#, &svc, None);
        assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(info.get("version").unwrap().as_u64(), Some(1));
        assert_eq!(info.get("trained_on").unwrap().as_u64(), Some(20));
        assert_eq!(
            info.get("coeffs").unwrap().as_arr().unwrap().len(),
            NUM_FEATURES
        );
        // Unknown RMSE (installed, not refit) is omitted, not NaN.
        assert!(info.get("fit_rmse").is_none());
        let missing =
            dispatch(r#"{"op":"model_info","app":"nope"}"#, &svc, None);
        assert_eq!(missing.get("ok").unwrap().as_bool(), Some(false));
        let noapp = dispatch(r#"{"op":"model_info"}"#, &svc, None);
        assert!(noapp
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("app"));
    }

    #[test]
    fn dispatch_retrain_without_trainer_is_error() {
        let svc = service();
        let resp = dispatch(r#"{"op":"retrain"}"#, &svc, None);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert!(resp
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("no trainer"));
    }
}
