//! Per-node runtime state: slot occupancy.

use super::spec::NodeSpec;

/// Node index within its cluster.
pub type NodeId = usize;

/// A worker node: immutable spec plus live slot accounting.
#[derive(Clone, Debug)]
pub struct Node {
    /// Index within the cluster's node list.
    pub id: NodeId,
    /// Immutable hardware description.
    pub spec: NodeSpec,
    /// Map slots currently running a task.
    pub busy_map_slots: u32,
    /// Reduce slots currently running a task.
    pub busy_reduce_slots: u32,
}

impl Node {
    /// Fresh node with all slots free.
    pub fn new(id: NodeId, spec: NodeSpec) -> Node {
        Node { id, spec, busy_map_slots: 0, busy_reduce_slots: 0 }
    }

    /// Map slots available right now.
    pub fn free_map_slots(&self) -> u32 {
        self.spec.map_slots - self.busy_map_slots
    }

    /// Reduce slots available right now.
    pub fn free_reduce_slots(&self) -> u32 {
        self.spec.reduce_slots - self.busy_reduce_slots
    }

    /// Occupy one map slot (panics on overdraw — a scheduler bug).
    pub fn take_map_slot(&mut self) {
        assert!(self.free_map_slots() > 0, "no free map slot on node {}", self.id);
        self.busy_map_slots += 1;
    }

    /// Free one map slot (panics on underflow — a scheduler bug).
    pub fn release_map_slot(&mut self) {
        assert!(self.busy_map_slots > 0, "map slot underflow on node {}", self.id);
        self.busy_map_slots -= 1;
    }

    /// Occupy one reduce slot (panics on overdraw — a scheduler bug).
    pub fn take_reduce_slot(&mut self) {
        assert!(self.free_reduce_slots() > 0, "no free reduce slot on node {}", self.id);
        self.busy_reduce_slots += 1;
    }

    /// Free one reduce slot (panics on underflow — a scheduler bug).
    pub fn release_reduce_slot(&mut self) {
        assert!(self.busy_reduce_slots > 0, "reduce slot underflow on node {}", self.id);
        self.busy_reduce_slots -= 1;
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::Cluster;

    #[test]
    fn slot_accounting() {
        let c = Cluster::paper_cluster();
        let mut n = c.nodes[0].clone();
        assert_eq!(n.free_map_slots(), 2);
        n.take_map_slot();
        n.take_map_slot();
        assert_eq!(n.free_map_slots(), 0);
        n.release_map_slot();
        assert_eq!(n.free_map_slots(), 1);
    }

    #[test]
    #[should_panic(expected = "no free map slot")]
    fn overdraw_panics() {
        let c = Cluster::paper_cluster();
        let mut n = c.nodes[0].clone();
        n.take_map_slot();
        n.take_map_slot();
        n.take_map_slot();
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn underflow_panics() {
        let c = Cluster::paper_cluster();
        let mut n = c.nodes[0].clone();
        n.release_reduce_slot();
    }
}
