//! Static node hardware description.

/// Hardware spec of one worker node (paper §V.A values in
/// [`crate::cluster::Cluster::paper_cluster`]).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSpec {
    /// Human-readable label (diagnostics only).
    pub name: String,
    /// CPU clock — the paper's primary heterogeneity axis; task CPU cost
    /// scales as `work / cpu_ghz`.
    pub cpu_ghz: f64,
    /// Physical RAM.
    pub ram_bytes: u64,
    /// Local disk capacity.
    pub disk_bytes: u64,
    /// CPU cache size (paper reports it per node; minor cost-model input).
    pub cache_kb: u64,
    /// Sequential read bandwidth (2011-era SATA).
    pub disk_read_mbps: f64,
    /// Sequential write bandwidth.
    pub disk_write_mbps: f64,
    /// Hadoop 0.20 fixed slot model: concurrent map tasks.
    pub map_slots: u32,
    /// Concurrent reduce tasks.
    pub reduce_slots: u32,
}

impl NodeSpec {
    /// Memory available to one task JVM: RAM shared across all slots plus
    /// OS/daemon overhead.  Determines the map-side sort buffer, which in
    /// turn drives spill behaviour (fewer MB -> more spill passes).
    pub fn per_task_ram_bytes(&self) -> u64 {
        let slots = (self.map_slots + self.reduce_slots) as u64;
        // ~25% of RAM reserved for OS, DataNode and TaskTracker daemons.
        (self.ram_bytes * 3 / 4) / slots.max(1)
    }

    /// io.sort.mb equivalent: the in-JVM sort buffer.  Hadoop 0.20 default
    /// was 100 MB but memory-starved nodes must shrink it (the paper's
    /// 512 MB nodes cannot give 100 MB to each of 4 slots).
    pub fn sort_buffer_bytes(&self) -> u64 {
        let default = 100 * crate::util::bytes::MB;
        // JVM heap ~ per-task RAM; sort buffer capped at half the heap.
        default.min(self.per_task_ram_bytes() / 2)
    }

    /// Relative CPU speed factor vs a 1 GHz reference core.
    pub fn speed(&self) -> f64 {
        self.cpu_ghz
    }

    /// Small multiplier for cache-starved nodes: a 254 KB L2 thrashes on
    /// sort-heavy workloads relative to 512 KB (secondary effect, ~5%).
    pub fn cache_penalty(&self) -> f64 {
        if self.cache_kb >= 512 {
            1.0
        } else {
            1.0 + 0.05 * (512.0 - self.cache_kb as f64) / 512.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GB, MB};

    fn fast() -> NodeSpec {
        NodeSpec {
            name: "fast".into(),
            cpu_ghz: 2.9,
            ram_bytes: GB,
            disk_bytes: 30 * GB,
            cache_kb: 512,
            disk_read_mbps: 70.0,
            disk_write_mbps: 55.0,
            map_slots: 2,
            reduce_slots: 2,
        }
    }

    #[test]
    fn per_task_ram_divides_by_slots() {
        let s = fast();
        assert_eq!(s.per_task_ram_bytes(), (GB * 3 / 4) / 4);
    }

    #[test]
    fn sort_buffer_shrinks_on_small_nodes() {
        let mut s = fast();
        assert!(s.sort_buffer_bytes() <= 100 * MB);
        let big_buffer = s.sort_buffer_bytes();
        s.ram_bytes = 512 * MB;
        assert!(s.sort_buffer_bytes() < big_buffer);
    }

    #[test]
    fn cache_penalty_ordering() {
        let mut s = fast();
        assert_eq!(s.cache_penalty(), 1.0);
        s.cache_kb = 254;
        assert!(s.cache_penalty() > 1.0 && s.cache_penalty() < 1.1);
    }
}
