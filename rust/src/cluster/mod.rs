//! Cluster hardware model.
//!
//! Models the paper's 4-node heterogeneous testbed (§V.A): per-node CPU
//! clock, memory, disk and cache, Hadoop 0.20-style fixed task slots, a
//! shared-medium network with fair-share contention and a simple disk
//! bandwidth model.

pub mod network;
pub mod node;
pub mod spec;

pub use network::Network;
pub use node::Node;
pub use spec::NodeSpec;

use crate::util::bytes::{GB, MB};

/// A cluster: node specs plus derived runtime state.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// Worker nodes (index == [`Node::id`]).
    pub nodes: Vec<Node>,
    /// Shared network model.
    pub network: Network,
}

impl Cluster {
    /// Build a cluster from node specs and a network description.
    pub fn new(specs: Vec<NodeSpec>, network: Network) -> Cluster {
        let nodes = specs.into_iter().enumerate().map(|(i, s)| Node::new(i, s)).collect();
        Cluster { nodes, network }
    }

    /// The paper's exact 4-node testbed (§V.A):
    ///
    /// * master/node-0 and node-1: 2.9 GHz, 1 GB RAM, 30 GB disk, 512 KB cache
    /// * node-2 and node-3:        2.5 GHz, 512 MB RAM, 60 GB disk, 254 KB cache
    ///
    /// Gigabit switched Ethernet (commodity 2011-era lab cluster); 2 map
    /// slots + 1 reduce slot per node — the standard sizing for
    /// single-processor boxes in the Hadoop 0.20 era (the 2/2 default
    /// oversubscribes a lone core badly during concurrent reduces).
    pub fn paper_cluster() -> Cluster {
        let fast = NodeSpec {
            name: "dell-2.9ghz".into(),
            cpu_ghz: 2.9,
            ram_bytes: GB,
            disk_bytes: 30 * GB,
            cache_kb: 512,
            disk_read_mbps: 70.0,
            disk_write_mbps: 55.0,
            map_slots: 2,
            reduce_slots: 1,
        };
        let slow = NodeSpec {
            name: "dell-2.5ghz".into(),
            cpu_ghz: 2.5,
            ram_bytes: 512 * MB,
            disk_bytes: 60 * GB,
            cache_kb: 254,
            disk_read_mbps: 60.0,
            disk_write_mbps: 48.0,
            map_slots: 2,
            reduce_slots: 1,
        };
        Cluster::new(
            vec![fast.clone(), fast, slow.clone(), slow],
            Network::switched_ethernet_1gbps(4),
        )
    }

    /// Number of worker nodes.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Cluster-wide map-slot capacity.
    pub fn total_map_slots(&self) -> u32 {
        self.nodes.iter().map(|n| n.spec.map_slots).sum()
    }

    /// Cluster-wide reduce-slot capacity.
    pub fn total_reduce_slots(&self) -> u32 {
        self.nodes.iter().map(|n| n.spec.reduce_slots).sum()
    }

    /// Mean CPU clock across nodes — used for cluster-wide cost estimates.
    pub fn mean_ghz(&self) -> f64 {
        self.nodes.iter().map(|n| n.spec.cpu_ghz).sum::<f64>() / self.nodes.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_matches_section_5a() {
        let c = Cluster::paper_cluster();
        assert_eq!(c.num_nodes(), 4);
        assert_eq!(c.nodes[0].spec.cpu_ghz, 2.9);
        assert_eq!(c.nodes[1].spec.ram_bytes, GB);
        assert_eq!(c.nodes[2].spec.cpu_ghz, 2.5);
        assert_eq!(c.nodes[3].spec.disk_bytes, 60 * GB);
        assert_eq!(c.nodes[3].spec.cache_kb, 254);
        assert_eq!(c.total_map_slots(), 8);
        assert_eq!(c.total_reduce_slots(), 4);
    }

    #[test]
    fn mean_ghz() {
        let c = Cluster::paper_cluster();
        assert!((c.mean_ghz() - 2.7).abs() < 1e-12);
    }
}
