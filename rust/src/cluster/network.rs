//! Shared-medium network model with fair-share contention.
//!
//! The shuffle phase is the network-intensive part of MapReduce (paper
//! §III).  We model a switched Ethernet where each node has a fixed NIC
//! rate and the switch backplane is non-blocking: a transfer's bandwidth
//! is its fair share of the more contended of its two endpoints.

/// Static network description.
#[derive(Clone, Debug)]
pub struct Network {
    /// Per-NIC bandwidth in bytes/sec.
    pub nic_bps: f64,
    /// Per-connection setup latency (TCP + Jetty fetch handshake), seconds.
    pub fetch_latency_s: f64,
    /// Number of attached nodes.
    pub nodes: usize,
}

impl Network {
    /// 100 Mbit/s switched Ethernet (for what-if comparisons).
    pub fn switched_ethernet_100mbps(nodes: usize) -> Network {
        Network {
            nic_bps: 100.0e6 / 8.0, // 100 Mbit/s -> 12.5 MB/s
            // Hadoop 0.20 shuffle fetches over HTTP (Jetty); each map-output
            // fetch pays connection + request overhead.
            fetch_latency_s: 0.08,
            nodes,
        }
    }

    /// Gigabit Ethernet — the paper-era lab default; used by
    /// [`crate::cluster::Cluster::paper_cluster`].  On 100 Mbit the shuffle
    /// would dominate every phase for 8 GB jobs, contradicting the paper's
    /// observation that the map-CPU-heavy WordCount runs ~2x the
    /// shuffle-heavy Exim job.
    pub fn switched_ethernet_1gbps(nodes: usize) -> Network {
        Network {
            nic_bps: 1.0e9 / 8.0, // 1 Gbit/s -> 125 MB/s
            fetch_latency_s: 0.08,
            nodes,
        }
    }

    /// Effective bandwidth of one transfer when `src_streams` transfers
    /// share the source NIC and `dst_streams` share the destination NIC.
    pub fn transfer_bps(&self, src_streams: u32, dst_streams: u32) -> f64 {
        let contention = src_streams.max(dst_streams).max(1) as f64;
        self.nic_bps / contention
    }

    /// Time to move `bytes` under a constant contention level.
    pub fn transfer_secs(&self, bytes: u64, src_streams: u32, dst_streams: u32) -> f64 {
        bytes as f64 / self.transfer_bps(src_streams, dst_streams)
    }

    /// Aggregate cluster shuffle capacity in bytes/sec: bounded by all NICs
    /// transmitting at once (each byte crosses one Tx and one Rx NIC).
    pub fn bisection_bps(&self) -> f64 {
        self.nic_bps * self.nodes as f64 / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uncontended_transfer_is_nic_rate() {
        let n = Network::switched_ethernet_100mbps(4);
        assert!((n.transfer_bps(1, 1) - 12.5e6).abs() < 1.0);
        // 125 MB at 12.5 MB/s = 10s
        assert!((n.transfer_secs(125_000_000, 1, 1) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn contention_divides_bandwidth() {
        let n = Network::switched_ethernet_100mbps(4);
        assert!((n.transfer_bps(4, 2) - 12.5e6 / 4.0).abs() < 1.0);
        assert!((n.transfer_bps(1, 8) - 12.5e6 / 8.0).abs() < 1.0);
    }

    #[test]
    fn bisection_scales_with_nodes() {
        let n4 = Network::switched_ethernet_100mbps(4);
        let n8 = Network::switched_ethernet_100mbps(8);
        assert!(n8.bisection_bps() > n4.bisection_bps());
        assert!((n4.bisection_bps() - 25.0e6).abs() < 1.0);
    }
}
