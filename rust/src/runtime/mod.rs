//! PJRT runtime: load and execute the AOT-compiled JAX+Pallas artifacts.
//!
//! The production path of the three-layer architecture: `make artifacts`
//! lowers the L2 JAX model (which calls the L1 Pallas kernels) to HLO
//! *text* once at build time; this module loads those files, compiles them
//! on the PJRT CPU client, and executes them from Rust with f64 literals.
//! Python never runs at request time.

pub mod artifacts;
pub mod backend;
pub mod pjrt;

pub use artifacts::Manifest;
pub use backend::XlaBackend;
pub use pjrt::XlaRuntime;
