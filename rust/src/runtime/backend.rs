//! [`crate::model::FitBackend`] implementation over the PJRT runtime:
//! padding, weighting, and batch-chunking around the fixed AOT shapes.

use anyhow::Result;

use crate::model::features::NUM_FEATURES;
use crate::model::regression::FitBackend;

use super::pjrt::XlaRuntime;

/// Production fitting/prediction backend: executes the AOT artifacts.
pub struct XlaBackend {
    /// The loaded PJRT runtime executing both artifacts.
    pub runtime: XlaRuntime,
}

impl XlaBackend {
    /// Wrap an already-loaded runtime.
    pub fn new(runtime: XlaRuntime) -> XlaBackend {
        XlaBackend { runtime }
    }

    /// Load the runtime from the default artifacts directory.
    pub fn load_default() -> Result<XlaBackend> {
        Ok(XlaBackend::new(XlaRuntime::load_default()?))
    }

    /// Pad a training set to the artifact's row count.  Rows beyond the
    /// live data get weight 0, which the weighted Gram kernel nullifies
    /// exactly (property-tested on the Python side and cross-checked in
    /// `rust/tests/`).
    fn pad_fit(
        &self,
        params: &[[f64; 2]],
        times: &[f64],
        weights: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>), String> {
        let rows = self.runtime.manifest.fit_rows;
        if params.len() > rows {
            return Err(format!(
                "training set of {} rows exceeds the artifact capacity {rows}; \
                 re-lower with a larger FIT_ROWS or chunk the campaign",
                params.len()
            ));
        }
        let mut p = vec![0.0; rows * 2];
        let mut t = vec![0.0; rows];
        let mut w = vec![0.0; rows];
        for (i, row) in params.iter().enumerate() {
            p[2 * i] = row[0];
            p[2 * i + 1] = row[1];
            t[i] = times[i];
            w[i] = weights[i];
        }
        Ok((p, t, w))
    }
}

impl FitBackend for XlaBackend {
    fn fit(
        &mut self,
        params: &[[f64; 2]],
        times: &[f64],
        weights: &[f64],
    ) -> Result<[f64; NUM_FEATURES], String> {
        if params.len() != times.len() || params.len() != weights.len() {
            return Err("params/times/weights length mismatch".into());
        }
        if weights.iter().all(|&w| w == 0.0) {
            return Err("all-zero weights".into());
        }
        let (p, t, w) = self.pad_fit(params, times, weights)?;
        self.runtime
            .fit_padded(&p, &t, &w)
            .map_err(|e| format!("{e:#}"))
    }

    /// Batched prediction through the predict artifact, chunked to the
    /// fixed batch size.  Padding rows are zeros; their outputs are
    /// sliced away.
    fn predict(
        &mut self,
        coeffs: &[f64; NUM_FEATURES],
        params: &[[f64; 2]],
    ) -> Result<Vec<f64>, String> {
        let rows = self.runtime.manifest.predict_rows;
        let mut out = Vec::with_capacity(params.len());
        for chunk in params.chunks(rows) {
            let mut p = vec![0.0; rows * 2];
            for (i, row) in chunk.iter().enumerate() {
                p[2 * i] = row[0];
                p[2 * i + 1] = row[1];
            }
            let preds = self
                .runtime
                .predict_padded(coeffs, &p)
                .map_err(|e| format!("{e:#}"))?;
            out.extend_from_slice(&preds[..chunk.len()]);
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}
