//! The PJRT executor: compile-once, execute-many of the HLO artifacts.
//!
//! Follows the pattern validated in `/opt/xla-example/load_hlo`: HLO text
//! -> `HloModuleProto::from_text_file` -> `XlaComputation` -> compile on
//! the CPU PJRT client -> execute with `Literal` inputs.  Artifacts are
//! lowered with `return_tuple=True`, so results unwrap via `to_tuple1`.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::model::features::NUM_FEATURES;

use super::artifacts::{default_dir, Manifest};

/// Loaded runtime: PJRT client plus the two compiled executables.
pub struct XlaRuntime {
    /// The manifest the artifacts were loaded from.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    fit_exe: xla::PjRtLoadedExecutable,
    predict_exe: xla::PjRtLoadedExecutable,
    /// Fit executions served (perf counter for the coordinator's metrics).
    pub fit_calls: std::cell::Cell<u64>,
    /// Predict executions served.
    pub predict_calls: std::cell::Cell<u64>,
}

impl XlaRuntime {
    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<XlaRuntime> {
        Self::load(&default_dir())
    }

    /// Load and compile both artifacts from `dir`.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let fit_exe = compile(&client, &manifest.fit_path)?;
        let predict_exe = compile(&client, &manifest.predict_path)?;
        Ok(XlaRuntime {
            manifest,
            client,
            fit_exe,
            predict_exe,
            fit_calls: std::cell::Cell::new(0),
            predict_calls: std::cell::Cell::new(0),
        })
    }

    /// Name of the PJRT platform serving the executables.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the fit artifact on an already-padded system.
    ///
    /// All slices must have exactly the manifest shapes
    /// (`fit_rows` rows); use [`super::backend::XlaBackend`] for the
    /// pad-and-weight convenience layer.
    pub fn fit_padded(
        &self,
        params: &[f64], // fit_rows * 2, row-major
        times: &[f64],  // fit_rows
        weights: &[f64],
    ) -> Result<[f64; NUM_FEATURES]> {
        let rows = self.manifest.fit_rows;
        anyhow::ensure!(params.len() == rows * 2, "params must be {rows}x2");
        anyhow::ensure!(times.len() == rows, "times must be len {rows}");
        anyhow::ensure!(weights.len() == rows, "weights must be len {rows}");
        let p = xla::Literal::vec1(params).reshape(&[rows as i64, 2])?;
        let t = xla::Literal::vec1(times);
        let w = xla::Literal::vec1(weights);
        let result = self.fit_exe.execute::<xla::Literal>(&[p, t, w])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let v = out.to_vec::<f64>()?;
        anyhow::ensure!(
            v.len() == NUM_FEATURES,
            "fit artifact returned {} values",
            v.len()
        );
        self.fit_calls.set(self.fit_calls.get() + 1);
        let mut coeffs = [0.0; NUM_FEATURES];
        coeffs.copy_from_slice(&v);
        Ok(coeffs)
    }

    /// Execute the predict artifact on an already-padded batch.
    pub fn predict_padded(
        &self,
        coeffs: &[f64; NUM_FEATURES],
        params: &[f64], // predict_rows * 2, row-major
    ) -> Result<Vec<f64>> {
        let rows = self.manifest.predict_rows;
        anyhow::ensure!(params.len() == rows * 2, "params must be {rows}x2");
        let c = xla::Literal::vec1(coeffs.as_slice());
        let p = xla::Literal::vec1(params).reshape(&[rows as i64, 2])?;
        let result = self.predict_exe.execute::<xla::Literal>(&[c, p])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let v = out.to_vec::<f64>()?;
        anyhow::ensure!(v.len() == rows, "predict artifact returned {}", v.len());
        self.predict_calls.set(self.predict_calls.get() + 1);
        Ok(v)
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .with_context(|| format!("parse HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compile {}", path.display()))
}

// NOTE: runtime tests that need built artifacts live in
// `rust/tests/runtime_integration.rs`; unit tests here only cover pieces
// that work without artifacts.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_missing_dir_fails_cleanly() {
        let err = match XlaRuntime::load(Path::new("/nonexistent-artifacts")) {
            Err(e) => e,
            Ok(_) => panic!("load must fail without artifacts"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
