//! Artifact discovery and manifest validation.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) records
//! the shapes and constants the artifacts were lowered with.  The runtime
//! refuses to run against artifacts whose constants disagree with the Rust
//! mirror in [`crate::model::features`] — catching drift between the two
//! sides at startup instead of as silent numerical garbage.

use std::path::{Path, PathBuf};

use crate::model::features::{NUM_FEATURES, PARAM_SCALE};
use crate::util::json::{parse, Json};

/// Parsed artifact manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Feature-vector length the artifacts were compiled for.
    pub num_features: usize,
    /// Parameter normalization divisor baked into the artifacts.
    pub param_scale: f64,
    /// Fixed training-batch row count of the fit artifact.
    pub fit_rows: usize,
    /// Fixed batch row count of the predict artifact.
    pub predict_rows: usize,
    /// Relative ridge regularization baked into the fit artifact.
    pub ridge_rel: f64,
    /// Path to the fit HLO text.
    pub fit_path: PathBuf,
    /// Path to the predict HLO text.
    pub predict_path: PathBuf,
}

impl Manifest {
    /// Parse a manifest JSON document, resolving paths relative to `dir`.
    pub fn parse_json(dir: &Path, v: &Json) -> Result<Manifest, String> {
        let req_u = |k: &str| -> Result<usize, String> {
            Ok(v.req(k)?.as_u64().ok_or_else(|| format!("{k} must be int"))? as usize)
        };
        let arts = v.req("artifacts")?;
        let file = |k: &str| -> Result<PathBuf, String> {
            Ok(dir.join(
                arts.req(k)?.as_str().ok_or_else(|| format!("{k} must be str"))?,
            ))
        };
        let m = Manifest {
            num_features: req_u("num_features")?,
            param_scale: v.req("param_scale")?.as_f64().ok_or("param_scale")?,
            fit_rows: req_u("fit_rows")?,
            predict_rows: req_u("predict_rows")?,
            ridge_rel: v.req("ridge_rel")?.as_f64().ok_or("ridge_rel")?,
            fit_path: file("fit")?,
            predict_path: file("predict")?,
        };
        let dtype = v.req("dtype")?.as_str().ok_or("dtype")?;
        if dtype != "f64" {
            return Err(format!("artifacts must be f64, got {dtype}"));
        }
        Ok(m)
    }

    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts`)", path.display()))?;
        let m = Manifest::parse_json(dir, &parse(&text)?)?;
        m.check_compatible()?;
        for p in [&m.fit_path, &m.predict_path] {
            if !p.exists() {
                return Err(format!("missing artifact {} (run `make artifacts`)", p.display()));
            }
        }
        Ok(m)
    }

    /// Verify the Python-side constants match the Rust mirrors.
    pub fn check_compatible(&self) -> Result<(), String> {
        if self.num_features != NUM_FEATURES {
            return Err(format!(
                "feature-count drift: artifacts {} vs rust {NUM_FEATURES}",
                self.num_features
            ));
        }
        if (self.param_scale - PARAM_SCALE).abs() > 1e-12 {
            return Err(format!(
                "param-scale drift: artifacts {} vs rust {PARAM_SCALE}",
                self.param_scale
            ));
        }
        if self.fit_rows == 0 || self.predict_rows == 0 {
            return Err("degenerate artifact shapes".into());
        }
        Ok(())
    }
}

/// Locate the artifacts directory: `$MRTUNER_ARTIFACTS`, else `artifacts/`
/// relative to the workspace root (where `make artifacts` puts it).
pub fn default_dir() -> PathBuf {
    if let Ok(p) = std::env::var("MRTUNER_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from the executable-relative CWD to find `artifacts/`.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json(features: u64, scale: f64) -> Json {
        parse(&format!(
            r#"{{"num_features":{features},"param_scale":{scale},"fit_rows":64,
                "predict_rows":64,"ridge_rel":1e-9,"dtype":"f64",
                "artifacts":{{"fit":"fit.hlo.txt","predict":"predict.hlo.txt"}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::parse_json(Path::new("/x"), &sample_json(7, 40.0)).unwrap();
        assert_eq!(m.num_features, 7);
        assert_eq!(m.fit_rows, 64);
        assert_eq!(m.fit_path, Path::new("/x/fit.hlo.txt"));
        m.check_compatible().unwrap();
    }

    #[test]
    fn rejects_feature_drift() {
        let m = Manifest::parse_json(Path::new("/x"), &sample_json(9, 40.0)).unwrap();
        assert!(m.check_compatible().unwrap_err().contains("feature-count drift"));
    }

    #[test]
    fn rejects_scale_drift() {
        let m = Manifest::parse_json(Path::new("/x"), &sample_json(7, 32.0)).unwrap();
        assert!(m.check_compatible().unwrap_err().contains("param-scale drift"));
    }

    #[test]
    fn rejects_non_f64() {
        let j = parse(
            r#"{"num_features":7,"param_scale":40,"fit_rows":64,"predict_rows":64,
                "ridge_rel":1e-9,"dtype":"f32",
                "artifacts":{"fit":"a","predict":"b"}}"#,
        )
        .unwrap();
        assert!(Manifest::parse_json(Path::new("/x"), &j).is_err());
    }

    #[test]
    fn load_real_artifacts_if_built() {
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).expect("built artifacts must validate");
            assert_eq!(m.num_features, NUM_FEATURES);
            assert!(m.fit_path.exists());
        }
    }
}
