//! User-facing MapReduce programming API and the functional execution engine.
//!
//! This is the part of Hadoop an application developer sees: `Mapper`,
//! `Reducer`, `Combiner`, `Partitioner`.  The framework executes these for
//! real over real bytes (`execute` below) — outputs are genuine word
//! counts / parsed transactions, so the simulator's semantics are testable
//! against ground truth rather than mocked.

pub mod engine;
pub mod kv;
pub mod traits;

pub use engine::{execute, ExecOptions, JobOutput};
pub use kv::Pair;
pub use traits::{Combiner, Mapper, Partitioner, Reducer};
