//! The MapReduce programming contracts.

use super::kv::Pair;

/// Emits intermediate pairs for one input record.  Input records are text
/// lines (key = byte offset rendered as string, value = the line), exactly
/// like Hadoop's `TextInputFormat`.
pub trait Mapper: Send + Sync {
    /// Process one input record, appending intermediate pairs to `out`.
    fn map(&self, offset: u64, line: &str, out: &mut Vec<Pair>);
}

/// Folds all values sharing a key into output pairs.
pub trait Reducer: Send + Sync {
    /// Fold every value of `key` into zero or more output pairs.
    fn reduce(&self, key: &str, values: &[String], out: &mut Vec<Pair>);
}

/// Optional map-side pre-aggregation (Hadoop's combiner).  Must be
/// algebraically compatible with the reducer; correctness is property-
/// tested per app (combiner on == combiner off).
pub trait Combiner: Send + Sync {
    /// Pre-aggregate the values of `key` seen within one split.
    fn combine(&self, key: &str, values: &[String], out: &mut Vec<Pair>);
}

/// Routes a key to one of `num_reducers` partitions.
pub trait Partitioner: Send + Sync {
    /// The partition (reducer index) `key` routes to.
    fn partition(&self, key: &str, num_reducers: u32) -> u32;
}

/// Hadoop's default `HashPartitioner`.  We reimplement Java's
/// `String.hashCode` so partition skew characteristics match the real
/// system (Java's 31x hash on short ASCII keys is mildly non-uniform,
/// which is part of why reducers see skewed shuffle volumes).
#[derive(Clone, Copy, Debug, Default)]
pub struct HashPartitioner;

impl HashPartitioner {
    /// `java.lang.String#hashCode`: s[0]*31^(n-1) + ... + s[n-1], i32 wrap.
    pub fn java_hash(s: &str) -> i32 {
        let mut h: i32 = 0;
        for c in s.encode_utf16() {
            h = h.wrapping_mul(31).wrapping_add(c as i32);
        }
        h
    }
}

impl Partitioner for HashPartitioner {
    fn partition(&self, key: &str, num_reducers: u32) -> u32 {
        // Hadoop: (hash & Integer.MAX_VALUE) % numReduceTasks
        ((Self::java_hash(key) & i32::MAX) as u32) % num_reducers.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn java_hash_known_values() {
        // Values cross-checked against the JVM.
        assert_eq!(HashPartitioner::java_hash(""), 0);
        assert_eq!(HashPartitioner::java_hash("a"), 97);
        assert_eq!(HashPartitioner::java_hash("ab"), 3105);
        assert_eq!(HashPartitioner::java_hash("hello"), 99162322);
        assert_eq!(HashPartitioner::java_hash("polygenelubricants"), i32::MIN);
    }

    #[test]
    fn partition_in_range_and_stable() {
        let p = HashPartitioner;
        for key in ["the", "a", "exim", "2011-07-01", ""] {
            let part = p.partition(key, 7);
            assert!(part < 7);
            assert_eq!(part, p.partition(key, 7), "stable for {key}");
        }
    }

    #[test]
    fn single_reducer_gets_everything() {
        let p = HashPartitioner;
        forall("hash partition r=1", 20, |rng| {
            let len = rng.range_usize(0, 12);
            let key: String =
                (0..len).map(|_| (b'a' + rng.range_u64(0, 26) as u8) as char).collect();
            assert_eq!(p.partition(&key, 1), 0);
        });
    }

    #[test]
    fn negative_hash_keys_still_partition() {
        // "polygenelubricants" hashes to i32::MIN; & MAX makes it 0.
        let p = HashPartitioner;
        assert_eq!(p.partition("polygenelubricants", 40), 0);
    }
}
