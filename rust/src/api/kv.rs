//! Key/value records.

/// A `<key, value>` pair — the unit of data flowing through MapReduce
/// (paper §III).  Keys and values are UTF-8 strings, matching the text
/// workloads the paper evaluates (WordCount, Exim mainlog lines).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Pair {
    /// The record key (sort/shuffle identity).
    pub key: String,
    /// The record value.
    pub value: String,
}

impl Pair {
    /// Convenience constructor from anything string-like.
    pub fn new(key: impl Into<String>, value: impl Into<String>) -> Pair {
        Pair { key: key.into(), value: value.into() }
    }

    /// Serialized size in bytes (key + TAB + value + newline), the same
    /// accounting Hadoop's map-output counters use for text records.
    pub fn byte_len(&self) -> u64 {
        self.key.len() as u64 + self.value.len() as u64 + 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_by_key_then_value() {
        let a = Pair::new("a", "2");
        let b = Pair::new("a", "1");
        let c = Pair::new("b", "0");
        let mut v = vec![c.clone(), a.clone(), b.clone()];
        v.sort();
        assert_eq!(v, vec![b, a, c]);
    }

    #[test]
    fn byte_len_counts_separators() {
        assert_eq!(Pair::new("word", "1").byte_len(), 7);
        assert_eq!(Pair::new("", "").byte_len(), 2);
    }
}
