//! Functional MapReduce execution: split → map → (combine) → partition →
//! sort → shuffle → merge → reduce, for real, in memory.
//!
//! This engine computes *what* a job produces; the DES framework in
//! `crate::mr` computes *how long* it takes at cluster scale.  Running the
//! same `Mapper`/`Reducer` code in both keeps semantics honest, and the
//! engine's measured record/byte statistics calibrate the cost model
//! (`crate::apps::profiles`).

use std::collections::BTreeMap;

use super::kv::Pair;
use super::traits::{Combiner, Mapper, Partitioner, Reducer};

/// Knobs mirroring the JobConf fields that matter functionally.
pub struct ExecOptions<'a> {
    /// Number of reduce partitions.
    pub num_reducers: u32,
    /// Optional combiner run per split before the shuffle.
    pub combiner: Option<&'a dyn Combiner>,
    /// Key → partition assignment.
    pub partitioner: &'a dyn Partitioner,
    /// Input split count (affects combiner aggregation scope, not results).
    pub num_splits: u32,
}

/// Functional result plus the counters the cost model consumes.
#[derive(Clone, Debug, Default)]
pub struct JobOutput {
    /// Final output, one vec per reducer (sorted by key within each).
    pub partitions: Vec<Vec<Pair>>,
    /// Input records read across all splits.
    pub input_records: u64,
    /// Input bytes read.
    pub input_bytes: u64,
    /// Records emitted by mappers (pre-combiner).
    pub map_output_records: u64,
    /// Bytes emitted by mappers (pre-combiner).
    pub map_output_bytes: u64,
    /// After combiner (== map output if no combiner).
    pub shuffle_records: u64,
    /// Bytes crossing the shuffle (post-combiner).
    pub shuffle_bytes: u64,
    /// Records in the final output.
    pub output_records: u64,
    /// Bytes in the final output.
    pub output_bytes: u64,
}

impl JobOutput {
    /// All output pairs merged (for assertions in tests/examples).
    pub fn all_pairs(&self) -> Vec<Pair> {
        let mut v: Vec<Pair> =
            self.partitions.iter().flatten().cloned().collect();
        v.sort();
        v
    }

    /// Map-output selectivity: shuffle bytes per input byte — the cost
    /// model's key application statistic.
    pub fn selectivity(&self) -> f64 {
        if self.input_bytes == 0 {
            0.0
        } else {
            self.shuffle_bytes as f64 / self.input_bytes as f64
        }
    }
}

/// Split text into `n` chunks on line boundaries (byte-range splits that
/// extend to the next newline, like Hadoop's `LineRecordReader`).
pub fn line_splits(input: &str, n: u32) -> Vec<&str> {
    let n = n.max(1) as usize;
    let bytes = input.as_bytes();
    let target = (bytes.len() / n).max(1);
    let mut splits = Vec::with_capacity(n);
    let mut start = 0;
    for _ in 0..n {
        if start >= bytes.len() {
            break;
        }
        let mut end = (start + target).min(bytes.len());
        // Extend to the next newline (or EOF).
        while end < bytes.len() && bytes[end - 1] != b'\n' {
            end += 1;
        }
        splits.push(&input[start..end]);
        start = end;
    }
    if start < bytes.len() {
        // Remainder goes to the last split.
        let last = splits.pop().unwrap_or("");
        let merged_start = last.as_ptr() as usize - input.as_ptr() as usize;
        splits.push(&input[merged_start..]);
    }
    splits
}

/// Run a full MapReduce job functionally.
pub fn execute(
    mapper: &dyn Mapper,
    reducer: &dyn Reducer,
    input: &str,
    opts: &ExecOptions<'_>,
) -> JobOutput {
    let r = opts.num_reducers.max(1);
    let mut out = JobOutput { partitions: vec![Vec::new(); r as usize], ..Default::default() };
    out.input_bytes = input.len() as u64;

    // Per-reducer intermediate store: key -> values, sorted by key (BTreeMap
    // plays the role of the sort/merge stage).
    let mut groups: Vec<BTreeMap<String, Vec<String>>> =
        vec![BTreeMap::new(); r as usize];

    let mut emitted = Vec::new();
    for split in line_splits(input, opts.num_splits) {
        // ---- map phase over this split
        let mut split_pairs: Vec<Pair> = Vec::new();
        let mut offset = 0u64;
        for line in split.lines() {
            out.input_records += 1;
            emitted.clear();
            mapper.map(offset, line, &mut emitted);
            offset += line.len() as u64 + 1;
            out.map_output_records += emitted.len() as u64;
            out.map_output_bytes += emitted.iter().map(Pair::byte_len).sum::<u64>();
            split_pairs.append(&mut emitted);
        }

        // ---- map-side combine (per split, like Hadoop's per-spill combine)
        let combined: Vec<Pair> = if let Some(c) = opts.combiner {
            let mut by_key: BTreeMap<String, Vec<String>> = BTreeMap::new();
            for p in split_pairs {
                by_key.entry(p.key).or_default().push(p.value);
            }
            let mut acc = Vec::new();
            for (k, vs) in &by_key {
                c.combine(k, vs, &mut acc);
            }
            acc
        } else {
            split_pairs
        };
        out.shuffle_records += combined.len() as u64;
        out.shuffle_bytes += combined.iter().map(Pair::byte_len).sum::<u64>();

        // ---- partition (the "shuffle" routing)
        for p in combined {
            let part = opts.partitioner.partition(&p.key, r) as usize;
            groups[part].entry(p.key).or_default().push(p.value);
        }
    }

    // ---- reduce phase
    for (part, group) in groups.into_iter().enumerate() {
        let mut acc = Vec::new();
        for (k, vs) in &group {
            reducer.reduce(k, vs, &mut acc);
        }
        out.output_records += acc.len() as u64;
        out.output_bytes += acc.iter().map(Pair::byte_len).sum::<u64>();
        out.partitions[part] = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::traits::HashPartitioner;

    struct IdentityMapper;
    impl Mapper for IdentityMapper {
        fn map(&self, _off: u64, line: &str, out: &mut Vec<Pair>) {
            out.push(Pair::new(line, "1"));
        }
    }

    /// Sums numeric values — combiner-compatible (sum is associative),
    /// like the canonical WordCount reducer.
    struct CountReducer;
    impl Reducer for CountReducer {
        fn reduce(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
            let total: u64 = values.iter().map(|v| v.parse::<u64>().unwrap()).sum();
            out.push(Pair::new(key, total.to_string()));
        }
    }
    impl Combiner for CountReducer {
        fn combine(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
            let total: u64 = values.iter().map(|v| v.parse::<u64>().unwrap()).sum();
            out.push(Pair::new(key, total.to_string()));
        }
    }

    fn opts(r: u32, splits: u32) -> ExecOptions<'static> {
        ExecOptions {
            num_reducers: r,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: splits,
        }
    }

    #[test]
    fn counts_lines() {
        let input = "a\nb\na\na\n";
        let out = execute(&IdentityMapper, &CountReducer, input, &opts(3, 2));
        let pairs = out.all_pairs();
        assert_eq!(
            pairs,
            vec![Pair::new("a", "3"), Pair::new("b", "1")]
        );
        assert_eq!(out.input_records, 4);
        assert_eq!(out.map_output_records, 4);
        assert_eq!(out.output_records, 2);
    }

    #[test]
    fn results_independent_of_split_and_reducer_count() {
        let input = "x\ny\nz\nx\ny\nx\n".repeat(50);
        let base = execute(&IdentityMapper, &CountReducer, &input, &opts(1, 1)).all_pairs();
        for r in [2, 5, 7] {
            for s in [1, 3, 8] {
                let got =
                    execute(&IdentityMapper, &CountReducer, &input, &opts(r, s)).all_pairs();
                assert_eq!(got, base, "r={r} s={s}");
            }
        }
    }

    #[test]
    fn partitions_respect_partitioner() {
        let input = "a\nb\nc\nd\n";
        let out = execute(&IdentityMapper, &CountReducer, input, &opts(4, 1));
        let p = HashPartitioner;
        for (i, part) in out.partitions.iter().enumerate() {
            for pair in part {
                assert_eq!(p.partition(&pair.key, 4) as usize, i);
            }
        }
    }

    #[test]
    fn output_sorted_within_partition() {
        let input = "delta\nalpha\ncharlie\nbravo\n".repeat(10);
        let out = execute(&IdentityMapper, &CountReducer, &input, &opts(2, 3));
        for part in &out.partitions {
            let keys: Vec<&String> = part.iter().map(|p| &p.key).collect();
            let mut sorted = keys.clone();
            sorted.sort();
            assert_eq!(keys, sorted);
        }
    }

    #[test]
    fn combiner_shrinks_shuffle_but_not_results() {
        let input = "w\n".repeat(100);
        let without = execute(&IdentityMapper, &CountReducer, &input, &opts(2, 4));
        let mut o = opts(2, 4);
        o.combiner = Some(&CountReducer);
        let with = execute(&IdentityMapper, &CountReducer, &input, &o);
        assert_eq!(with.all_pairs(), without.all_pairs());
        assert!(with.shuffle_records < without.shuffle_records);
        assert!(with.shuffle_bytes < without.shuffle_bytes);
        // 4 splits of identical words -> 4 combined records.
        assert_eq!(with.shuffle_records, 4);
    }

    #[test]
    fn line_splits_cover_input_exactly() {
        let input = "one\ntwo\nthree\nfour\nfive\n";
        for n in 1..8 {
            let splits = line_splits(input, n);
            let joined: String = splits.concat();
            assert_eq!(joined, input, "n={n}");
            for s in &splits[..splits.len().saturating_sub(1)] {
                assert!(s.ends_with('\n'), "split not on line boundary: {s:?}");
            }
        }
    }

    #[test]
    fn empty_input() {
        let out = execute(&IdentityMapper, &CountReducer, "", &opts(3, 2));
        assert_eq!(out.input_records, 0);
        assert_eq!(out.output_records, 0);
        assert_eq!(out.partitions.len(), 3);
        assert_eq!(out.selectivity(), 0.0);
    }

    #[test]
    fn selectivity_reflects_bytes() {
        let input = "word\n".repeat(20);
        let out = execute(&IdentityMapper, &CountReducer, &input, &opts(1, 1));
        // Each 5-byte line -> "word\t1\n"-style 7-byte pair.
        assert!(out.selectivity() > 1.0);
    }
}
