//! The declared lock hierarchy backing the `lock_discipline` rule.
//!
//! The repo's blocking primitives form a global acquisition order; holding
//! a higher-ranked lock while acquiring a lower-ranked one risks deadlock
//! between the serving path, the background compactor, and cooperative
//! campaign drains. Conceptually there are five levels:
//!
//! 1. the model-registry `RwLock` in `coordinator/service.rs`;
//! 2. the store *root* `compact.lock` file guarding cross-shard layout
//!    changes (legacy migration, shard-count resolution);
//! 3. the *per-shard* `compact.lock` file guarding one shard's segment
//!    rewrite;
//! 4. segment write locks, taken when a [`SegmentWriter`] is created;
//! 5. per-rep drain/replay leases under the dead-letter queue.
//!
//! Levels 2 and 3 share one primitive (`CompactGuard::acquire`, pointed at
//! either the root or a shard directory), so a single token pattern covers
//! both and the root-before-shard order within the pair is enforced by the
//! call structure in `profiler/store/sharded.rs` rather than by the lint.
//!
//! Every pattern listed here must match at least one real call site in the
//! tree; `run_lint` reports a stale manifest otherwise, so this file cannot
//! silently drift from the code it describes.
//!
//! [`SegmentWriter`]: crate::profiler::store

/// One level of the global lock-acquisition order.
#[derive(Debug)]
pub struct LockLevel {
    /// Position in the acquisition order; lower ranks must be taken first.
    pub rank: u8,
    /// Human-readable name used in findings.
    pub name: &'static str,
    /// Token patterns whose match marks an acquisition of this level.
    /// Each pattern element is an identifier or a single punctuation
    /// character, compared in sequence against the token stream.
    pub patterns: &'static [&'static [&'static str]],
}

/// The hierarchy, ordered by rank.
pub const LOCK_HIERARCHY: &[LockLevel] = &[
    LockLevel {
        rank: 0,
        name: "model-registry RwLock",
        patterns: &[&["registry_read"], &["registry_write"]],
    },
    LockLevel {
        rank: 1,
        name: "store compaction guard (root or per-shard compact.lock)",
        patterns: &[&["CompactGuard", ":", ":", "acquire"]],
    },
    LockLevel {
        rank: 2,
        name: "segment write lock",
        patterns: &[&["SegmentWriter", ":", ":", "create"]],
    },
    LockLevel {
        rank: 3,
        name: "drain/replay lease",
        patterns: &[&["try_claim_lease"]],
    },
];

/// Flatten the hierarchy into `(level, pattern)` pairs, in manifest order.
/// The freshness check in `run_lint` counts matches per entry of this list.
pub fn flat_patterns() -> Vec<(&'static LockLevel, &'static [&'static str])> {
    let mut out = Vec::new();
    for level in LOCK_HIERARCHY {
        for pat in level.patterns {
            out.push((level, *pat));
        }
    }
    out
}
