//! A minimal Rust lexer for the `mrtuner lint` static-analysis pass.
//!
//! The rule engine in [`super::rules`] matches token *patterns* (identifier
//! and punctuation sequences), so the lexer's only job is to produce those
//! tokens while guaranteeing that nothing inside a comment, a string
//! literal, a raw string, a byte string, or a char literal ever reaches a
//! rule. It also recognizes the repo's suppression-comment grammar (a line
//! comment carrying `allow(<rules>) — <why>` after the lint's marker word;
//! see the "Static invariants" section of `docs/ARCHITECTURE.md` for the
//! exact spelling) and reports those directives alongside the token stream.
//!
//! Deliberate simplifications, safe for a linter that only needs *token*
//! accuracy:
//!
//! * numeric literals are consumed but not emitted (no rule matches them);
//! * lifetimes are consumed but not emitted, after disambiguating them from
//!   char literals (`'a'` is a char, `'a ` is a lifetime);
//! * doc comments (`///`, `//!`) are skipped like ordinary comments but are
//!   *not* scanned for suppression directives, so documentation may quote
//!   the directive grammar without tripping the malformed-directive check.

/// Kinds of tokens surfaced to the rule engine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokenKind {
    /// An identifier or keyword (`foo`, `fn`, `BTreeMap`).
    Ident(String),
    /// A single punctuation character (`.`, `[`, `!`, ...).
    Punct(char),
}

/// One token together with the 1-based source line it starts on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Token {
    /// 1-based line number.
    pub line: u32,
    /// The token payload.
    pub kind: TokenKind,
}

impl Token {
    /// The identifier text, when this token is an identifier.
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokenKind::Ident(s) => Some(s),
            TokenKind::Punct(_) => None,
        }
    }

    /// True when the token is exactly the punctuation character `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokenKind::Punct(c)
    }
}

/// True when `tok` renders as `want`: a one-character `want` that is not an
/// identifier character compares against punctuation, anything else against
/// identifier text. This is the comparison used by every rule pattern.
pub(crate) fn token_is(tok: &Token, want: &str) -> bool {
    match &tok.kind {
        TokenKind::Ident(s) => s == want,
        TokenKind::Punct(c) => {
            let mut it = want.chars();
            it.next() == Some(*c) && it.next().is_none()
        }
    }
}

/// A parsed suppression directive from a line comment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AllowDirective {
    /// 1-based line of the comment. The directive suppresses findings on
    /// this line and on the line directly below it.
    pub line: u32,
    /// Rule-family names listed inside `allow(...)`.
    pub rules: Vec<String>,
    /// Whether non-empty justification text follows the closing paren.
    pub justified: bool,
}

/// Lexer output: the token stream plus the lint-control comments found.
#[derive(Debug, Default)]
pub struct LexOutput {
    /// Tokens outside comments and literals, in source order.
    pub tokens: Vec<Token>,
    /// Well-formed suppression directives.
    pub allows: Vec<AllowDirective>,
    /// Lines of non-doc comments that mention the lint marker word but do
    /// not parse as a directive.
    pub malformed: Vec<u32>,
}

/// The marker word that introduces a suppression directive in a comment.
/// Kept out of this module's own comments so the shipped tree self-lints
/// clean (a stray mention in a plain comment is itself a finding).
const MARKER: &str = "mrlint";

/// Tokenize `source`, skipping comments and all literal forms.
pub fn lex(source: &str) -> LexOutput {
    let chars: Vec<char> = source.chars().collect();
    let mut out = LexOutput::default();
    let mut line: u32 = 1;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && next == Some('/') {
            let start = i;
            while i < chars.len() && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            record_comment(&text, line, &mut out);
        } else if c == '/' && next == Some('*') {
            i += 2;
            let mut depth = 1u32;
            while i < chars.len() && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == '"' {
            i = skip_string(&chars, i + 1, &mut line);
        } else if c == '\'' {
            i = skip_char_or_lifetime(&chars, i, &mut line);
        } else if c.is_ascii_digit() {
            i = skip_number(&chars, i);
        } else if c == '_' || c.is_alphabetic() {
            i = lex_word(&chars, i, &mut line, &mut out);
        } else {
            out.tokens.push(Token {
                line,
                kind: TokenKind::Punct(c),
            });
            i += 1;
        }
    }
    out
}

/// Consume a (non-raw) string body starting just past the opening quote;
/// returns the index past the closing quote.
fn skip_string(chars: &[char], mut i: usize, line: &mut u32) -> usize {
    while i < chars.len() {
        match chars[i] {
            '\\' => {
                if chars.get(i + 1) == Some(&'\n') {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Disambiguate `'a'` (char literal) from `'a` (lifetime) at index `i`
/// (which holds the quote) and consume whichever it is.
fn skip_char_or_lifetime(chars: &[char], i: usize, line: &mut u32) -> usize {
    let n1 = chars.get(i + 1).copied();
    let n2 = chars.get(i + 2).copied();
    let lifetime =
        n1.is_some_and(|ch| ch == '_' || ch.is_alphabetic()) && n2 != Some('\'');
    let mut j = i + 1;
    if lifetime {
        while j < chars.len() && (chars[j] == '_' || chars[j].is_alphanumeric()) {
            j += 1;
        }
        return j;
    }
    while j < chars.len() {
        match chars[j] {
            '\\' => j += 2,
            '\'' => return j + 1,
            '\n' => {
                // Tolerate malformed input: keep line numbers right.
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// Consume a numeric literal starting at digit index `i` (ints, floats,
/// hex/oct/bin, underscores, exponents). Emits nothing.
fn skip_number(chars: &[char], mut i: usize) -> usize {
    i += 1;
    while i < chars.len() {
        let d = chars[i];
        if d == '_' || d.is_ascii_alphanumeric() {
            let sign_after_exp = (d == 'e' || d == 'E')
                && matches!(chars.get(i + 1).copied(), Some('+') | Some('-'))
                && chars.get(i + 2).copied().is_some_and(|x| x.is_ascii_digit());
            i += if sign_after_exp { 3 } else { 1 };
        } else if d == '.'
            && chars.get(i + 1).copied().is_some_and(|x| x.is_ascii_digit())
        {
            i += 2;
        } else {
            break;
        }
    }
    i
}

/// Read an identifier word at index `i`; handles the `r"…"`, `r#"…"#`,
/// `b"…"`, `br#"…"#` string prefixes and `r#ident` raw identifiers.
fn lex_word(chars: &[char], mut i: usize, line: &mut u32, out: &mut LexOutput) -> usize {
    let start = i;
    while i < chars.len() && (chars[i] == '_' || chars[i].is_alphanumeric()) {
        i += 1;
    }
    let word: String = chars[start..i].iter().collect();
    let nc = chars.get(i).copied();
    if (word == "r" || word == "br") && (nc == Some('"') || nc == Some('#')) {
        let mut j = i;
        let mut hashes = 0usize;
        while chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if chars.get(j) == Some(&'"') {
            return skip_raw_string(chars, j + 1, hashes, line);
        }
        if word == "r" && hashes == 1 {
            // Raw identifier `r#type`: drop the `r#`, lex the rest normally.
            return j;
        }
    }
    if word == "b" && nc == Some('"') {
        return skip_string(chars, i + 1, line);
    }
    out.tokens.push(Token {
        line: *line,
        kind: TokenKind::Ident(word),
    });
    i
}

/// Consume a raw-string body starting just past the opening quote, closing
/// on a quote followed by `hashes` hash characters.
fn skip_raw_string(chars: &[char], mut i: usize, hashes: usize, line: &mut u32) -> usize {
    while i < chars.len() {
        if chars[i] == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if chars[i] == '"' {
            let mut k = 0usize;
            while k < hashes && chars.get(i + 1 + k) == Some(&'#') {
                k += 1;
            }
            if k == hashes {
                return i + 1 + hashes;
            }
        }
        i += 1;
    }
    i
}

/// Inspect a line comment for the suppression grammar. Doc comments are
/// ignored entirely so documentation can quote the syntax.
fn record_comment(text: &str, line: u32, out: &mut LexOutput) {
    if text.starts_with("///") || text.starts_with("//!") {
        return;
    }
    let Some(pos) = text.find(MARKER) else { return };
    let rest = text[pos + MARKER.len()..]
        .trim_start_matches(':')
        .trim_start();
    let Some(rest) = rest.strip_prefix("allow") else {
        out.malformed.push(line);
        return;
    };
    let Some(rest) = rest.trim_start().strip_prefix('(') else {
        out.malformed.push(line);
        return;
    };
    let Some(close) = rest.find(')') else {
        out.malformed.push(line);
        return;
    };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        out.malformed.push(line);
        return;
    }
    let tail = rest[close + 1..]
        .trim_start_matches([' ', '\t', '\u{2014}', '\u{2013}', '-', ':', ','])
        .trim();
    out.allows.push(AllowDirective {
        line,
        rules,
        justified: !tail.is_empty(),
    });
}

/// Remove tokens belonging to `#[cfg(test)]` items (the attribute itself,
/// any attributes stacked after it, and the item body). The skip covers
/// exactly one item, so a mid-file `#[cfg(test)] fn helper()` does not
/// swallow the production code below it.
pub fn strip_cfg_test(tokens: &[Token]) -> Vec<Token> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if is_cfg_test(tokens, i) {
            i = skip_attribute(tokens, i);
            while is_attribute_start(tokens, i) {
                i = skip_attribute(tokens, i);
            }
            i = skip_item(tokens, i);
        } else {
            out.push(tokens[i].clone());
            i += 1;
        }
    }
    out
}

fn is_cfg_test(tokens: &[Token], i: usize) -> bool {
    const SHAPE: [&str; 7] = ["#", "[", "cfg", "(", "test", ")", "]"];
    tokens.len() >= i + SHAPE.len()
        && SHAPE
            .iter()
            .enumerate()
            .all(|(k, want)| token_is(&tokens[i + k], want))
}

fn is_attribute_start(tokens: &[Token], i: usize) -> bool {
    i + 1 < tokens.len() && tokens[i].is_punct('#') && tokens[i + 1].is_punct('[')
}

/// Skip a `#[...]` attribute starting at the `#`; returns the index past
/// the closing bracket.
fn skip_attribute(tokens: &[Token], i: usize) -> usize {
    let mut j = i + 2;
    let mut depth = 1i32;
    while j < tokens.len() && depth > 0 {
        if tokens[j].is_punct('[') {
            depth += 1;
        }
        if tokens[j].is_punct(']') {
            depth -= 1;
        }
        j += 1;
    }
    j
}

/// Skip one item: through its balanced `{...}` body, or through a `;` at
/// brace depth zero for brace-less items (`use`, trait method decls).
fn skip_item(tokens: &[Token], i: usize) -> usize {
    let mut j = i;
    let mut depth = 0i32;
    while j < tokens.len() {
        let t = &tokens[j];
        if t.is_punct('{') {
            depth += 1;
        }
        if t.is_punct('}') {
            depth -= 1;
            if depth <= 0 {
                return j + 1;
            }
        }
        if t.is_punct(';') && depth == 0 {
            return j + 1;
        }
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.ident().map(str::to_string))
            .collect()
    }

    #[test]
    fn line_and_block_comments_emit_nothing() {
        let src = "let a = 1; // HashMap here\n/* Instant::now()\n/* nested SystemTime */ still */\nlet b = 2;";
        let ids = idents(src);
        assert_eq!(ids, ["let", "a", "let", "b"]);
        let last = lex(src).tokens.last().cloned().unwrap();
        assert_eq!(last.line, 4, "nested block comment must count lines");
    }

    #[test]
    fn strings_and_raw_strings_emit_nothing() {
        let src = r###"let s = "partial_cmp"; let r = r#"f64::max "quoted" inner"#; let b = b"unwrap()";"###;
        let ids = idents(src);
        assert_eq!(ids, ["let", "s", "let", "r", "let", "b"]);
    }

    #[test]
    fn raw_string_hash_depth_is_respected() {
        // A `"#` inside an `r##"…"##` string does not close it.
        let src = "r##\" inner \"# still inside \"## after";
        assert_eq!(idents(src), ["after"]);
    }

    #[test]
    fn char_literals_are_not_lifetimes() {
        let src = "let c = 'x'; fn f<'shelf>(v: &'shelf str) { let esc = '\\n'; let quote = '\\''; }";
        let ids = idents(src);
        assert!(ids.contains(&"str".to_string()));
        assert!(ids.contains(&"esc".to_string()));
        // Neither the char payloads nor the lifetime name leak as idents.
        assert!(!ids.contains(&"x".to_string()));
        assert!(!ids.contains(&"shelf".to_string()));
    }

    #[test]
    fn numbers_and_floats_emit_nothing() {
        let src = "let x = 0xFF_u32 + 1.5e-3 + 2.0; let r = 0..5;";
        assert_eq!(idents(src), ["let", "x", "let", "r"]);
    }

    #[test]
    fn directive_parses_rules_and_justification() {
        let src = "// mrlint: allow(determinism, panic_free) \u{2014} clock names files only\nlet x = 1;";
        let out = lex(src);
        assert_eq!(out.allows.len(), 1);
        let a = &out.allows[0];
        assert_eq!(a.line, 1);
        assert_eq!(a.rules, ["determinism", "panic_free"]);
        assert!(a.justified);
        assert!(out.malformed.is_empty());
    }

    #[test]
    fn directive_without_justification_and_malformed_marker() {
        let out = lex("// mrlint: allow(determinism)\nlet x = 1; // mrlint fixme later\n");
        assert_eq!(out.allows.len(), 1);
        assert!(!out.allows[0].justified);
        assert_eq!(out.malformed, [2]);
    }

    #[test]
    fn doc_comments_may_quote_the_grammar() {
        let out = lex("/// write `// mrlint: allow(rule) — why` above the site\nlet x = 1;");
        assert!(out.allows.is_empty());
        assert!(out.malformed.is_empty());
    }

    #[test]
    fn cfg_test_strips_only_the_next_item() {
        let src = "#[cfg(test)]\nfn helper() { let h = HashMap::new(); }\nfn real() { let i = Instant::now(); }";
        let kept = strip_cfg_test(&lex(src).tokens);
        let ids: Vec<&str> = kept.iter().filter_map(Token::ident).collect();
        assert!(!ids.contains(&"HashMap"));
        assert!(ids.contains(&"Instant"), "code after the test item must survive");
    }

    #[test]
    fn cfg_test_strips_whole_mod_and_stacked_attributes() {
        let src = "fn real() {}\n#[cfg(test)]\n#[allow(dead_code)]\nmod tests { fn t() { x.lock().unwrap(); } }\nfn after() {}";
        let kept = strip_cfg_test(&lex(src).tokens);
        let ids: Vec<&str> = kept.iter().filter_map(Token::ident).collect();
        assert!(!ids.contains(&"unwrap"));
        assert!(ids.contains(&"after"));
    }

    #[test]
    fn cfg_test_strips_braceless_items() {
        let src = "#[cfg(test)]\nuse std::collections::HashMap;\nfn real() {}";
        let kept = strip_cfg_test(&lex(src).tokens);
        let ids: Vec<&str> = kept.iter().filter_map(Token::ident).collect();
        assert!(!ids.contains(&"HashMap"));
        assert!(ids.contains(&"real"));
    }
}
