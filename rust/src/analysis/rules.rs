//! Token-pattern rules for `mrtuner lint`.
//!
//! Four rule families, each scoped to the modules where its invariant is
//! load-bearing (scopes are matched on the path relative to the scanned
//! root, `/`-separated):
//!
//! * **determinism** — wall clocks (`Instant`, `SystemTime`) and
//!   randomized-order collections (`HashMap`, `HashSet`, `DefaultHasher`,
//!   `RandomState`) are banned in the simulation-critical modules (`mr/`,
//!   `sim/`, `model/`, `apps/`, `datagen/`, `dfs/`, `cluster/`, and
//!   `profiler/` outside `profiler/store/`), where they would break the
//!   "a `StoreKey` fully determines its simulation" invariant.
//! * **nan_ordering** — `partial_cmp` and `f64::max`/`f64::min` (and the
//!   `f32` twins) are banned everywhere in favor of `total_cmp` /
//!   `util::stats::total_max` / `total_min`; a NaN must surface, not
//!   silently reorder or vanish. Float `sort_by` comparators are covered
//!   transitively: the only float comparator is `partial_cmp` itself.
//!   Known limitation: the method form `x.max(y)` is indistinguishable
//!   from `Ord::max` at token level and is left to review.
//! * **lock_discipline** — in `coordinator/` and `profiler/store/`, lock
//!   results must not be `.unwrap()`/`.expect()`-ed (poison must be
//!   recovered, mirroring `ServiceMetrics::lock_poisoned`); additionally,
//!   in every function body, acquisitions matched against the
//!   [`super::manifest::LOCK_HIERARCHY`] patterns must appear in
//!   non-decreasing rank order.
//! * **panic_free** — on the serving hot path (`coordinator/server.rs`,
//!   `wire.rs`, `service.rs`) and in all store backends
//!   (`profiler/store/`), `.unwrap()`, `.expect()`, `panic!` and
//!   slice/array indexing are banned; `assert!`/`debug_assert!` remain
//!   allowed as invariant documentation.
//!
//! Test code is exempt: `#[cfg(test)]` items are stripped before matching.
//! A finding is suppressed by an `allow` directive comment on the same
//! line or the line above (grammar in `docs/ARCHITECTURE.md`); directives
//! must carry a justification, must name a known rule, and must actually
//! suppress something — violations of those meta-rules are findings
//! themselves, so the suppression inventory can never rot silently.

use super::lexer::{self, AllowDirective, Token, TokenKind};
use super::manifest;

/// One lint finding.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path of the file, relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: u32,
    /// Rule family that fired (one of [`RULES`], or `mrlint` for
    /// directive meta-findings).
    pub rule: String,
    /// Human-readable explanation.
    pub message: String,
    /// The offending source line, trimmed (empty for synthetic findings).
    pub snippet: String,
}

impl Finding {
    /// One-line human rendering: `file:line: [rule] message | snippet`.
    pub fn render(&self) -> String {
        if self.snippet.is_empty() {
            format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
        } else {
            format!(
                "{}:{}: [{}] {} | {}",
                self.file, self.line, self.rule, self.message, self.snippet
            )
        }
    }

    /// Machine-readable one-object-per-line JSON rendering.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"message\":\"{}\",\"snippet\":\"{}\"}}",
            json_escape(&self.file),
            self.line,
            json_escape(&self.rule),
            json_escape(&self.message),
            json_escape(&self.snippet)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Rule-family names accepted by the `allow` directive.
pub const RULES: [&str; 4] = [
    "determinism",
    "nan_ordering",
    "lock_discipline",
    "panic_free",
];

/// Rule name used for directive meta-findings (malformed, unjustified,
/// unknown-rule, and unused directives). Not itself suppressible.
pub const META_RULE: &str = "mrlint";

/// Lint one source file; `rel` is its path relative to the scan root.
pub fn lint_source(rel: &str, text: &str) -> Vec<Finding> {
    lint_source_counted(rel, text).0
}

/// Lint one source file and also count, per flattened manifest pattern,
/// how many times it matched (for the manifest-freshness check).
pub fn lint_source_counted(rel: &str, text: &str) -> (Vec<Finding>, Vec<usize>) {
    let lexed = lexer::lex(text);
    let code = lexer::strip_cfg_test(&lexed.tokens);
    let mut raw: Vec<RawFinding> = Vec::new();
    if in_determinism_scope(rel) {
        check_determinism(&code, &mut raw);
    }
    check_nan_ordering(&code, &mut raw);
    if in_lock_scope(rel) {
        check_lock_unwrap(&code, &mut raw);
    }
    check_lock_order(&code, &mut raw);
    if in_panic_scope(rel) {
        check_panic_free(&code, &mut raw);
    }
    let counts = manifest_counts(&code);
    let findings = apply_allows(rel, text, &lexed.allows, &lexed.malformed, raw);
    (findings, counts)
}

/// `(line, rule, message)` before suppression is applied.
type RawFinding = (u32, &'static str, String);

fn in_determinism_scope(rel: &str) -> bool {
    const PREFIXES: [&str; 7] = [
        "mr/", "sim/", "model/", "apps/", "datagen/", "dfs/", "cluster/",
    ];
    if PREFIXES.iter().any(|p| rel.starts_with(p)) {
        return true;
    }
    rel.starts_with("profiler/") && !rel.starts_with("profiler/store/")
}

fn in_lock_scope(rel: &str) -> bool {
    rel.starts_with("coordinator/") || rel.starts_with("profiler/store/")
}

fn in_panic_scope(rel: &str) -> bool {
    matches!(
        rel,
        "coordinator/server.rs" | "coordinator/wire.rs" | "coordinator/service.rs"
    ) || rel.starts_with("profiler/store/")
}

/// True when `code[at..]` starts with the pattern (see
/// `lexer::token_is` for the per-element comparison).
fn matches_seq(code: &[Token], at: usize, pat: &[&str]) -> bool {
    pat.iter()
        .enumerate()
        .all(|(k, want)| code.get(at + k).is_some_and(|t| lexer::token_is(t, want)))
}

const DETERMINISM_BANNED: [(&str, &str); 6] = [
    ("Instant", "wall-clock reads are not reproducible across runs"),
    ("SystemTime", "wall-clock reads are not reproducible across runs"),
    ("DefaultHasher", "hash output varies per process"),
    ("RandomState", "hash seeding varies per process"),
    ("HashMap", "iteration order is randomized; use BTreeMap"),
    ("HashSet", "iteration order is randomized; use BTreeSet"),
];

fn check_determinism(code: &[Token], raw: &mut Vec<RawFinding>) {
    for t in code {
        let TokenKind::Ident(s) = &t.kind else { continue };
        if let Some((name, why)) = DETERMINISM_BANNED
            .iter()
            .find(|(name, _)| *name == s.as_str())
        {
            raw.push((
                t.line,
                "determinism",
                format!("`{name}` in a simulation-critical module: {why}"),
            ));
        }
    }
}

fn check_nan_ordering(code: &[Token], raw: &mut Vec<RawFinding>) {
    for (i, t) in code.iter().enumerate() {
        let TokenKind::Ident(s) = &t.kind else { continue };
        if s == "partial_cmp" {
            raw.push((
                t.line,
                "nan_ordering",
                "`partial_cmp` returns None on NaN; use `total_cmp`".to_string(),
            ));
        }
        if (s == "f64" || s == "f32") && matches_seq(code, i + 1, &[":", ":"]) {
            if let Some(m) = code.get(i + 3).and_then(Token::ident) {
                if m == "max" || m == "min" {
                    raw.push((
                        t.line,
                        "nan_ordering",
                        format!(
                            "`{s}::{m}` silently drops a NaN operand; use `util::stats::total_{m}`"
                        ),
                    ));
                }
            }
        }
    }
}

fn check_lock_unwrap(code: &[Token], raw: &mut Vec<RawFinding>) {
    for i in 0..code.len() {
        if !code[i].is_punct('.') {
            continue;
        }
        let Some(m) = code.get(i + 1).and_then(Token::ident) else {
            continue;
        };
        if m != "lock" && m != "read" && m != "write" {
            continue;
        }
        if !matches_seq(code, i + 2, &["(", ")", "."]) {
            continue;
        }
        let Some(next) = code.get(i + 5).and_then(Token::ident) else {
            continue;
        };
        if next == "unwrap" || next == "expect" {
            raw.push((
                code[i + 1].line,
                "lock_discipline",
                format!(
                    "`.{m}().{next}(..)` on a lock result; recover poison \
                     (see `ServiceMetrics::lock_poisoned`) instead of panicking"
                ),
            ));
        }
    }
}

/// Check every function body for lock acquisitions that decrease in rank.
fn check_lock_order(code: &[Token], raw: &mut Vec<RawFinding>) {
    let mut i = 0usize;
    while i < code.len() {
        if code[i].ident() != Some("fn") {
            i += 1;
            continue;
        }
        // Find the body start: the first `{` outside the signature's
        // parens/brackets; a `;` there means a bodiless declaration.
        let mut j = i + 1;
        let mut paren = 0i32;
        let mut bracket = 0i32;
        let mut body_start = None;
        while j < code.len() {
            let t = &code[j];
            if t.is_punct('(') {
                paren += 1;
            }
            if t.is_punct(')') {
                paren -= 1;
            }
            if t.is_punct('[') {
                bracket += 1;
            }
            if t.is_punct(']') {
                bracket -= 1;
            }
            if paren == 0 && bracket == 0 {
                if t.is_punct(';') {
                    break;
                }
                if t.is_punct('{') {
                    body_start = Some(j + 1);
                    break;
                }
            }
            j += 1;
        }
        let Some(start) = body_start else {
            i = j + 1;
            continue;
        };
        let mut depth = 1i32;
        let mut k = start;
        while k < code.len() && depth > 0 {
            if code[k].is_punct('{') {
                depth += 1;
            }
            if code[k].is_punct('}') {
                depth -= 1;
            }
            k += 1;
        }
        scan_order(&code[start..k], raw);
        i = k;
    }
}

fn match_level_at(code: &[Token], i: usize) -> Option<&'static manifest::LockLevel> {
    for level in manifest::LOCK_HIERARCHY {
        for pat in level.patterns {
            if matches_seq(code, i, pat) {
                return Some(level);
            }
        }
    }
    None
}

fn scan_order(body: &[Token], raw: &mut Vec<RawFinding>) {
    let mut held: Option<(u8, &'static str)> = None;
    for i in 0..body.len() {
        let Some(level) = match_level_at(body, i) else {
            continue;
        };
        if let Some((rank, name)) = held {
            if level.rank < rank {
                raw.push((
                    body[i].line,
                    "lock_discipline",
                    format!(
                        "`{}` (rank {}) acquired after `{}` (rank {}); \
                         violates the declared lock hierarchy",
                        level.name, level.rank, name, rank
                    ),
                ));
            }
        }
        let update = match held {
            None => true,
            Some((rank, _)) => level.rank > rank,
        };
        if update {
            held = Some((level.rank, level.name));
        }
    }
}

/// Identifiers that may legitimately precede `[` without it being an
/// indexing expression (`let [a, b] = ...`, `&mut [u8]`, `x as [u8; 2]`).
const NON_INDEX_KEYWORDS: [&str; 26] = [
    "let", "mut", "ref", "in", "if", "else", "match", "return", "while", "for", "loop",
    "move", "as", "box", "break", "continue", "unsafe", "where", "dyn", "impl", "pub",
    "const", "static", "use", "mod", "yield",
];

fn check_panic_free(code: &[Token], raw: &mut Vec<RawFinding>) {
    for i in 0..code.len() {
        let t = &code[i];
        match &t.kind {
            TokenKind::Ident(s) => {
                if (s == "unwrap" || s == "expect")
                    && i > 0
                    && code[i - 1].is_punct('.')
                    && code.get(i + 1).is_some_and(|n| n.is_punct('('))
                {
                    raw.push((
                        t.line,
                        "panic_free",
                        format!("`.{s}(..)` can panic on a hot path; propagate the error"),
                    ));
                }
                if s == "panic" && code.get(i + 1).is_some_and(|n| n.is_punct('!')) {
                    raw.push((
                        t.line,
                        "panic_free",
                        "`panic!` on a hot path; return an error instead".to_string(),
                    ));
                }
            }
            TokenKind::Punct('[') => {
                if i == 0 {
                    continue;
                }
                let indexable = match &code[i - 1].kind {
                    TokenKind::Ident(w) => !NON_INDEX_KEYWORDS.contains(&w.as_str()),
                    TokenKind::Punct(c) => *c == ')' || *c == ']',
                };
                if indexable {
                    raw.push((
                        t.line,
                        "panic_free",
                        "slice/array indexing can panic on a hot path; use `.get()`"
                            .to_string(),
                    ));
                }
            }
            TokenKind::Punct(_) => {}
        }
    }
}

/// Count, per flattened manifest pattern, how many times it matches.
fn manifest_counts(code: &[Token]) -> Vec<usize> {
    let pats = manifest::flat_patterns();
    let mut counts = vec![0usize; pats.len()];
    for i in 0..code.len() {
        for (pi, (_, pat)) in pats.iter().enumerate() {
            if matches_seq(code, i, pat) {
                counts[pi] += 1;
            }
        }
    }
    counts
}

/// Apply suppression directives to the raw findings and append the
/// directive meta-findings (malformed / unjustified / unknown / unused).
fn apply_allows(
    rel: &str,
    text: &str,
    allows: &[AllowDirective],
    malformed: &[u32],
    raw: Vec<RawFinding>,
) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let snippet = |line: u32| -> String {
        let idx = line.saturating_sub(1) as usize;
        lines.get(idx).map(|l| l.trim().to_string()).unwrap_or_default()
    };
    let finding = |line: u32, rule: &str, message: String, with_snippet: bool| Finding {
        file: rel.to_string(),
        line,
        rule: rule.to_string(),
        message,
        snippet: if with_snippet { snippet(line) } else { String::new() },
    };
    let mut used: Vec<Vec<bool>> = allows
        .iter()
        .map(|a| vec![false; a.rules.len()])
        .collect();
    let mut out = Vec::new();
    for (line, rule, message) in raw {
        let mut suppressed = false;
        for (ai, a) in allows.iter().enumerate() {
            if a.line != line && a.line + 1 != line {
                continue;
            }
            if let Some(ri) = a.rules.iter().position(|r| r == rule) {
                used[ai][ri] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            out.push(finding(line, rule, message, true));
        }
    }
    for l in malformed {
        out.push(finding(
            *l,
            META_RULE,
            "comment mentions the lint marker but is not a well-formed \
             `allow(<rules>) — <why>` directive"
                .to_string(),
            true,
        ));
    }
    for (ai, a) in allows.iter().enumerate() {
        if !a.justified {
            out.push(finding(
                a.line,
                META_RULE,
                "allow directive lacks a justification after the closing paren".to_string(),
                true,
            ));
        }
        for (ri, r) in a.rules.iter().enumerate() {
            if !RULES.contains(&r.as_str()) {
                out.push(finding(
                    a.line,
                    META_RULE,
                    format!("unknown rule `{r}` in allow directive"),
                    true,
                ));
            } else if !used[ai][ri] {
                out.push(finding(
                    a.line,
                    META_RULE,
                    format!("unused allow for `{r}`: no finding on this or the next line"),
                    true,
                ));
            }
        }
    }
    out.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, src: &str) -> Vec<String> {
        lint_source(rel, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn determinism_fires_in_scope_only() {
        let src = "use std::collections::HashMap;\nfn f() { let t = Instant::now(); }\n";
        assert_eq!(
            rules_fired("mr/task.rs", src),
            ["determinism", "determinism"]
        );
        assert!(rules_fired("util/stats.rs", src).is_empty());
        assert!(rules_fired("profiler/store/file_backend.rs", src)
            .iter()
            .all(|r| r != "determinism"));
        assert_eq!(rules_fired("profiler/executor.rs", src).len(), 2);
    }

    #[test]
    fn nan_ordering_fires_everywhere() {
        let src = "fn f(a: f64, b: f64) { let _ = a.partial_cmp(&b); }\n";
        let fired = rules_fired("util/stats.rs", src);
        assert_eq!(fired, ["nan_ordering"]);
        let src2 = "fn g(xs: &[f64]) -> f64 { xs.iter().copied().fold(0.0, f64::max) }\n";
        assert_eq!(rules_fired("report/figure.rs", src2), ["nan_ordering"]);
        // f64 paths that are not max/min do not fire.
        let src3 = "fn h() -> f64 { f64::from_bits(1) + f64::INFINITY }\n";
        assert!(rules_fired("report/figure.rs", src3).is_empty());
    }

    #[test]
    fn lock_unwrap_fires_in_lock_scope() {
        let src = "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }\n";
        assert_eq!(rules_fired("coordinator/trainer.rs", src), ["lock_discipline"]);
        let lock_free = "fn f(r: &RwLock<u32>) { let g = r.read().expect(\"x\"); }\n";
        assert_eq!(
            rules_fired("profiler/store/extra.rs", lock_free),
            // store files are also in the panic_free scope, so `.expect`
            // fires twice: once per family.
            ["lock_discipline", "panic_free"]
        );
        assert!(rules_fired("util/cli.rs", src).is_empty());
        // `read(&mut buf)` is I/O, not a lock acquisition.
        let io = "fn f(s: &mut TcpStream, b: &mut Vec<u8>) { s.read(b).unwrap(); }\n";
        assert!(rules_fired("coordinator/client.rs", io).is_empty());
    }

    #[test]
    fn lock_order_inversion_fires_anywhere() {
        let src = "fn f(p: &Path) {\n    let l = try_claim_lease(p);\n    let g = CompactGuard::acquire(p);\n}\n";
        assert_eq!(rules_fired("profiler/executor.rs", src), ["lock_discipline"]);
        let fine = "fn f(p: &Path) {\n    let g = CompactGuard::acquire(p);\n    let l = try_claim_lease(p);\n}\n";
        assert!(rules_fired("profiler/executor.rs", fine).is_empty());
        // Separate functions hold nothing across each other.
        let split = "fn a(p: &Path) { let l = try_claim_lease(p); }\nfn b(p: &Path) { let g = CompactGuard::acquire(p); }\n";
        assert!(rules_fired("profiler/executor.rs", split).is_empty());
    }

    #[test]
    fn panic_free_fires_on_hot_paths_only() {
        let src = "fn f(v: &[u8]) -> u8 { v[0] }\nfn g(o: Option<u8>) -> u8 { o.unwrap() }\nfn h() { panic!(\"no\"); }\n";
        assert_eq!(
            rules_fired("coordinator/server.rs", src),
            ["panic_free", "panic_free", "panic_free"]
        );
        assert!(rules_fired("coordinator/client.rs", src).is_empty());
        // unwrap_or_else and array-type syntax do not fire.
        let fine = "fn f(o: Option<[u8; 4]>) -> [u8; 4] { let [a, b, c, d] = o.unwrap_or_default(); [a, b, c, d] }\n";
        assert!(rules_fired("coordinator/wire.rs", fine).is_empty());
        // assert! stays allowed.
        let asserts = "fn f(n: usize) { assert!(n < 4); debug_assert!(n > 0); }\n";
        assert!(rules_fired("coordinator/wire.rs", asserts).is_empty());
    }

    #[test]
    fn cfg_test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(v: &[u8]) -> u8 { v[0] }\n}\nfn real() {}\n";
        assert!(rules_fired("coordinator/server.rs", src).is_empty());
    }

    #[test]
    fn allow_suppresses_same_and_next_line() {
        let above = "fn f(v: &[u8]) -> u8 {\n    // mrlint: allow(panic_free) \u{2014} length checked by caller\n    v[0]\n}\n";
        assert!(rules_fired("coordinator/server.rs", above).is_empty());
        let trailing =
            "fn f(v: &[u8]) -> u8 { v[0] } // mrlint: allow(panic_free) \u{2014} checked\n";
        assert!(rules_fired("coordinator/server.rs", trailing).is_empty());
        // The directive does not reach two lines down.
        let far = "fn f(v: &[u8]) -> u8 {\n    // mrlint: allow(panic_free) \u{2014} checked\n    let n = 1;\n    v[n]\n}\n";
        let fired = rules_fired("coordinator/server.rs", far);
        assert!(fired.contains(&"panic_free".to_string()));
        assert!(fired.contains(&META_RULE.to_string()), "allow is unused");
    }

    #[test]
    fn directive_meta_findings() {
        // Unjustified.
        let unjustified =
            "fn f(v: &[u8]) -> u8 { v[0] } // mrlint: allow(panic_free)\n";
        assert_eq!(rules_fired("coordinator/server.rs", unjustified), [META_RULE]);
        // Unknown rule name.
        let unknown = "fn f() {} // mrlint: allow(no_such_rule) \u{2014} why\n";
        assert_eq!(rules_fired("util/cli.rs", unknown), [META_RULE]);
        // Unused allow.
        let unused = "fn f() {} // mrlint: allow(panic_free) \u{2014} why\n";
        assert_eq!(rules_fired("util/cli.rs", unused), [META_RULE]);
        // Malformed marker mention.
        let malformed = "fn f() {} // mrlint should fix this\n";
        assert_eq!(rules_fired("util/cli.rs", malformed), [META_RULE]);
    }

    #[test]
    fn manifest_patterns_count_matches() {
        let src = "fn f(p: &Path) { let g = CompactGuard::acquire(p); }\n";
        let (_, counts) = lint_source_counted("profiler/store/sharded.rs", src);
        let pats = manifest::flat_patterns();
        let idx = pats
            .iter()
            .position(|(_, pat)| pat.join("") == "CompactGuard::acquire")
            .expect("manifest has the compaction pattern");
        assert_eq!(counts[idx], 1);
        assert_eq!(counts.iter().sum::<usize>(), 1);
    }

    #[test]
    fn findings_render_and_serialize() {
        let f = lint_source("mr/task.rs", "use std::collections::HashMap;\n")
            .pop()
            .expect("one finding");
        assert_eq!(f.line, 1);
        let rendered = f.render();
        assert!(rendered.starts_with("mr/task.rs:1: [determinism]"));
        let json = f.to_json();
        assert!(json.starts_with("{\"file\":\"mr/task.rs\",\"line\":1,"));
        assert!(json.contains("\"rule\":\"determinism\""));
    }
}
