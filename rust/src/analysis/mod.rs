//! `mrtuner lint` — a repo-invariant static-analysis pass.
//!
//! The paper's method (profile → fit → predict, arXiv 1203.0651) is only
//! sound if a configuration point maps to a reproducible measurement, so
//! this crate carries two load-bearing invariants: *a `StoreKey` fully
//! determines its simulation* and *parallel output is bit-identical to
//! serial*. The test suite checks them after the fact; this module checks
//! their known failure modes at the source level, on every PR, with a
//! hand-rolled zero-dependency scanner:
//!
//! * [`lexer`] tokenizes Rust source, guaranteeing comments, strings, raw
//!   strings, and char literals never reach a rule, and strips
//!   `#[cfg(test)]` items;
//! * [`manifest`] declares the global lock-acquisition hierarchy;
//! * [`rules`] matches the four rule families (determinism, NaN ordering,
//!   lock discipline, panic-free hot paths) and applies the suppression
//!   directives.
//!
//! [`run_lint`] walks a source tree (deterministically: paths sorted),
//! lints every `.rs` file, and adds the manifest-freshness check — every
//! lock-hierarchy pattern must still match at least one real site, so the
//! manifest cannot drift from the code. The `mrtuner lint` subcommand
//! exits non-zero when any unsuppressed finding remains.

pub mod lexer;
pub mod manifest;
pub mod rules;

pub use rules::Finding;

use std::fs;
use std::path::{Path, PathBuf};

/// Result of linting a source tree.
#[derive(Debug)]
pub struct LintReport {
    /// All unsuppressed findings, ordered by file then line.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

/// Walk `root` and lint every `.rs` file under it.
pub fn run_lint(root: &Path) -> Result<LintReport, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let pats = manifest::flat_patterns();
    let mut totals = vec![0usize; pats.len()];
    let mut findings = Vec::new();
    for path in &files {
        let text = fs::read_to_string(path)
            .map_err(|e| format!("lint: read {}: {e}", path.display()))?;
        let rel = relative_label(root, path);
        let (mut file_findings, counts) = rules::lint_source_counted(&rel, &text);
        for (total, count) in totals.iter_mut().zip(counts) {
            *total += count;
        }
        findings.append(&mut file_findings);
    }
    for ((level, pat), total) in pats.iter().zip(&totals) {
        if *total == 0 {
            findings.push(Finding {
                file: "analysis/manifest.rs".to_string(),
                line: 1,
                rule: "lock_discipline".to_string(),
                message: format!(
                    "stale lock-hierarchy manifest: pattern `{}` for `{}` matches no site",
                    pat.join(""),
                    level.name
                ),
                snippet: String::new(),
            });
        }
    }
    Ok(LintReport {
        findings,
        files_scanned: files.len(),
    })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("lint: read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("lint: read dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|ext| ext == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `root`-relative path with `/` separators, used for scope matching and
/// stable output across platforms.
fn relative_label(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walker_is_deterministic_and_reports_stale_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "mrtuner-lint-walk-{}",
            std::process::id()
        ));
        let sub = dir.join("mr");
        fs::create_dir_all(&sub).expect("mkdir");
        fs::write(sub.join("b.rs"), "fn ok() {}\n").expect("write");
        fs::write(sub.join("a.rs"), "use std::collections::HashMap;\n").expect("write");
        let report = run_lint(&dir).expect("walk");
        assert_eq!(report.files_scanned, 2);
        // One determinism finding from mr/a.rs plus one stale-manifest
        // finding per lock-hierarchy pattern (the temp tree has no locks).
        let stale = manifest::flat_patterns().len();
        assert_eq!(report.findings.len(), 1 + stale);
        assert_eq!(report.findings[0].file, "mr/a.rs");
        assert_eq!(report.findings[0].rule, "determinism");
        fs::remove_dir_all(&dir).ok();
    }
}
