//! End-to-end validation driver (DESIGN.md §7).
//!
//! Exercises every layer on a real small workload and checks the paper's
//! headline claims:
//!
//! 1. generate real corpus/mainlog bytes and *functionally execute*
//!    WordCount and Exim parsing through the MapReduce engine, verifying
//!    outputs against independently computed ground truth;
//! 2. calibrate app profiles from the functional run;
//! 3. profile the paper's 20-setting campaign on the simulated 4-node
//!    cluster (5 reps, averaged — Fig. 2a);
//! 4. fit via the AOT JAX+Pallas artifact through PJRT (or the pure-Rust
//!    baseline when artifacts are absent) — both backends cross-checked;
//! 5. predict 20 held-out settings and evaluate Fig. 3 / Table 1 metrics;
//! 6. spot-check the Fig. 4 surface shape.
//!
//! Used by `examples/e2e_repro.rs` and `mrtuner e2e`; the run for the
//! record is in EXPERIMENTS.md.

use std::collections::HashMap;

use crate::api::engine::{execute, ExecOptions};
use crate::api::traits::HashPartitioner;
use crate::apps::{profiles, AppId};
use crate::model::regression::{RegressionModel, RustSolverBackend};
use crate::model::features::NUM_FEATURES;
use crate::model::FitBackend;
use crate::profiler::CampaignExecutor;
use crate::util::bytes::fmt_secs;
use crate::util::rng::Rng;

use super::experiments;

/// Outcome summary (also printed step by step).
#[derive(Clone, Debug)]
pub struct E2eOutcome {
    /// WordCount mean prediction error (Table 1 row 1).
    pub wordcount_mean_err_pct: f64,
    /// Exim mean prediction error (Table 1 row 2).
    pub exim_mean_err_pct: f64,
    /// Fit/predict backend actually used ("xla-pjrt" or "rust-cholesky").
    pub backend: &'static str,
    /// (M, R) of the Fig. 4 surface minimum.
    pub surface_min: (u32, u32),
    /// Whether both apps came in under the paper's 5 % headline.
    pub headline_reproduced: bool,
}

/// Run the validation with a machine-sized profiling executor (output is
/// bit-identical whatever the worker count).
pub fn run(seed: u64) -> Result<E2eOutcome, String> {
    run_with(seed, &CampaignExecutor::machine_sized())
}

/// Run the validation through a caller-supplied executor (so CLI `--jobs`
/// and `--store` settings apply to every campaign inside).
pub fn run_with(seed: u64, executor: &CampaignExecutor) -> Result<E2eOutcome, String> {
    println!(
        "=== mrtuner end-to-end validation (seed {seed}, {} profiling workers) ===\n",
        executor.jobs()
    );

    // ---- step 1: functional execution on real bytes -------------------
    println!("[1/6] functional MapReduce execution on generated data");
    let mut rng = Rng::new(seed);
    let corpus = crate::datagen::corpus::generate(&mut rng, 512 * 1024);
    let (wc_map, wc_red, wc_comb) = AppId::WordCount.functional();
    let wc_out = execute(
        wc_map.as_ref(),
        wc_red.as_ref(),
        &corpus,
        &ExecOptions {
            num_reducers: 8,
            combiner: wc_comb.as_deref(),
            partitioner: &HashPartitioner,
            num_splits: 16,
        },
    );
    // Ground truth via a plain hash map.
    let mut truth: HashMap<&str, u64> = HashMap::new();
    for w in corpus.split_whitespace() {
        *truth.entry(w).or_insert(0) += 1;
    }
    let the = wc_out
        .all_pairs()
        .into_iter()
        .find(|p| p.key == "the")
        .ok_or("wordcount lost 'the'")?;
    if the.value != truth["the"].to_string() {
        return Err(format!(
            "wordcount mismatch for 'the': {} vs {}",
            the.value, truth["the"]
        ));
    }
    if wc_out.output_records != truth.len() as u64 {
        return Err("wordcount vocabulary size mismatch".into());
    }
    println!(
        "      wordcount: {} words, {} distinct, counts verified",
        wc_out.map_output_records, wc_out.output_records
    );

    let mainlog = crate::datagen::exim_log::generate(&mut rng, 512 * 1024);
    let (ex_map, ex_red, _) = AppId::EximParse.functional();
    let ex_out = execute(
        ex_map.as_ref(),
        ex_red.as_ref(),
        &mainlog,
        &ExecOptions {
            num_reducers: 8,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 16,
        },
    );
    let mut ids = std::collections::HashSet::new();
    for line in mainlog.lines() {
        if let Some(id) = crate::apps::exim::message_id(line) {
            ids.insert(id);
        }
    }
    if ex_out.output_records != ids.len() as u64 {
        return Err(format!(
            "exim transaction count mismatch: {} vs {}",
            ex_out.output_records,
            ids.len()
        ));
    }
    println!(
        "      exim: {} log lines -> {} transactions, grouping verified",
        ex_out.input_records, ex_out.output_records
    );

    // ---- step 2: profile calibration ----------------------------------
    println!("[2/6] profile calibration from functional runs");
    let (wc_cal, wc_drift) = profiles::calibrate(&profiles::wordcount(), &wc_out);
    let (ex_cal, ex_drift) = profiles::calibrate(&profiles::exim(), &ex_out);
    println!(
        "      wordcount selectivity {:.3} (drift {:.2}), exim {:.3} (drift {:.2})",
        wc_cal.selectivity, wc_drift, ex_cal.selectivity, ex_drift
    );

    // ---- step 3+4+5: the paper's pipeline -----------------------------
    println!("[3/6] profiling campaigns (20 settings x 5 reps, simulated 4-node cluster)");
    println!("[4/6] fit via AOT artifact (PJRT) with pure-Rust cross-check");
    println!("[5/6] predict 20 held-out settings per app");
    let wc = experiments::fig3_with(executor, AppId::WordCount, seed);
    let ex = experiments::fig3_with(executor, AppId::EximParse, seed);

    // Cross-check the production backend against the baseline solver.
    let mut baseline = RustSolverBackend;
    let weights = vec![1.0; wc.train.len()];
    let check = baseline.fit(&wc.train.params, &wc.train.times, &weights)?;
    for i in 0..NUM_FEATURES {
        let scale = check[i].abs().max(1.0);
        if (check[i] - wc.model.coeffs[i]).abs() / scale > 1e-6 {
            return Err(format!(
                "backend disagreement on coeff {i}: {} vs {}",
                wc.model.coeffs[i], check[i]
            ));
        }
    }
    for d in [&wc, &ex] {
        println!(
            "      {:<10} mean err {:.2}%  variance {:.2}%  max {:.2}%  (backend {})",
            d.app.name(),
            d.errors.mean_pct(),
            d.errors.variance_pct(),
            d.errors.max_pct(),
            d.backend
        );
    }

    // ---- step 6: surface sanity ---------------------------------------
    println!("[6/6] Fig. 4 surface spot-check (step-5 lattice, 3 reps)");
    let surf = experiments::fig4_with(executor, AppId::WordCount, 5, 3, seed);
    let (bm, br) = surf.argmin();
    println!(
        "      wordcount minimum at M={bm}, R={br} (paper: 20, 5), mean {}",
        fmt_secs(surf.mean_time())
    );
    // Combined in-memory + on-disk accounting: with a persistent store
    // attached, `simulated` can be zero on a fully warm-started run.
    println!("      profiling executor: {}", executor.stats());

    let headline = wc.errors.mean_pct() < 5.0 && ex.errors.mean_pct() < 5.0;
    println!(
        "\nheadline (mean prediction error < 5% for both apps): {}",
        if headline { "REPRODUCED" } else { "NOT REPRODUCED" }
    );
    // Secondary shape claims.
    println!(
        "exim error > wordcount error (streaming noise): {}",
        if ex.errors.mean_pct() > wc.errors.mean_pct() { "yes" } else { "no (within noise)" }
    );

    Ok(E2eOutcome {
        wordcount_mean_err_pct: wc.errors.mean_pct(),
        exim_mean_err_pct: ex.errors.mean_pct(),
        backend: wc.backend,
        surface_min: (bm, br),
        headline_reproduced: headline,
    })
}

/// Save a fitted model per paper app for later `mrtuner predict` use.
pub fn save_models(seed: u64, dir: &std::path::Path) -> Result<(), String> {
    let cluster = crate::cluster::Cluster::paper_cluster();
    let executor = CampaignExecutor::machine_sized();
    let (mut backend, _) = experiments::default_backend();
    for app in AppId::paper_apps() {
        let (train, _) = crate::profiler::paper_campaign(app, seed);
        let (_, ds) = train.run_with(&cluster, &executor);
        let model = RegressionModel::fit_dataset(backend.as_mut(), &ds)?;
        let path = dir.join(format!("{}_model.json", app.name()));
        model.save(&path).map_err(|e| e.to_string())?;
    }
    Ok(())
}
