//! The paper's experiments, end to end.
//!
//! Every public function here runs the *full pipeline* — simulate
//! profiling runs on the 4-node cluster model, fit via a backend
//! (PJRT artifacts when built, pure-Rust otherwise), predict held-out
//! settings — and returns the data behind one of the paper's evaluation
//! artifacts.  See DESIGN.md §5 for the experiment index.

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::model::regression::{FitBackend, RegressionModel, RustSolverBackend};
use crate::model::PredictionErrors;
use crate::profiler::campaign::{grid_specs, paper_campaign};
use crate::profiler::{CampaignExecutor, Dataset, ExperimentSpec};
use crate::runtime::{artifacts, XlaBackend};

/// Pick the production backend when artifacts are built, else the
/// pure-Rust baseline.  Returns the backend and its name for reporting.
pub fn default_backend() -> (Box<dyn FitBackend>, &'static str) {
    if artifacts::default_dir().join("manifest.json").exists() {
        match XlaBackend::load_default() {
            Ok(b) => return (Box::new(b), "xla-pjrt"),
            Err(e) => eprintln!("warn: artifacts unusable ({e:#}); falling back"),
        }
    }
    (Box::new(RustSolverBackend), "rust-cholesky")
}

/// Data behind Fig. 3 (a,b) or (c,d): actual vs predicted execution time
/// and per-experiment errors on 20 held-out random settings.
#[derive(Clone, Debug)]
pub struct Fig3Data {
    /// Application evaluated.
    pub app: AppId,
    /// Fit/predict backend used.
    pub backend: &'static str,
    /// The 20 held-out settings, in plot order.
    pub test_specs: Vec<ExperimentSpec>,
    /// Actual-vs-predicted errors on the held-out settings.
    pub errors: PredictionErrors,
    /// The model fitted on the training campaign.
    pub model: RegressionModel,
    /// Training dataset (for cross-checks and reuse).
    pub train: Dataset,
}

/// Run the paper's Fig. 3 protocol for one application (serial executor).
pub fn fig3(app: AppId, seed: u64) -> Fig3Data {
    fig3_with(&CampaignExecutor::serial(), app, seed)
}

/// Fig. 3 protocol through a shared [`CampaignExecutor`]: both campaigns
/// fan out over its worker pool, and overlapping settings (e.g. a later
/// grid sweep at the same session seed) come from its cache.
pub fn fig3_with(executor: &CampaignExecutor, app: AppId, seed: u64) -> Fig3Data {
    let cluster = Cluster::paper_cluster();
    let (train_c, test_c) = paper_campaign(app, seed);
    let (_, train) = executor.run_campaign(&cluster, &train_c);
    let (mut backend, backend_name) = default_backend();
    let model = RegressionModel::fit_dataset(backend.as_mut(), &train)
        .expect("fit must succeed on a 20-point campaign");

    // Held-out: run the *actual* experiments (new seeds = new wall-clock
    // runs) and predict them through the backend's batched predict.
    let (_, test) = executor.run_campaign(&cluster, &test_c);
    let predicted = backend
        .predict(&model.coeffs, &test.params)
        .expect("predict");
    Fig3Data {
        app,
        backend: backend_name,
        test_specs: test_c.specs.clone(),
        errors: PredictionErrors::new(test.times.clone(), predicted),
        model,
        train,
    }
}

/// Data behind one Fig. 4 panel pair: the full (M, R) execution-time
/// surface.
#[derive(Clone, Debug)]
pub struct Fig4Data {
    /// Application swept.
    pub app: AppId,
    /// Mapper-axis lattice values.
    pub ms: Vec<u32>,
    /// Reducer-axis lattice values.
    pub rs: Vec<u32>,
    /// Row-major surface `[ms.len() * rs.len()]`, seconds.
    pub times: Vec<f64>,
}

impl Fig4Data {
    /// (M, R) of the surface minimum — the paper reports (20, 5).
    pub fn argmin(&self) -> (u32, u32) {
        let mut best = (0usize, f64::INFINITY);
        for (i, &t) in self.times.iter().enumerate() {
            if t < best.1 {
                best = (i, t);
            }
        }
        (self.ms[best.0 / self.rs.len()], self.rs[best.0 % self.rs.len()])
    }

    /// Relative fluctuation: (max - min) / min — the paper observes
    /// WordCount fluctuates more than Exim.
    pub fn fluctuation(&self) -> f64 {
        let min = self
            .times
            .iter()
            .cloned()
            .fold(f64::INFINITY, crate::util::stats::total_min);
        let max = self
            .times
            .iter()
            .cloned()
            .fold(0.0, crate::util::stats::total_max);
        (max - min) / min
    }

    /// Mean execution time over the whole surface.
    pub fn mean_time(&self) -> f64 {
        crate::util::stats::mean(&self.times)
    }
}

/// Run the Fig. 4 sweep for one application on a `step`-spaced lattice
/// (serial executor).
pub fn fig4(app: AppId, step: u32, reps: u32, seed: u64) -> Fig4Data {
    fig4_with(&CampaignExecutor::serial(), app, step, reps, seed)
}

/// Fig. 4 sweep through a shared [`CampaignExecutor`]: the whole lattice
/// (64+ settings × reps) is one fan-out over the worker pool, and settings
/// already profiled at this session seed are cache hits.
pub fn fig4_with(
    executor: &CampaignExecutor,
    app: AppId,
    step: u32,
    reps: u32,
    seed: u64,
) -> Fig4Data {
    let cluster = Cluster::paper_cluster();
    let specs = grid_specs(app, step);
    let mut ms: Vec<u32> = specs.iter().map(|s| s.num_mappers).collect();
    ms.dedup();
    let rs: Vec<u32> = specs
        .iter()
        .take_while(|s| s.num_mappers == specs[0].num_mappers)
        .map(|s| s.num_reducers)
        .collect();
    let times: Vec<f64> = executor
        .run_specs(&cluster, &specs, reps, seed)
        .into_iter()
        .map(|r| r.mean_time_s)
        .collect();
    Fig4Data { app, ms, rs, times }
}

/// One row of Table 1: mean and variance of prediction errors.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Application evaluated.
    pub app: AppId,
    /// Reproduced mean prediction error (%).
    pub mean_pct: f64,
    /// Reproduced variance of prediction errors (%).
    pub variance_pct: f64,
    /// Paper's reported values for side-by-side comparison.
    pub paper_mean_pct: f64,
    /// Paper's reported variance.
    pub paper_variance_pct: f64,
}

/// Regenerate Table 1 (both paper applications, serial executor).
pub fn table1(seed: u64) -> Vec<Table1Row> {
    table1_with(&CampaignExecutor::serial(), seed)
}

/// Table 1 through a shared [`CampaignExecutor`].
pub fn table1_with(executor: &CampaignExecutor, seed: u64) -> Vec<Table1Row> {
    AppId::paper_apps()
        .into_iter()
        .map(|app| {
            let d = fig3_with(executor, app, seed);
            let (pm, pv) = match app {
                AppId::WordCount => (0.9204, 2.6013),
                AppId::EximParse => (2.7982, 6.7008),
                AppId::Grep => (f64::NAN, f64::NAN),
            };
            Table1Row {
                app,
                mean_pct: d.errors.mean_pct(),
                variance_pct: d.errors.variance_pct(),
                paper_mean_pct: pm,
                paper_variance_pct: pv,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_argmin_and_fluctuation() {
        let d = Fig4Data {
            app: AppId::WordCount,
            ms: vec![5, 20],
            rs: vec![5, 40],
            times: vec![400.0, 500.0, 300.0, 450.0],
        };
        assert_eq!(d.argmin(), (20, 5));
        assert!((d.fluctuation() - (500.0 - 300.0) / 300.0).abs() < 1e-12);
        assert_eq!(d.mean_time(), 412.5);
    }

    #[test]
    fn fig4_fluctuation_is_nan_honest() {
        // With f64::min/max a NaN cell was silently skipped and the
        // fluctuation looked clean; total order propagates it.
        let d = Fig4Data {
            app: AppId::WordCount,
            ms: vec![5, 20],
            rs: vec![5, 40],
            times: vec![400.0, f64::NAN, 300.0, 450.0],
        };
        assert!(d.fluctuation().is_nan(), "corrupt surface must not hide");
    }

    // Full-pipeline smoke (small lattice, 1 rep) — the real Fig. 3/Table 1
    // regenerations run in `rust/tests/pipeline_e2e.rs` and the benches.
    #[test]
    fn fig4_small_sweep_runs() {
        let d = fig4(AppId::Grep, 35, 1, 1);
        assert_eq!(d.ms, vec![5, 40]);
        assert_eq!(d.rs, vec![5, 40]);
        assert_eq!(d.times.len(), 4);
        assert!(d.times.iter().all(|&t| t > 0.0));
    }
}
