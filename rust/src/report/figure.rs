//! ASCII figures (bar strips, surfaces) and CSV emission.

/// Render paired series (actual vs predicted) as an ASCII strip chart —
/// the shape of the paper's Fig. 3a/3c.
pub fn strip_chart(
    title: &str,
    labels: &[String],
    actual: &[f64],
    predicted: &[f64],
    width: usize,
) -> String {
    assert_eq!(actual.len(), predicted.len());
    let max = actual
        .iter()
        .chain(predicted)
        .cloned()
        .fold(f64::MIN_POSITIVE, crate::util::stats::total_max);
    let mut out = format!("{title}\n");
    let bar = |v: f64| {
        let n = ((v / max) * width as f64).round() as usize;
        "#".repeat(n.min(width))
    };
    for i in 0..actual.len() {
        let label = labels.get(i).map(String::as_str).unwrap_or("");
        out.push_str(&format!(
            "{label:>10} actual    {:>8.1}s |{}\n",
            actual[i],
            bar(actual[i])
        ));
        out.push_str(&format!(
            "{:>10} predicted {:>8.1}s |{}\n",
            "",
            predicted[i],
            bar(predicted[i])
        ));
    }
    out
}

/// Render an error-percent series — the shape of Fig. 3b/3d.
pub fn error_chart(title: &str, labels: &[String], errors_pct: &[f64]) -> String {
    let mut out = format!("{title}\n");
    for (i, &e) in errors_pct.iter().enumerate() {
        let label = labels.get(i).map(String::as_str).unwrap_or("");
        let n = (e * 4.0).round() as usize;
        out.push_str(&format!("{label:>10} {e:>6.2}% |{}\n", "*".repeat(n.min(120))));
    }
    out
}

/// Render a (M, R) -> value surface as an ASCII heatmap grid — Fig. 4.
pub fn surface(
    title: &str,
    ms: &[u32],
    rs: &[u32],
    values: &[f64], // row-major [ms.len() * rs.len()]
) -> String {
    assert_eq!(values.len(), ms.len() * rs.len());
    let mut out = format!("{title}\n      ");
    for r in rs {
        out.push_str(&format!("R={r:<7}"));
    }
    out.push('\n');
    for (i, m) in ms.iter().enumerate() {
        out.push_str(&format!("M={m:<4}"));
        for j in 0..rs.len() {
            out.push_str(&format!("{:>8.1}", values[i * rs.len() + j]));
        }
        out.push('\n');
    }
    out
}

/// Write series as CSV (header + rows).  Columns must be equal length.
pub fn csv(header: &[&str], columns: &[&[f64]]) -> String {
    assert!(!columns.is_empty());
    let rows = columns[0].len();
    assert!(columns.iter().all(|c| c.len() == rows), "ragged columns");
    let mut out = header.join(",");
    out.push('\n');
    for i in 0..rows {
        let row: Vec<String> = columns.iter().map(|c| format!("{}", c[i])).collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_chart_renders_both_series() {
        let s = strip_chart(
            "fig3a",
            &["e1".into()],
            &[100.0],
            &[95.0],
            20,
        );
        assert!(s.contains("actual"));
        assert!(s.contains("predicted"));
        assert!(s.contains("100.0s"));
    }

    #[test]
    fn strip_chart_survives_nan_series() {
        // A NaN sample (degenerate fit upstream) becomes the running max
        // under total order; `v / max` is then NaN, `.round() as usize`
        // saturates to 0, and the chart renders empty bars instead of
        // scaling every other bar against a silently-dropped NaN.
        let s = strip_chart(
            "fig3a",
            &["e1".into(), "e2".into()],
            &[100.0, f64::NAN],
            &[95.0, 90.0],
            20,
        );
        assert!(s.contains("NaN"), "NaN sample shown, not hidden: {s}");
        assert!(s.lines().count() == 5, "all rows rendered");
    }

    #[test]
    fn surface_layout() {
        let s = surface("fig4", &[5, 10], &[5, 40], &[1.0, 2.0, 3.0, 4.0]);
        assert!(s.contains("M=5"));
        assert!(s.contains("R=40"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn csv_round_shape() {
        let s = csv(&["a", "b"], &[&[1.0, 2.0], &[3.0, 4.0]]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines, vec!["a,b", "1,3", "2,4"]);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn csv_rejects_ragged() {
        csv(&["a", "b"], &[&[1.0], &[1.0, 2.0]]);
    }

    #[test]
    fn error_chart_scales_stars() {
        let s = error_chart("err", &["x".into(), "y".into()], &[1.0, 5.0]);
        let stars: Vec<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.matches('*').count())
            .collect();
        assert!(stars[1] > stars[0]);
    }
}
