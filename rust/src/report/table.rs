//! ASCII table renderer.

/// Render rows as a boxed ASCII table; the first row is the header.
pub fn render(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let sep = |c: char| -> String {
        let mut s = String::from("+");
        for w in &widths {
            s.push_str(&c.to_string().repeat(w + 2));
            s.push('+');
        }
        s.push('\n');
        s
    };
    let mut out = sep('-');
    for (ri, row) in rows.iter().enumerate() {
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            let cell = row.get(i).map(String::as_str).unwrap_or("");
            out.push_str(&format!(" {cell:>w$} |", w = w));
        }
        out.push('\n');
        if ri == 0 {
            out.push_str(&sep('='));
        }
    }
    out.push_str(&sep('-'));
    out
}

/// Convenience: stringify a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let t = render(&[
            vec!["app".into(), "mean".into()],
            vec!["wordcount".into(), f(0.92, 2), "extra".into()],
        ]);
        assert!(t.contains("| wordcount |"));
        assert!(t.contains("0.92"));
        assert!(t.contains("===")); // header separator
        // Ragged rows are padded, not dropped.
        assert!(t.contains("extra"));
    }

    #[test]
    fn empty_is_empty() {
        assert_eq!(render(&[]), "");
    }

    #[test]
    fn alignment_right_justified() {
        let t = render(&[
            vec!["x".into(), "value".into()],
            vec!["a".into(), "1".into()],
        ]);
        assert!(t.contains("|     1 |"), "{t}");
    }
}
