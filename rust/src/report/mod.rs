//! Regeneration of the paper's evaluation artifacts.
//!
//! [`experiments`] runs the full pipelines behind Fig. 3, Fig. 4 and
//! Table 1; [`table`] and [`figure`] render them as ASCII and CSV.  The
//! CLI (`mrtuner fig3|fig4|table1`) and the benches
//! (`rust/benches/fig*_*.rs`) both call into this module, so the printed
//! rows are identical no matter the entry point.

pub mod e2e;
pub mod experiments;
pub mod figure;
pub mod table;

pub use experiments::{fig3, fig4, table1, Fig3Data, Fig4Data, Table1Row};
