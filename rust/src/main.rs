//! mrtuner CLI — leader entrypoint for the reproduction.
//!
//! Subcommands:
//!   profile   run a profiling campaign (paper Fig. 2a) and save a dataset
//!   fit       fit a regression model from a dataset (Eqn. 6, via PJRT)
//!   predict   predict one (app, M, R) setting from a saved model
//!   run-job   simulate a single job and print its phase breakdown
//!   fig3      regenerate Fig. 3 (a,b or c,d) for one application
//!   fig4      regenerate the Fig. 4 execution-time surface
//!   table1    regenerate Table 1 (both paper applications)
//!   ext4      extended 4-parameter sweep (M, R, input, block; time + CPU)
//!   serve     start the TCP prediction service
//!   e2e       full end-to-end validation (same driver as examples/e2e_repro)
//!   store     inspect/compact/clear a persistent profile store
//!   dlq       list/retry/clear the store's dead-letter queue of failed reps
//!   bench     store/executor/serving microbenchmarks -> BENCH_*.json
//!   lint      repo-invariant static analysis over rust/src (CI gate)

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::coordinator::{
    Client, ClientError, ModelRegistry, PipelinedClient, PredictionService,
    ServeOptions, Server, ServiceConfig, Trainer,
};
use mrtuner::model::features::NUM_FEATURES;
use mrtuner::model::ndpoly::NdPolyModel;
use mrtuner::model::regression::RegressionModel;
use mrtuner::mr::{run_job, JobConfig, RepOutcome};
use mrtuner::profiler::campaign::grid_specs;
use mrtuner::profiler::dlq;
use mrtuner::profiler::extended::{random_ext4, scales, Ext4Spec};
use mrtuner::profiler::store::{FileBackend, StoreBackend, StoreOptions};
use mrtuner::profiler::{
    cluster_fingerprint, ext4_rep_jobs, paper_campaign, Campaign,
    CampaignExecutor, Dataset, DlqRecord, ExperimentSpec, ProfileStore,
    RepJob, StoreKey,
};
use mrtuner::report::{e2e, experiments, figure, table};
use mrtuner::util::benchkit::{bench, BenchStats};
use mrtuner::util::bytes::fmt_secs;
use mrtuner::util::cli::Args;
use mrtuner::util::json::Json;
use mrtuner::util::rng::Rng;
use mrtuner::util::stats;

/// The machine-wide store directory from `MRTUNER_STORE`, if set.
fn env_store_path() -> Option<String> {
    std::env::var("MRTUNER_STORE").ok().filter(|s| !s.is_empty())
}

/// Resolve the persistent profile-store directory: `--store PATH` wins,
/// then the `MRTUNER_STORE` environment variable; `--no-store` disables
/// both (one-off cold runs, benchmarking).
fn store_path_from(args: &Args) -> Option<String> {
    let explicit = args.str_opt("store");
    if args.switch("no-store") {
        return None;
    }
    explicit.or_else(env_store_path)
}

/// Resolve the store size cap in bytes: `--store-max-mb N` wins, then the
/// `MRTUNER_STORE_MAX_MB` environment variable.  When set, compaction
/// evicts least-recently-used records (paper-plane reps are pinned) so
/// the index never exceeds the cap.
fn store_cap_from(args: &Args) -> Result<Option<u64>, String> {
    // Track where the value came from, so a bad value blames the knob
    // the user actually turned (flag vs environment variable).
    let (raw, source) = match args.str_opt("store-max-mb") {
        Some(s) => (Some(s), "--store-max-mb"),
        None => (
            std::env::var("MRTUNER_STORE_MAX_MB").ok().filter(|s| !s.is_empty()),
            "MRTUNER_STORE_MAX_MB",
        ),
    };
    match raw {
        None => Ok(None),
        Some(s) => {
            let mb: u64 = s
                .parse()
                .map_err(|_| format!("{source}: bad integer '{s}'"))?;
            if mb == 0 {
                return Err(format!("{source} must be >= 1"));
            }
            Ok(Some(mb * 1024 * 1024))
        }
    }
}

/// Resolve the requested shard count from `--store-shards N`.  The
/// `MRTUNER_STORE_SHARDS` fallback (and the rule that an existing
/// `shards.meta` overrules both) lives in the store itself, so every
/// open path agrees.
fn store_shards_from(args: &Args) -> Result<Option<usize>, String> {
    match args.str_opt("store-shards") {
        None => Ok(None),
        Some(s) => {
            let n: usize = s
                .parse()
                .map_err(|_| format!("--store-shards: bad integer '{s}'"))?;
            if n == 0 {
                return Err("--store-shards must be >= 1".into());
            }
            Ok(Some(n))
        }
    }
}

/// Build the profiling executor from `--jobs N` (default: one worker per
/// core), attaching the persistent profile store when one is configured.
/// Campaign output is bit-identical whatever the worker count, and warm
/// store runs are bit-identical to cold ones.
fn executor_from(args: &Args) -> Result<CampaignExecutor, String> {
    let exec = match args.str_opt("jobs") {
        None => CampaignExecutor::machine_sized(),
        Some(s) => {
            let n: u64 = s.parse().map_err(|_| format!("--jobs: bad integer '{s}'"))?;
            CampaignExecutor::new(n as usize)
        }
    };
    // Parse the cap unconditionally (so the flag is always recognized)
    // but only *validate* it when a store is actually configured — a
    // storeless run must not be blocked by a malformed machine-wide
    // MRTUNER_STORE_MAX_MB that could never affect it.
    let cap = store_cap_from(args);
    let shards = store_shards_from(args);
    // Cooperative drain only makes sense against a shared on-disk store:
    // the per-setting leases live inside its directory.
    let cooperative = args.switch("cooperative");
    match store_path_from(args) {
        Some(p) => {
            let store = ProfileStore::open_with_opts(
                Path::new(&p),
                StoreOptions {
                    cap_bytes: cap?,
                    shards: shards?,
                    ..StoreOptions::default()
                },
            )?;
            // Deliberately NOT store.len() here: counting residents
            // would force every shard to load, and the fast lazy open
            // is the point of the sharded layout.
            eprintln!(
                "profile store: {} ({} shards)",
                p,
                store.shard_count()
            );
            Ok(exec.with_store(store).with_cooperative(cooperative))
        }
        None if cooperative => Err(
            "--cooperative requires a persistent store (--store PATH or \
             MRTUNER_STORE)"
                .into(),
        ),
        None => Ok(exec),
    }
}

/// One-line machine-greppable summary of where this invocation's reps
/// came from (simulated vs in-memory vs on-disk).
fn report_executor(executor: &CampaignExecutor) {
    eprintln!("executor stats: {}", executor.stats());
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let result = match sub.as_str() {
        "profile" => cmd_profile(&args),
        "fit" => cmd_fit(&args),
        "predict" => cmd_predict(&args),
        "run-job" => cmd_run_job(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig4(&args),
        "table1" => cmd_table1(&args),
        "ext4" => cmd_ext4(&args),
        "serve" => cmd_serve(&args),
        "e2e" => cmd_e2e(&args),
        "store" => cmd_store(&args),
        "dlq" => cmd_dlq(&args),
        "bench" => cmd_bench(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}' (try `mrtuner help`)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "mrtuner — MapReduce configuration-parameter execution-time modeling\n\
         (reproduction of Rizvandi et al., 2012)\n\n\
         USAGE: mrtuner <SUBCOMMAND> [--flags]\n\n\
         SUBCOMMANDS\n\
           profile  --app A [--seed N] [--out FILE] [--jobs N] [--resume]\n\
           fit      --data FILE [--out FILE]             fit model from dataset\n\
           predict  --model FILE --mappers M --reducers R\n\
           run-job  --app A --mappers M --reducers R [--seed N]\n\
           fig3     --app A [--seed N] [--csv FILE] [--jobs N]\n\
           fig4     --app A [--step N] [--reps N] [--csv FILE] [--jobs N]\n\
           table1   [--seed N] [--jobs N]                mean/variance of errors\n\
           ext4     --app A [--train N] [--test N] [--reps N] [--seed N]\n\
                    [--csv FILE] [--jobs N] [--resume]   4-parameter sweep:\n\
                    T and CPU-seconds vs (M, R, input GB, block MB)\n\
           serve    [--addr HOST:PORT] [--seed N] [--jobs N]\n\
                    [--retrain-every SECS] [--serve-workers N]\n\
                    [--serve-queue N]\n\
                    TCP prediction service (JSON lines + pipelined binary\n\
                    protocol, autodetected per connection); with --store it\n\
                    also runs the online trainer (protocol op `retrain`,\n\
                    plus a periodic refit every SECS seconds) so newly\n\
                    profiled apps are served without restart.  Models are\n\
                    fit per target (time_s | cpu_s | shuffle_bytes); add\n\
                    \"target\":\"shuffle_bytes\" to a predict op (or query\n\
                    app \"wordcount@shuffle_bytes\") for non-time targets\n\
           e2e      [--seed N] [--jobs N]                full pipeline validation\n\
           store    <stats|compact|clear> --store PATH [--store-max-mb N]\n\
                    persistent profile store maintenance; stats prints a\n\
                    per-shard breakdown plus combined totals, compact runs\n\
                    one synchronous pass over every shard (migrating any\n\
                    legacy single-directory layout first)\n\
           dlq      <list|retry|clear> --store PATH     dead-letter queue:\n\
                    reps that kept failing are quarantined there instead\n\
                    of aborting a campaign; retry re-runs them through the\n\
                    executor (recovered reps land in the store)\n\
           bench    <store|campaign|serve|trainer> [--records N] [--reps N]\n\
                    [--jobs N] [--requests N] [--clients N] [--window W]\n\
                    [--settings N] [--out FILE]  store/executor/serving/\n\
                    trainer microbenchmarks; writes BENCH_store.json /\n\
                    BENCH_campaign.json / BENCH_serve.json /\n\
                    BENCH_trainer.json\n\
           lint     [--root DIR] [--json]               static analysis:\n\
                    determinism, NaN-ordering, lock-discipline and\n\
                    panic-free-hot-path rules over DIR (default rust/src);\n\
                    exits non-zero on any unsuppressed finding\n\n\
         --jobs N sets the profiling worker count (default: all cores);\n\
         campaign results are bit-identical for any N.\n\n\
         --store PATH attaches a persistent on-disk profile store to any\n\
         profiling subcommand: completed reps are saved and every later\n\
         invocation warm-starts from them (bit-identical to a cold run).\n\
         MRTUNER_STORE=PATH does the same machine-wide; --no-store\n\
         disables both for one invocation.  --store-max-mb N (or\n\
         MRTUNER_STORE_MAX_MB=N) caps the compacted store size: coldest\n\
         records are evicted first, paper-plane reps are never evicted.\n\
         Stores are sharded per application; --store-shards N (or\n\
         MRTUNER_STORE_SHARDS=N, default 4) picks the shard count for a\n\
         *new* store — an existing store's shards.meta always wins.\n\n\
         The store journal doubles as a campaign checkpoint: an\n\
         interrupted (even SIGKILLed) store-backed campaign re-run with\n\
         the same flags re-simulates only what is missing.  --resume\n\
         (profile | ext4) additionally reports the done/missing diff\n\
         before dispatch.  --cooperative lets N processes pointed at one\n\
         store split a campaign via per-setting leases.\n\n\
         APPS: wordcount | exim | grep | sort | join"
    );
}

fn parse_app(args: &Args) -> Result<AppId, String> {
    AppId::parse(&args.str_or("app", "wordcount"))
}

fn cmd_profile(args: &Args) -> Result<(), String> {
    let app = parse_app(args)?;
    let seed = args.u64_or("seed", 42)?;
    let out = args.str_or("out", &format!("{}_train.json", app.name()));
    let resume = args.switch("resume");
    let executor = executor_from(args)?;
    args.reject_unknown()?;
    let cluster = Cluster::paper_cluster();
    let (train, _) = paper_campaign(app, seed);
    if resume {
        // The store journal *is* the checkpoint: report how much of this
        // campaign is already on disk, then dispatch only the remainder
        // (the executor skips completed reps on its own).
        let status = executor.campaign_resume_status(&cluster, &train)?;
        eprintln!("resume: {status}");
    }
    eprintln!(
        "profiling {} settings x {} reps for {} ({} workers) ...",
        train.specs.len(),
        train.reps,
        app.name(),
        executor.jobs()
    );
    let (results, ds) = train.run_with(&cluster, &executor);
    for r in &results {
        eprintln!(
            "  M={:<3} R={:<3} mean {:>8} (+-{:.1}s over {} reps)",
            r.spec.num_mappers,
            r.spec.num_reducers,
            fmt_secs(r.mean_time_s),
            r.rep_stddev(),
            r.rep_times_s.len()
        );
    }
    ds.save(&PathBuf::from(&out)).map_err(|e| e.to_string())?;
    println!("wrote {out} ({} rows)", ds.len());
    report_executor(&executor);
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<(), String> {
    let data = args.str_opt("data").ok_or("--data FILE required")?;
    let out = args.str_or("out", "model.json");
    args.reject_unknown()?;
    let ds = Dataset::load(&PathBuf::from(&data))?;
    let (mut backend, name) = experiments::default_backend();
    let model = RegressionModel::fit_dataset(backend.as_mut(), &ds)?;
    model.save(&PathBuf::from(&out)).map_err(|e| e.to_string())?;
    println!(
        "fitted {} on {} rows via {name}; coefficients {:?}",
        model.app_name, model.trained_on, model.coeffs
    );
    println!("wrote {out}");
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<(), String> {
    let model_path = args.str_opt("model").ok_or("--model FILE required")?;
    let m = args.u64_or("mappers", 20)? as u32;
    let r = args.u64_or("reducers", 5)? as u32;
    args.reject_unknown()?;
    let model = RegressionModel::load(&PathBuf::from(&model_path))?;
    let (mut backend, name) = experiments::default_backend();
    let pred = backend
        .predict(&model.coeffs, &[[m as f64, r as f64]])?
        .pop()
        .unwrap();
    println!(
        "{}: predicted total execution time for M={m}, R={r}: {} ({name})",
        model.app_name,
        fmt_secs(pred)
    );
    Ok(())
}

fn cmd_run_job(args: &Args) -> Result<(), String> {
    let app = parse_app(args)?;
    let m = args.u64_or("mappers", 20)? as u32;
    let r = args.u64_or("reducers", 5)? as u32;
    let seed = args.u64_or("seed", 0)?;
    args.reject_unknown()?;
    let cluster = Cluster::paper_cluster();
    let config = JobConfig::paper_default(m, r).with_seed(seed);
    let res = run_job(&cluster, &app.profile(), &config);
    println!("app            : {}", app.name());
    println!("mappers        : {m}   reducers: {r}   seed: {seed}");
    println!("total time     : {}", fmt_secs(res.total_time_s));
    println!("map phase end  : {}", fmt_secs(res.map_phase_s));
    println!("first reducer  : {}", fmt_secs(res.first_reduce_s));
    println!(
        "locality       : {:.0}% data-local maps",
        100.0 * res.locality_fraction()
    );
    println!(
        "speculation    : {} launched, {} won",
        res.counters.speculative_maps, res.counters.speculative_wins
    );
    println!(
        "shuffle bytes  : {}",
        mrtuner::util::bytes::fmt_bytes(res.counters.shuffle_bytes)
    );
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<(), String> {
    let app = parse_app(args)?;
    let seed = args.u64_or("seed", 42)?;
    let csv_out = args.str_opt("csv");
    let executor = executor_from(args)?;
    args.reject_unknown()?;
    let d = experiments::fig3_with(&executor, app, seed);
    let labels: Vec<String> = d
        .test_specs
        .iter()
        .map(|s| format!("({},{})", s.num_mappers, s.num_reducers))
        .collect();
    println!(
        "{}",
        figure::strip_chart(
            &format!(
                "Fig. 3 ({}) — actual vs predicted, backend {}",
                app.name(),
                d.backend
            ),
            &labels,
            &d.errors.actual,
            &d.errors.predicted,
            48,
        )
    );
    println!(
        "{}",
        figure::error_chart(
            &format!("Fig. 3 ({}) — prediction error", app.name()),
            &labels,
            &d.errors.errors_pct,
        )
    );
    println!(
        "mean error {:.2}%  variance {:.2}%  median {:.2}%  max {:.2}%  R^2 {:.4}",
        d.errors.mean_pct(),
        d.errors.variance_pct(),
        d.errors.median_pct(),
        d.errors.max_pct(),
        d.errors.r_squared()
    );
    if let Some(path) = csv_out {
        let ms: Vec<f64> = d.test_specs.iter().map(|s| s.num_mappers as f64).collect();
        let rs: Vec<f64> = d.test_specs.iter().map(|s| s.num_reducers as f64).collect();
        let csv = figure::csv(
            &["mappers", "reducers", "actual_s", "predicted_s", "error_pct"],
            &[&ms, &rs, &d.errors.actual, &d.errors.predicted, &d.errors.errors_pct],
        );
        std::fs::write(&path, csv).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    report_executor(&executor);
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<(), String> {
    let app = parse_app(args)?;
    let step = args.u64_or("step", 5)? as u32;
    let reps = args.u64_or("reps", 5)? as u32;
    let seed = args.u64_or("seed", 42)?;
    let csv_out = args.str_opt("csv");
    let executor = executor_from(args)?;
    args.reject_unknown()?;
    let d = experiments::fig4_with(&executor, app, step, reps, seed);
    println!(
        "{}",
        figure::surface(
            &format!("Fig. 4 ({}) — total execution time (s) vs M, R", app.name()),
            &d.ms,
            &d.rs,
            &d.times,
        )
    );
    let (bm, br) = d.argmin();
    println!(
        "minimum at M={bm}, R={br} (paper: 20, 5); fluctuation {:.2}; mean {}",
        d.fluctuation(),
        fmt_secs(d.mean_time())
    );
    if let Some(path) = csv_out {
        let mut ms = Vec::new();
        let mut rs = Vec::new();
        for m in &d.ms {
            for r in &d.rs {
                ms.push(*m as f64);
                rs.push(*r as f64);
            }
        }
        let csv = figure::csv(&["mappers", "reducers", "time_s"], &[&ms, &rs, &d.times]);
        std::fs::write(&path, csv).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    report_executor(&executor);
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<(), String> {
    let seed = args.u64_or("seed", 42)?;
    let executor = executor_from(args)?;
    args.reject_unknown()?;
    let rows = experiments::table1_with(&executor, seed);
    let mut t = vec![vec![
        "application".to_string(),
        "mean (%)".to_string(),
        "variance (%)".to_string(),
        "paper mean (%)".to_string(),
        "paper variance (%)".to_string(),
    ]];
    for r in &rows {
        t.push(vec![
            r.app.name().to_string(),
            table::f(r.mean_pct, 4),
            table::f(r.variance_pct, 4),
            table::f(r.paper_mean_pct, 4),
            table::f(r.paper_variance_pct, 4),
        ]);
    }
    println!("Table 1 — statistical mean and variance of prediction errors");
    print!("{}", table::render(&t));
    let all_under_5 = rows.iter().all(|r| r.mean_pct < 5.0);
    println!(
        "headline claim (mean error < 5%): {}",
        if all_under_5 { "REPRODUCED" } else { "NOT reproduced" }
    );
    report_executor(&executor);
    Ok(())
}

fn cmd_ext4(args: &Args) -> Result<(), String> {
    let app = parse_app(args)?;
    let seed = args.u64_or("seed", 42)?;
    let train_n = args.u64_or("train", 60)? as usize;
    let test_n = args.u64_or("test", 25)? as usize;
    let reps = args.u64_or("reps", 5)? as u32;
    let csv_out = args.str_opt("csv");
    let resume = args.switch("resume");
    let executor = executor_from(args)?;
    args.reject_unknown()?;
    if train_n == 0 || test_n == 0 || reps == 0 {
        return Err("--train, --test and --reps must all be >= 1".into());
    }
    let cluster = Cluster::paper_cluster();
    // Settings are sampled from the CLI seed; profiling sessions reuse
    // the paper protocol's split (train at `seed`, held-out at a distinct
    // session so test runs are genuinely new executions).
    let mut rng = Rng::new(seed ^ 0xE474_5377_3E50_5EED);
    let train_specs = random_ext4(app, train_n, &mut rng);
    let test_specs = random_ext4(app, test_n, &mut rng);
    if resume {
        // Diff the whole invocation's work list (both sessions) against
        // the store, then dispatch only the remainder.
        let mut jobs = ext4_rep_jobs(&train_specs, reps, seed);
        jobs.extend(ext4_rep_jobs(
            &test_specs,
            reps,
            seed.wrapping_add(0x7E57),
        ));
        let status = executor.resume_status(&cluster, &jobs)?;
        eprintln!("resume: {status}");
    }
    eprintln!(
        "ext4 profiling {} train + {} test settings x {} reps for {} ({} workers) ...",
        train_specs.len(),
        test_specs.len(),
        reps,
        app.name(),
        executor.jobs()
    );
    let (rows, times, cpus) =
        executor.run_ext4_campaign(&cluster, &train_specs, reps, seed);
    let (trows, ttimes, tcpus) = executor.run_ext4_campaign(
        &cluster,
        &test_specs,
        reps,
        seed.wrapping_add(0x7E57),
    );

    let w = vec![1.0; rows.len()];
    let time_model =
        NdPolyModel::fit(app.name(), &rows, &times, &w, 3, &scales())?;
    let cpu_model =
        NdPolyModel::fit(app.name(), &rows, &cpus, &w, 3, &scales())?;
    let tpred = time_model.predict(&trows);
    let cpred = cpu_model.predict(&trows);

    println!(
        "ext4 ({}) — held-out predictions over (M, R, input GB, block MB)",
        app.name()
    );
    let mut t = vec![vec![
        "M".to_string(),
        "R".to_string(),
        "input (GB)".to_string(),
        "block (MB)".to_string(),
        "actual T (s)".to_string(),
        "predicted T (s)".to_string(),
        "err (%)".to_string(),
    ]];
    for (i, s) in test_specs.iter().enumerate() {
        t.push(vec![
            s.num_mappers.to_string(),
            s.num_reducers.to_string(),
            table::f(s.input_gb, 1),
            s.block_mb.to_string(),
            table::f(ttimes[i], 1),
            table::f(tpred[i], 1),
            table::f(100.0 * (tpred[i] - ttimes[i]).abs() / ttimes[i], 2),
        ]);
    }
    print!("{}", table::render(&t));

    println!(
        "T(M,R,input,block) additive basis : mean |err| {:.3}% ({} features)",
        stats::mean_abs_err_pct(&tpred, &ttimes),
        time_model.num_features()
    );
    // The additive Eqn.-2 basis cannot express the input x block coupling
    // (it sets the map-task count); pairwise interactions close the gap
    // when the training set is big enough to identify them.
    let inter_features = NdPolyModel::feature_count(scales().len(), 3, true);
    if rows.len() >= inter_features {
        let inter = NdPolyModel::fit_opts(
            app.name(),
            &rows,
            &times,
            &w,
            3,
            &scales(),
            true,
        )?;
        println!(
            "  + pairwise interactions         : mean |err| {:.3}% ({} features)",
            stats::mean_abs_err_pct(&inter.predict(&trows), &ttimes),
            inter.num_features()
        );
    } else {
        println!(
            "  + pairwise interactions         : skipped \
             (needs >= {inter_features} training settings)"
        );
    }
    println!(
        "CPU-seconds model ([24])          : mean |err| {:.3}%",
        stats::mean_abs_err_pct(&cpred, &tcpus)
    );

    if let Some(path) = csv_out {
        let ms: Vec<f64> = test_specs.iter().map(|s| s.num_mappers as f64).collect();
        let rs: Vec<f64> = test_specs.iter().map(|s| s.num_reducers as f64).collect();
        let igb: Vec<f64> = test_specs.iter().map(|s| s.input_gb).collect();
        let blk: Vec<f64> = test_specs.iter().map(|s| s.block_mb as f64).collect();
        let csv = figure::csv(
            &[
                "mappers",
                "reducers",
                "input_gb",
                "block_mb",
                "actual_s",
                "predicted_s",
                "actual_cpu_s",
                "predicted_cpu_s",
            ],
            &[&ms, &rs, &igb, &blk, &ttimes, &tpred, &tcpus, &cpred],
        );
        std::fs::write(&path, csv).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    report_executor(&executor);
    Ok(())
}

fn cmd_store(args: &Args) -> Result<(), String> {
    let action = args
        .positional(0)
        .ok_or("usage: mrtuner store <stats|compact|clear> --store PATH")?;
    let path = args
        .str_opt("store")
        .or_else(env_store_path)
        .ok_or("--store PATH (or MRTUNER_STORE) required")?;
    // Parse the cap but validate it only on the `compact` path: stats
    // and clear must keep working on fleets that export a (possibly
    // malformed) machine-wide MRTUNER_STORE_MAX_MB.
    let cap = store_cap_from(args);
    args.reject_unknown()?;
    // The *explicit* flag on a non-compact action is a user error —
    // nobody should believe `stats --store-max-mb N` reported against
    // a cap.
    if args.str_opt("store-max-mb").is_some() && action != "compact" {
        return Err("--store-max-mb only applies to `store compact`".into());
    }
    let dir = PathBuf::from(&path);
    match action.as_str() {
        "stats" => {
            // Peek: report what is on disk without rewriting anything.
            let store = ProfileStore::peek(&dir)?;
            for (i, st) in store.shard_stats().iter().enumerate() {
                println!("  shard-{i:02}: {st}");
            }
            println!(
                "store {}: {} shard(s), {}",
                dir.display(),
                store.shard_count(),
                store.stats()
            );
            Ok(())
        }
        "compact" => {
            // Synchronous: the CLI's promise is that the work is done
            // when it returns, so the background thread stays off.
            let store = ProfileStore::open_with_opts(
                &dir,
                StoreOptions {
                    cap_bytes: cap?,
                    background_compaction: false,
                    ..StoreOptions::default()
                },
            )?;
            let pass = store.compact_now()?;
            println!(
                "store {}: merged {} segment(s); {pass}",
                dir.display(),
                pass.merged_segments
            );
            Ok(())
        }
        "clear" => {
            let removed = ProfileStore::clear(&dir)?;
            println!("store {}: removed {removed} file(s)", dir.display());
            Ok(())
        }
        other => {
            Err(format!("unknown store action '{other}' (stats | compact | clear)"))
        }
    }
}

fn cmd_dlq(args: &Args) -> Result<(), String> {
    let action = args
        .positional(0)
        .ok_or("usage: mrtuner dlq <list|retry|clear> --store PATH")?;
    let path = args
        .str_opt("store")
        .or_else(env_store_path)
        .ok_or("--store PATH (or MRTUNER_STORE) required")?;
    let dir = dlq::dlq_dir(Path::new(&path));
    match action.as_str() {
        "list" => {
            args.reject_unknown()?;
            let records = dlq::load(&dir)?;
            for r in &records {
                println!(
                    "  {} M={} R={} input={}GB block={}MB rep={} seed={} \
                     attempts={} error={:?}",
                    r.key.app.name(),
                    r.key.num_mappers,
                    r.key.num_reducers,
                    r.key.input_gb(),
                    r.key.block_mb,
                    r.key.rep,
                    r.key.base_seed,
                    r.attempts,
                    r.error,
                );
            }
            println!(
                "dlq {}: {} quarantined rep(s)",
                dir.display(),
                records.len()
            );
            Ok(())
        }
        "retry" => {
            // Reuses the profiling executor, so a recovered rep lands in
            // the store exactly as if the original campaign had run it —
            // and a rep that *keeps* failing re-quarantines itself.
            let executor = executor_from(args)?;
            args.reject_unknown()?;
            if !executor.stats().store_attached {
                // Without the store, recovered reps would evaporate and
                // taken records could not re-quarantine: refuse up front.
                return Err("dlq retry requires the store (drop --no-store)".into());
            }
            let cluster = Cluster::paper_cluster();
            let fp = cluster_fingerprint(&cluster);
            let records = dlq::take(&dir)?;
            if records.is_empty() {
                println!("dlq {}: empty, nothing to retry", dir.display());
                return Ok(());
            }
            // Records keyed under a different cluster fingerprint cannot
            // be re-simulated here: park them again untouched.
            let (ours, foreign): (Vec<_>, Vec<_>) =
                records.into_iter().partition(|r| r.key.cluster == fp);
            if !foreign.is_empty() {
                dlq::append(&dir, &foreign)?;
                eprintln!(
                    "dlq: {} record(s) keyed under a different cluster \
                     fingerprint; left quarantined",
                    foreign.len()
                );
            }
            // A StoreKey carries every simulation coordinate, so any
            // quarantined rep rebuilds as an extended work item (on the
            // paper plane that *is* the 2-parameter rep, bit for bit).
            let jobs: Vec<RepJob> = ours
                .iter()
                .map(|r| {
                    RepJob::ext4(
                        Ext4Spec {
                            app: r.key.app,
                            num_mappers: r.key.num_mappers,
                            num_reducers: r.key.num_reducers,
                            input_gb: r.key.input_gb(),
                            block_mb: r.key.block_mb,
                        },
                        r.key.rep,
                        r.key.base_seed,
                    )
                })
                .collect();
            let outcomes = executor.run_outcomes(&cluster, &jobs);
            executor.flush_store()?;
            let recovered =
                outcomes.iter().filter(|o| o.time_s.is_finite()).count();
            report_executor(&executor);
            println!(
                "dlq {}: retried {} rep(s): {recovered} recovered, {} \
                 re-quarantined",
                dir.display(),
                jobs.len(),
                jobs.len() - recovered
            );
            Ok(())
        }
        "clear" => {
            args.reject_unknown()?;
            let removed = dlq::clear(&dir)?;
            println!("dlq {}: dropped {removed} record(s)", dir.display());
            Ok(())
        }
        other => Err(format!("unknown dlq action '{other}' (list | retry | clear)")),
    }
}

/// One benchkit case rendered into the `BENCH_*.json` schema.
fn bench_case(st: &BenchStats, units: f64) -> Json {
    Json::obj(vec![
        ("name", Json::Str(st.name.clone())),
        ("iters", Json::Num(st.iters as f64)),
        ("mean_s", Json::Num(st.mean_s)),
        ("min_s", Json::Num(st.min_s)),
        ("p50_s", Json::Num(st.p50_s)),
        ("units_per_s", Json::Num(st.throughput(units))),
    ])
}

/// `mrtuner lint [--root DIR] [--json]` — run the static-analysis pass
/// over DIR (default `rust/src`) and exit non-zero on any unsuppressed
/// finding, so CI can gate on it next to clippy.
fn cmd_lint(args: &Args) -> Result<(), String> {
    let root = args.str_or("root", "rust/src");
    let json = args.switch("json");
    args.reject_unknown()?;
    let report = mrtuner::analysis::run_lint(Path::new(&root))?;
    for finding in &report.findings {
        if json {
            println!("{}", finding.to_json());
        } else {
            println!("{}", finding.render());
        }
    }
    if report.findings.is_empty() {
        eprintln!("lint: {} files clean under {root}", report.files_scanned);
        Ok(())
    } else {
        Err(format!(
            "lint: {} finding(s) across {} files under {root}",
            report.findings.len(),
            report.files_scanned
        ))
    }
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let what = args
        .positional(0)
        .ok_or("usage: mrtuner bench <store|campaign|serve|trainer> [--flags]")?;
    match what.as_str() {
        "store" => bench_store(args),
        "campaign" => bench_campaign(args),
        "serve" => bench_serve(args),
        "trainer" => bench_trainer(args),
        other => Err(format!(
            "unknown bench target '{other}' (store | campaign | serve | trainer)"
        )),
    }
}

/// Synthetic serving model for `bench serve`: coefficients chosen so
/// predictions vary with (M, R); the intercept parameterizes hot-swap
/// refits.
fn serve_bench_model(intercept: f64) -> RegressionModel {
    let mut coeffs = [0.0; NUM_FEATURES];
    coeffs[0] = intercept;
    coeffs[1] = 40.0;
    coeffs[4] = -8.0;
    RegressionModel { app_name: "wordcount".into(), coeffs, trained_on: 20 }
}

/// Serving-path benchmark over a real loopback server: unloaded
/// round-trip latency and concurrent throughput for both protocols
/// (legacy JSON lines vs pipelined binary), cross-protocol prediction
/// bit-identity, version monotonicity under hot-swap, and the shed rate
/// of a deliberately starved queue.  Results land in `BENCH_serve.json`
/// (`--out`), the serving leg of the perf trajectory CI validates.
fn bench_serve(args: &Args) -> Result<(), String> {
    let requests = args.u64_or("requests", 40_000)? as usize;
    let clients = args.u64_or("clients", 4)? as usize;
    let window = args.u64_or("window", 64)? as usize;
    let out = args.str_or("out", "BENCH_serve.json");
    args.reject_unknown()?;
    if requests == 0 || clients == 0 || window == 0 {
        return Err(
            "--requests, --clients and --window must all be >= 1".into()
        );
    }

    // The bench measures the serving path, not the fit: install a
    // synthetic model directly.
    let mut registry = ModelRegistry::new();
    registry.insert(serve_bench_model(400.0));
    let service = Arc::new(PredictionService::start(
        || experiments::default_backend().0,
        registry,
        ServiceConfig::default(),
    ));
    let server = Server::start_tuned(
        "127.0.0.1:0",
        Arc::clone(&service),
        None,
        ServeOptions::default(),
    )
    .map_err(|e| e.to_string())?;
    let addr = server.addr.to_string();

    let per_client = requests.div_ceil(clients);
    let total = per_client * clients;
    let workload: Vec<(String, u32, u32)> = (0..per_client)
        .map(|i| {
            (
                "wordcount".to_string(),
                5 + (i % 36) as u32,
                5 + (i % 7) as u32,
            )
        })
        .collect();
    println!(
        "bench serve: {total} predicts, {clients} client(s), window {window}"
    );

    // Unloaded request-level round-trip latency, per protocol.
    let lat_iters = requests.clamp(100, 2_000) as u32;
    let json_lat = {
        let mut c = Client::connect(&addr).map_err(|e| e.to_string())?;
        bench("json predict round-trip, unloaded", 50, lat_iters, || {
            c.predict("wordcount", 20, 5).unwrap();
        })
    };
    let bin_lat = {
        let mut c =
            PipelinedClient::connect(&addr).map_err(|e| e.to_string())?;
        bench("binary predict round-trip, unloaded", 50, lat_iters, || {
            let id = c.submit_predict("wordcount", 20, 5);
            c.flush().unwrap();
            let (got, _) = c.recv().unwrap();
            assert_eq!(got, id);
        })
    };

    // Concurrent throughput: same workload, both protocols.  The JSON
    // protocol is strictly request-response; the binary protocol keeps
    // `window` requests in flight per connection.
    let json_tp = bench("json throughput, concurrent clients", 0, 2, || {
        std::thread::scope(|s| {
            for _ in 0..clients {
                s.spawn(|| {
                    let mut c = Client::connect(&addr).unwrap();
                    for (app, m, r) in &workload {
                        c.predict(app, *m, *r).unwrap();
                    }
                });
            }
        });
    });
    let bin_tp =
        bench("binary pipelined throughput, concurrent clients", 0, 2, || {
            std::thread::scope(|s| {
                for _ in 0..clients {
                    s.spawn(|| {
                        let mut c = PipelinedClient::connect(&addr).unwrap();
                        let replies =
                            c.predict_many(&workload, window).unwrap();
                        for r in &replies {
                            r.as_ref().unwrap();
                        }
                    });
                }
            });
        });

    // Cross-protocol bit-identity: both protocols must answer every
    // probe with exactly the same bits and version.
    let probe: Vec<(String, u32, u32)> = (0..200)
        .map(|i| {
            (
                "wordcount".to_string(),
                5 + (i % 36) as u32,
                5 + (i % 7) as u32,
            )
        })
        .collect();
    let mut bit_identical = true;
    {
        let mut jc = Client::connect(&addr).map_err(|e| e.to_string())?;
        let mut bc =
            PipelinedClient::connect(&addr).map_err(|e| e.to_string())?;
        let bin = bc.predict_many(&probe, window).map_err(|e| e.to_string())?;
        for ((app, m, r), b) in probe.iter().zip(&bin) {
            let b = b.as_ref().map_err(|e| e.to_string())?;
            let j =
                jc.predict_versioned(app, *m, *r).map_err(|e| e.to_string())?;
            if j.seconds.to_bits() != b.seconds.to_bits()
                || j.version != b.version
            {
                bit_identical = false;
            }
        }
    }

    // Hot-swap monotonicity: versions observed by a pipelined stream
    // must never go backwards while refits publish concurrently.
    let monotonic = {
        let mut bc =
            PipelinedClient::connect(&addr).map_err(|e| e.to_string())?;
        let load: Vec<(String, u32, u32)> = (0..4_000)
            .map(|i| ("wordcount".to_string(), 5 + (i % 36) as u32, 5))
            .collect();
        let swap_service = Arc::clone(&service);
        let swapper = std::thread::spawn(move || {
            for k in 0..10u32 {
                std::thread::sleep(std::time::Duration::from_millis(3));
                swap_service
                    .publish_model(serve_bench_model(400.0 + k as f64), 0.0);
            }
        });
        let replies =
            bc.predict_many(&load, window).map_err(|e| e.to_string())?;
        swapper.join().map_err(|_| "swapper panicked".to_string())?;
        let versions: Vec<u64> = replies
            .iter()
            .map(|r| r.as_ref().map(|p| p.version))
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        versions.windows(2).all(|w| w[0] <= w[1])
    };

    // Load shedding on a deliberately starved queue: one slow worker
    // (fault-injected 2 ms per job), queue depth 1.  Some requests must
    // come back as typed SHED, the rest must still be answered.
    let shed_opts = ServeOptions {
        workers: 1,
        queue_depth: 1,
        max_batch: 16,
        batch_delay: std::time::Duration::from_millis(2),
    };
    let shed_server = Server::start_tuned(
        "127.0.0.1:0",
        Arc::clone(&service),
        None,
        shed_opts,
    )
    .map_err(|e| e.to_string())?;
    let shed_addr = shed_server.addr.to_string();
    let shed_reqs: Vec<(String, u32, u32)> = (0..600)
        .map(|i| ("wordcount".to_string(), 5 + (i % 36) as u32, 5))
        .collect();
    let mut shed = 0usize;
    let mut served = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                s.spawn(|| {
                    let mut c =
                        PipelinedClient::connect(&shed_addr).unwrap();
                    let replies = c.predict_many(&shed_reqs, 256).unwrap();
                    replies.iter().fold(
                        (0usize, 0usize),
                        |(sh, ok), r| match r {
                            Err(ClientError::Shed) => (sh + 1, ok),
                            Ok(_) => (sh, ok + 1),
                            Err(_) => (sh, ok),
                        },
                    )
                })
            })
            .collect();
        for h in handles {
            let (sh, ok) = h.join().unwrap();
            shed += sh;
            served += ok;
        }
    });
    let shed_rate = shed as f64 / (2 * shed_reqs.len()) as f64;
    if served == 0 {
        return Err("bench serve: starved server answered nothing".into());
    }

    let json_pps = json_tp.throughput(total as f64);
    let bin_pps = bin_tp.throughput(total as f64);
    let ratio = bin_pps / json_pps;
    let doc = Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        ("schema", Json::Num(1.0)),
        ("records", Json::Num(total as f64)),
        ("clients", Json::Num(clients as f64)),
        ("window", Json::Num(window as f64)),
        (
            "cases",
            Json::Arr(vec![
                bench_case(&json_lat, 1.0),
                bench_case(&bin_lat, 1.0),
                bench_case(&json_tp, total as f64),
                bench_case(&bin_tp, total as f64),
            ]),
        ),
        ("p50_latency_s", Json::Num(bin_lat.p50_s)),
        ("p99_latency_s", Json::Num(bin_lat.p99_s)),
        ("json_predictions_per_s", Json::Num(json_pps)),
        ("binary_predictions_per_s", Json::Num(bin_pps)),
        ("binary_vs_json_throughput_ratio", Json::Num(ratio)),
        ("shed_rate", Json::Num(shed_rate)),
        ("bit_identical_json_binary", Json::Bool(bit_identical)),
        ("monotonic_versions_under_hot_swap", Json::Bool(monotonic)),
    ]);
    std::fs::write(&out, format!("{doc}\n")).map_err(|e| e.to_string())?;
    println!(
        "binary/json throughput ratio: {ratio:.2}x ({bin_pps:.0} vs \
         {json_pps:.0} predictions/s); shed rate {shed_rate:.3}; \
         bit-identical: {bit_identical}; monotonic under hot-swap: \
         {monotonic}"
    );
    println!("wrote {out}");
    Ok(())
}

/// Store-scaling benchmark: one record population laid out as a single
/// eager-index directory (the pre-shard format) and as a sharded store,
/// timed through cold open, affinity lookup, and legacy migration, plus
/// a real (small) campaign asserting cold → warm executor bit-identity
/// and zero re-simulation across both the file and memory backends.
/// Results land in `BENCH_store.json` (`--out`), the perf-trajectory
/// artifact CI validates.
fn bench_store(args: &Args) -> Result<(), String> {
    let records = args.u64_or("records", 100_000)? as usize;
    let out = args.str_or("out", "BENCH_store.json");
    args.reject_unknown()?;
    if records == 0 {
        return Err("--records must be >= 1".into());
    }
    let base = std::env::temp_dir()
        .join(format!("mrtuner_bench_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).map_err(|e| e.to_string())?;

    // Synthetic but realistically-shaped population: distinct keys spread
    // over the 4-parameter lattice, plausible outcome figures.
    let mut rng = Rng::new(0xBE4C_57F0_4E5E_ED00);
    let apps = AppId::all();
    let recs: Vec<(StoreKey, RepOutcome)> = (0..records)
        .map(|i| {
            let key = StoreKey {
                cluster: 0xC1A5_7E12_3456_789A,
                app: apps[i % apps.len()],
                num_mappers: 5 + (i % 36) as u32,
                num_reducers: 5 + ((i / 36) % 36) as u32,
                input_gb_bits: (1.0 + (i % 31) as f64 * 0.5).to_bits(),
                block_mb: [32u32, 64, 128, 256][(i / 7) % 4],
                rep: i as u32,
                base_seed: 42,
            };
            let time_s = 100.0 + rng.range_f64(0.0, 1000.0);
            (key, RepOutcome::full(time_s, time_s * rng.range_f64(0.5, 4.0)))
        })
        .collect();

    // The production shape is a capped store; the cap is generous enough
    // that nothing evicts, so every record survives to be read back.
    let cap = Some(256u64 << 20);

    // Baseline: the pre-shard layout — every record in ONE directory
    // behind ONE compacted index, loaded eagerly on open.
    let single_dir = base.join("single");
    {
        let backend = FileBackend::new(&single_dir, cap, true);
        for (k, o) in &recs {
            backend.put(*k, *o);
        }
        backend.flush()?;
        backend.compact()?;
    }

    // The same population through the sharded facade, compacted so every
    // shard is one index file.
    let shard_dir = base.join("sharded");
    let shard_count = {
        let store = ProfileStore::open_with_opts(
            &shard_dir,
            StoreOptions {
                cap_bytes: cap,
                background_compaction: false,
                ..StoreOptions::default()
            },
        )?;
        for (k, o) in &recs {
            store.put(*k, *o);
        }
        store.flush()?;
        store.compact_now()?;
        if store.len() != records {
            return Err(format!(
                "bench store: expected {records} records, found {}",
                store.len()
            ));
        }
        store.shard_count()
    };

    println!("bench store: {records} records per store");
    let mut cases: Vec<Json> = Vec::new();

    // Cold open per layout.  The single-index baseline parses the whole
    // index up front; the sharded open reads nothing but `shards.meta`
    // until a lookup lands on a shard.
    let single_open = bench("open single-index store, eager load", 1, 3, || {
        let backend = FileBackend::open_eager(&single_dir, cap).unwrap();
        std::hint::black_box(backend.len());
    });
    cases.push(bench_case(&single_open, records as f64));
    let sharded_open = bench("open sharded store, lazy shards", 1, 3, || {
        let store = ProfileStore::peek(&shard_dir).unwrap();
        std::hint::black_box(store.shard_count());
    });
    cases.push(bench_case(&sharded_open, records as f64));

    // Open plus one routed lookup: the affinity case — a session that
    // profiles one application parses that application's shard only.
    let probe = recs[0].0;
    let first_get = bench("open sharded + get() one app's shard", 1, 3, || {
        let store = ProfileStore::peek(&shard_dir).unwrap();
        std::hint::black_box(store.get(&probe));
    });
    cases.push(bench_case(&first_get, 1.0));

    // Resident lookup rate across all shards (bounds the executor's
    // store-hit cost).
    {
        let store = ProfileStore::peek(&shard_dir)?;
        let lookups = bench("get() every record, resident", 1, 3, || {
            for (k, _) in &recs {
                std::hint::black_box(store.get(k));
            }
        });
        cases.push(bench_case(&lookups, records as f64));
    }

    // One-shot: the migration the first sharded open performs on a
    // legacy single-directory store, then byte-identity of every record
    // across it.
    let legacy_dir = base.join("legacy");
    {
        let backend = FileBackend::new(&legacy_dir, cap, true);
        for (k, o) in &recs {
            backend.put(*k, *o);
        }
        backend.flush()?;
        backend.compact()?;
    }
    let migrate = bench("open: migrate legacy root into shards", 0, 1, || {
        let store = ProfileStore::open_with_opts(
            &legacy_dir,
            StoreOptions {
                cap_bytes: cap,
                background_compaction: false,
                ..StoreOptions::default()
            },
        )
        .unwrap();
        std::hint::black_box(store.len());
    });
    cases.push(bench_case(&migrate, records as f64));
    let migration_get_identical = {
        let migrated = ProfileStore::peek(&legacy_dir)?;
        recs.iter().all(|(k, o)| {
            migrated.get(k).is_some_and(|got| got.same_bits(o))
        })
    };

    // Cold → warm executor bit-identity on real simulations, across both
    // backends (the store's whole correctness claim in one check).
    let cluster = Cluster::paper_cluster();
    let specs = [
        ExperimentSpec::new(AppId::WordCount, 10, 5),
        ExperimentSpec::new(AppId::WordCount, 20, 5),
    ];
    let camp_dir = base.join("campaign");
    let cold = {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&camp_dir)?);
        exec.run_specs(&cluster, &specs, 2, 11)
    };
    let warm_file = {
        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&camp_dir)?);
        let res = exec.run_specs(&cluster, &specs, 2, 11);
        if exec.stats().simulated != 0 {
            return Err("bench store: file warm run re-simulated".into());
        }
        res
    };
    // Memory backend: preload the campaign's records into an ephemeral
    // store and warm-start from that — same records, no disk underneath.
    let warm_mem = {
        let (entries, _) = ProfileStore::peek(&camp_dir)?.read_since(0);
        let mem = ProfileStore::memory();
        for (k, o) in entries {
            mem.put(k, o);
        }
        let exec = CampaignExecutor::new(2).with_store(mem);
        let res = exec.run_specs(&cluster, &specs, 2, 11);
        if exec.stats().simulated != 0 {
            return Err("bench store: memory warm run re-simulated".into());
        }
        res
    };
    let bit_identical =
        cold.iter().zip(&warm_file).zip(&warm_mem).all(|((a, b), c)| {
            a.rep_times_s == b.rep_times_s && a.rep_times_s == c.rep_times_s
        });

    let speedup = single_open.mean_s / sharded_open.mean_s;
    let doc = Json::obj(vec![
        ("bench", Json::Str("store".into())),
        ("schema", Json::Num(1.0)),
        ("records", Json::Num(records as f64)),
        ("shards", Json::Num(shard_count as f64)),
        ("cases", Json::Arr(cases)),
        ("sharded_vs_single_open_speedup", Json::Num(speedup)),
        ("migration_get_identical", Json::Bool(migration_get_identical)),
        ("bit_identical_cold_warm", Json::Bool(bit_identical)),
    ]);
    std::fs::write(&out, format!("{doc}\n")).map_err(|e| e.to_string())?;
    println!(
        "sharded lazy open speedup over single eager index: {speedup:.1}x; \
         migration byte-identical: {migration_get_identical}; \
         cold/warm bit-identical: {bit_identical}"
    );
    println!("wrote {out}");
    let _ = std::fs::remove_dir_all(&base);
    Ok(())
}

/// Trainer-scaling benchmark: refit throughput when a trainer resumes
/// against a large warm store (ingest everything + first refit per
/// application) and the steady-state latency of an incremental poll
/// diffing one fresh repetition.  Results land in `BENCH_trainer.json`
/// (`--out`).
fn bench_trainer(args: &Args) -> Result<(), String> {
    let settings = args.u64_or("settings", 324)? as usize;
    let reps = args.u64_or("reps", 2)? as u32;
    let out = args.str_or("out", "BENCH_trainer.json");
    args.reject_unknown()?;
    if settings < NUM_FEATURES {
        return Err(format!(
            "--settings must be >= {NUM_FEATURES} (cubic basis unknowns)"
        ));
    }
    if settings > 36 * 36 {
        return Err("--settings must be <= 1296 (the 36x36 grid)".into());
    }
    if reps == 0 {
        return Err("--reps must be >= 1".into());
    }
    let dir = std::env::temp_dir()
        .join(format!("mrtuner_bench_trainer_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // A warm store shaped like a finished profiling campaign: paper-plane
    // records over the (M, R) grid for every application, with a smooth
    // synthetic time surface (the fit must be well-conditioned; it need
    // not be physically meaningful).
    let cluster = Cluster::paper_cluster();
    let fp = cluster_fingerprint(&cluster);
    let mut rng = Rng::new(0x7124_11E4_B05E_D511);
    let apps = AppId::all();
    let mut records = 0usize;
    {
        let store = ProfileStore::open_with_opts(
            &dir,
            StoreOptions {
                background_compaction: false,
                ..StoreOptions::default()
            },
        )?;
        for (ai, app) in apps.iter().enumerate() {
            for i in 0..settings {
                let m = 5 + (i % 36) as u32;
                let r = 5 + (i / 36) as u32;
                let surface = 200.0
                    + (ai as f64 + 1.0) * 3000.0 / m as f64
                    + 800.0 / r as f64
                    + 0.05 * (m * r) as f64;
                for rep in 0..reps {
                    let key = StoreKey {
                        cluster: fp,
                        app: *app,
                        num_mappers: m,
                        num_reducers: r,
                        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
                        block_mb: StoreKey::PAPER_BLOCK_MB,
                        rep,
                        base_seed: 42,
                    };
                    let jitter = rng.range_f64(-2.0, 2.0);
                    store.put(key, RepOutcome::time_only(surface + jitter));
                    records += 1;
                }
            }
        }
        store.flush()?;
        store.compact_now()?;
    }
    println!(
        "bench trainer: {records} records ({settings} settings x {} apps \
         x {reps} reps)",
        apps.len()
    );
    let mut cases: Vec<Json> = Vec::new();

    // Every application the store profiled must come back as a refit —
    // the determinism claim behind warm serve starts.
    let refits_cover_all_apps = {
        let mut trainer = Trainer::open(&dir, &cluster)?;
        let report = trainer.poll()?;
        report.refits.len() == apps.len()
            && report.new_records == records as u64
    };

    // Resume: a fresh trainer opens the warm store, ingests everything,
    // and refits every application — the cost a `serve --retrain-every`
    // start pays over an existing campaign.
    let resume = bench("trainer resume: ingest store + refit", 1, 3, || {
        let mut trainer = Trainer::open(&dir, &cluster).unwrap();
        let report = trainer.poll().unwrap();
        std::hint::black_box(report.new_records);
    });
    cases.push(bench_case(&resume, records as f64));

    // Incremental: a long-lived trainer diffs exactly one fresh rep per
    // poll — the steady-state retrain cadence.
    let writer = ProfileStore::open_with_opts(
        &dir,
        StoreOptions {
            background_compaction: false,
            ..StoreOptions::default()
        },
    )?;
    let mut trainer = Trainer::open(&dir, &cluster)?;
    trainer.poll()?;
    let mut next_rep = reps;
    let incremental =
        bench("trainer poll: one fresh rep, refit diff", 2, 10, || {
            let key = StoreKey {
                cluster: fp,
                app: AppId::WordCount,
                num_mappers: 5,
                num_reducers: 5,
                input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
                block_mb: StoreKey::PAPER_BLOCK_MB,
                rep: next_rep,
                base_seed: 42,
            };
            next_rep += 1;
            writer.put(key, RepOutcome::time_only(777.0 + next_rep as f64));
            writer.flush().unwrap();
            let report = trainer.poll().unwrap();
            std::hint::black_box(report.generation);
        });
    cases.push(bench_case(&incremental, 1.0));
    drop(trainer);
    drop(writer);

    let resume_rate = resume.throughput(records as f64);
    let doc = Json::obj(vec![
        ("bench", Json::Str("trainer".into())),
        ("schema", Json::Num(1.0)),
        ("records", Json::Num(records as f64)),
        ("settings", Json::Num(settings as f64)),
        ("cases", Json::Arr(cases)),
        ("resume_records_per_s", Json::Num(resume_rate)),
        ("incremental_poll_p50_s", Json::Num(incremental.p50_s)),
        ("refits_cover_all_apps", Json::Bool(refits_cover_all_apps)),
    ]);
    std::fs::write(&out, format!("{doc}\n")).map_err(|e| e.to_string())?;
    println!(
        "trainer resume: {resume_rate:.0} records/s; incremental poll \
         p50 {:.6}s; refits cover all apps: {refits_cover_all_apps}",
        incremental.p50_s
    );
    println!("wrote {out}");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// Executor-scaling benchmark on a deliberately skewed extended grid:
/// serial vs work-stealing parallel dispatch, asserting bit-identity, to
/// `BENCH_campaign.json` (`--out`).
fn bench_campaign(args: &Args) -> Result<(), String> {
    let reps = args.u64_or("reps", 1)? as u32;
    let out = args.str_or("out", "BENCH_campaign.json");
    // Same defaulting as executor_from: one worker per core.
    let jobs = match args.str_opt("jobs") {
        None => CampaignExecutor::machine_sized().jobs(),
        Some(s) => {
            s.parse().map_err(|_| format!("--jobs: bad integer '{s}'"))?
        }
    };
    args.reject_unknown()?;
    if reps == 0 {
        return Err("--reps must be >= 1".into());
    }
    let cluster = Cluster::paper_cluster();
    // Every sixth setting is a 256-map monster, the rest are 4-map
    // quickies — the shape that starves equal-share splits and shows
    // what chunk stealing buys.
    let specs: Vec<Ext4Spec> = (0..12u32)
        .map(|i| {
            let heavy = i % 6 == 0;
            Ext4Spec {
                app: AppId::WordCount,
                num_mappers: 5 + i,
                num_reducers: 5 + (i % 3) * 10,
                input_gb: if heavy { 8.0 } else { 1.0 },
                block_mb: if heavy { 32 } else { 256 },
            }
        })
        .collect();
    let units = (specs.len() as u32 * reps) as f64;
    println!(
        "bench campaign: {} settings x {reps} rep(s), {jobs} workers",
        specs.len()
    );
    let serial = bench("skewed ext4 grid, serial", 0, 2, || {
        let exec = CampaignExecutor::serial();
        std::hint::black_box(exec.run_ext4_specs(&cluster, &specs, reps, 7));
    });
    let stolen = bench(
        &format!("skewed ext4 grid, jobs={jobs} (work stealing)"),
        0,
        2,
        || {
            let exec = CampaignExecutor::new(jobs);
            std::hint::black_box(
                exec.run_ext4_specs(&cluster, &specs, reps, 7),
            );
        },
    );
    let a = CampaignExecutor::serial().run_ext4_specs(&cluster, &specs, reps, 7);
    let b = CampaignExecutor::new(jobs).run_ext4_specs(&cluster, &specs, reps, 7);
    let bit_identical = a.iter().zip(&b).all(|(x, y)| {
        x.mean_time_s.to_bits() == y.mean_time_s.to_bits()
            && x.mean_cpu_s.to_bits() == y.mean_cpu_s.to_bits()
    });
    // Checkpoint/resume contract: after a store-backed cold run, a fresh
    // executor on the same store must re-simulate *nothing* and still
    // reproduce the cold output bit for bit — what `--resume` relies on.
    let resume_dir = std::env::temp_dir()
        .join(format!("mrtuner_bench_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&resume_dir);
    let cold = {
        let exec = CampaignExecutor::new(jobs)
            .with_store(ProfileStore::open(&resume_dir)?);
        exec.run_ext4_specs(&cluster, &specs, reps, 7)
    };
    let resume_zero_resim = {
        let exec = CampaignExecutor::new(jobs)
            .with_store(ProfileStore::open(&resume_dir)?);
        let warm = exec.run_ext4_specs(&cluster, &specs, reps, 7);
        exec.stats().simulated == 0
            && cold.iter().zip(&warm).all(|(x, y)| {
                x.mean_time_s.to_bits() == y.mean_time_s.to_bits()
                    && x.mean_cpu_s.to_bits() == y.mean_cpu_s.to_bits()
            })
    };
    let _ = std::fs::remove_dir_all(&resume_dir);
    // Dead-letter retry latency: the whole skewed grid quarantined, then
    // re-run through the same take → rebuild → store-backed-executor →
    // flush path `mrtuner dlq retry` uses.  The warmup pass simulates the
    // reps into the store, so the measured iterations isolate the DLQ
    // machinery (decode, rebuild, warm dispatch, re-append bookkeeping).
    let fp = cluster_fingerprint(&cluster);
    let dlq_store_dir = std::env::temp_dir()
        .join(format!("mrtuner_bench_dlq_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dlq_store_dir);
    let poisoned: Vec<DlqRecord> = specs
        .iter()
        .flat_map(|s| {
            let s = *s;
            (0..reps).map(move |rep| DlqRecord {
                key: StoreKey {
                    cluster: fp,
                    app: s.app,
                    num_mappers: s.num_mappers,
                    num_reducers: s.num_reducers,
                    input_gb_bits: s.input_gb.to_bits(),
                    block_mb: s.block_mb,
                    rep,
                    base_seed: 7,
                },
                attempts: 3,
                error: "bench: synthetic quarantine".into(),
            })
        })
        .collect();
    let dlq_dir = dlq::dlq_dir(&dlq_store_dir);
    let dlq_exec = CampaignExecutor::new(jobs)
        .with_store(ProfileStore::open(&dlq_store_dir)?);
    let dlq_retry = bench("dlq retry: re-run poisoned grid", 1, 3, || {
        dlq::append(&dlq_dir, &poisoned).unwrap();
        let records = dlq::take(&dlq_dir).unwrap();
        let retry_jobs: Vec<RepJob> = records
            .iter()
            .map(|r| {
                RepJob::ext4(
                    Ext4Spec {
                        app: r.key.app,
                        num_mappers: r.key.num_mappers,
                        num_reducers: r.key.num_reducers,
                        input_gb: r.key.input_gb(),
                        block_mb: r.key.block_mb,
                    },
                    r.key.rep,
                    r.key.base_seed,
                )
            })
            .collect();
        let outcomes = dlq_exec.run_outcomes(&cluster, &retry_jobs);
        dlq_exec.flush_store().unwrap();
        std::hint::black_box(outcomes.len());
    });
    drop(dlq_exec);
    let _ = std::fs::remove_dir_all(&dlq_store_dir);
    // `--resume` diff cost at campaign scale: campaign_resume_status over
    // the full 36×36 paper lattice × 8 reps (10368 rep jobs, half of them
    // already on disk) — the preflight a `profile --resume` pays before
    // dispatching anything.
    let diff_dir = std::env::temp_dir()
        .join(format!("mrtuner_bench_resume_diff_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&diff_dir);
    let diff_campaign = Campaign {
        app: AppId::WordCount,
        specs: grid_specs(AppId::WordCount, 1),
        reps: 8,
        base_seed: 42,
    };
    let diff_units =
        (diff_campaign.specs.len() as u32 * diff_campaign.reps) as f64;
    {
        let store = ProfileStore::open(&diff_dir)?;
        let mut i = 0usize;
        for spec in &diff_campaign.specs {
            for rep in 0..diff_campaign.reps {
                // Every other rep is already "done" so the diff exercises
                // both the hit and the miss path.
                if i % 2 == 0 {
                    let key = StoreKey {
                        cluster: fp,
                        app: diff_campaign.app,
                        num_mappers: spec.num_mappers,
                        num_reducers: spec.num_reducers,
                        input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
                        block_mb: StoreKey::PAPER_BLOCK_MB,
                        rep,
                        base_seed: diff_campaign.base_seed,
                    };
                    store.put(key, RepOutcome::time_only(100.0 + i as f64));
                }
                i += 1;
            }
        }
        store.flush()?;
    }
    let diff_exec =
        CampaignExecutor::new(jobs).with_store(ProfileStore::open(&diff_dir)?);
    let resume_diff =
        bench("resume diff: status over 10368-rep grid", 1, 5, || {
            let status =
                diff_exec.campaign_resume_status(&cluster, &diff_campaign).unwrap();
            assert_eq!(status.total as f64, diff_units);
            std::hint::black_box(status.missing);
        });
    drop(diff_exec);
    let _ = std::fs::remove_dir_all(&diff_dir);
    let speedup = serial.mean_s / stolen.mean_s;
    let doc = Json::obj(vec![
        ("bench", Json::Str("campaign".into())),
        ("schema", Json::Num(1.0)),
        ("records", Json::Num(units)),
        ("jobs", Json::Num(jobs as f64)),
        (
            "cases",
            Json::Arr(vec![
                bench_case(&serial, units),
                bench_case(&stolen, units),
                bench_case(&dlq_retry, poisoned.len() as f64),
                bench_case(&resume_diff, diff_units),
            ]),
        ),
        ("parallel_speedup", Json::Num(speedup)),
        ("bit_identical_serial_parallel", Json::Bool(bit_identical)),
        ("resume_zero_resim", Json::Bool(resume_zero_resim)),
    ]);
    std::fs::write(&out, format!("{doc}\n")).map_err(|e| e.to_string())?;
    println!(
        "parallel speedup: {speedup:.2}x; bit-identical: {bit_identical}; \
         resume zero-resim: {resume_zero_resim}"
    );
    println!("wrote {out}");
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<(), String> {
    let seed = args.u64_or("seed", 42)?;
    let executor = executor_from(args)?;
    args.reject_unknown()?;
    e2e::run_with(seed, &executor).map(|_| ())
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let addr = args.str_or("addr", "127.0.0.1:7070");
    let seed = args.u64_or("seed", 42)?;
    let retrain_every = args.u64_or("retrain-every", 0)?;
    // Serving-path knobs (binary protocol batching + admission control);
    // defaults mirror ServeOptions::default().
    let serve_workers = args.u64_or("serve-workers", 1)? as usize;
    let serve_queue = args.u64_or("serve-queue", 1024)? as usize;
    let store_dir = store_path_from(args);
    let executor = executor_from(args)?;
    args.reject_unknown()?;
    if serve_workers == 0 || serve_queue == 0 {
        return Err("--serve-workers and --serve-queue must be >= 1".into());
    }
    if retrain_every > 0 && store_dir.is_none() {
        return Err(
            "--retrain-every requires a profile store (--store PATH or \
             MRTUNER_STORE)"
                .into(),
        );
    }
    // Profile all apps up front (on the simulated cluster, fanned out
    // over the campaign executor).  Without a store the models are fit
    // and installed here; with one, the reps land in the store and the
    // trainer's initial sync below does the (one and only) startup fit
    // per app — fitting here too would publish every model twice.
    let cluster = Cluster::paper_cluster();
    let mut registry = ModelRegistry::new();
    {
        let (mut backend, name) = experiments::default_backend();
        for app in AppId::all() {
            let (train, _) = paper_campaign(app, seed);
            let (_, ds) = train.run_with(&cluster, &executor);
            if store_dir.is_some() {
                eprintln!("profiled {} ({} rows)", app.name(), ds.len());
            } else {
                let model =
                    RegressionModel::fit_dataset(backend.as_mut(), &ds)?;
                eprintln!(
                    "fitted {} ({} rows) via {name}",
                    app.name(),
                    ds.len()
                );
                registry.insert(model);
            }
        }
    }
    report_executor(&executor);
    let service = Arc::new(PredictionService::start(
        || experiments::default_backend().0,
        registry,
        ServiceConfig::default(),
    ));
    // With a store configured, wire the online trainer: `retrain` over
    // the protocol (and the periodic thread below) tails the store and
    // hot-swaps refit models — newly profiled apps become predictable
    // without restarting the server.
    let trainer = match &store_dir {
        Some(dir) => {
            let mut t = Trainer::open(Path::new(dir), &cluster)?;
            // Sync to everything already profiled (including the startup
            // campaigns above, flushed through the executor's store).
            let summary = t.retrain(&service).map_err(|e| {
                format!("initial retrain from {dir} failed: {e}")
            })?;
            eprintln!(
                "trainer: synced {} store record(s); {} model(s) published",
                summary.new_records,
                summary.published.len()
            );
            Some(Arc::new(Mutex::new(t)))
        }
        None => None,
    };
    if retrain_every > 0 {
        let trainer = Arc::clone(trainer.as_ref().expect("checked above"));
        let service = Arc::clone(&service);
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(retrain_every));
            let mut t = match trainer.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            match t.retrain(&service) {
                Ok(summary) => {
                    for (name, version) in &summary.published {
                        eprintln!("trainer: hot-swapped {name} -> v{version}");
                    }
                }
                Err(e) => eprintln!("trainer: periodic retrain failed: {e}"),
            }
        });
    }
    let opts = ServeOptions {
        workers: serve_workers,
        queue_depth: serve_queue,
        ..ServeOptions::default()
    };
    let server = Server::start_tuned(&addr, service, trainer, opts)
        .map_err(|e| e.to_string())?;
    println!("prediction service listening on {}", server.addr);
    println!("protocols (autodetected per connection):");
    println!("  JSON lines — one object per line, e.g.");
    println!("  {{\"op\":\"predict\",\"app\":\"wordcount\",\"mappers\":20,\"reducers\":5}}");
    println!("  ops: predict | models | model_info | retrain | health");
    println!(
        "  binary v2 — pipelined length-prefixed frames \
         (docs/OPERATIONS.md, \"Serving at scale\")"
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
