//! # mrtuner
//!
//! Reproduction of *"On Modeling Dependency between MapReduce Configuration
//! Parameters and Total Execution Time"* (Rizvandi, Zomaya, Javadzadeh
//! Boloori, Taheri — 2012) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper's pipeline — **profile** a MapReduce application across
//! `(num_mappers, num_reducers)` settings, **model** total execution time
//! with a per-parameter-cubic multivariate linear regression, **predict**
//! unseen settings — is built on a full simulated substrate:
//!
//! * [`sim`] / [`cluster`] / [`dfs`] / [`mr`] — a discrete-event Hadoop-0.20
//!   model of the paper's 4-node heterogeneous testbed;
//! * [`api`] / [`apps`] / [`datagen`] — real WordCount / Exim-mainlog-parse
//!   applications executed functionally over generated corpora;
//! * [`profiler`] — the paper's Fig-2a protocol (5 runs per setting, mean);
//! * [`model`] — feature expansion + pure-Rust least squares (baseline);
//! * [`runtime`] — PJRT execution of the JAX+Pallas AOT fit/predict
//!   artifacts (the production path: Python never runs at request time);
//! * [`coordinator`] — a prediction service with dynamic request batching,
//!   an online trainer that tails the profile store and hot-swaps
//!   versioned model refits, and a predicted-time-aware job scheduler;
//! * [`report`] — regeneration of every figure/table in the paper's
//!   evaluation (Fig. 3, Fig. 4, Table 1).
//!
//! Prose documentation lives in `docs/ARCHITECTURE.md` (layer walkthrough,
//! campaign/store data flow) and `docs/PAPER_MAPPING.md` (paper artifact →
//! module/test index).

#![warn(missing_docs)]

pub mod analysis;
pub mod api;
pub mod apps;
pub mod cluster;
pub mod coordinator;
pub mod datagen;
pub mod dfs;
pub mod model;
pub mod mr;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
