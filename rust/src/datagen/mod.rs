//! Synthetic workload data generators.
//!
//! Stand-ins for the paper's 8 GB inputs (§V.A) and the extension
//! benchmarks: a Zipf-distributed text corpus for WordCount/Grep, a
//! realistic Exim mainlog for the parsing benchmark, fixed-width
//! `key\tpayload` records for the terasort-like sort, and Zipf-skewed
//! tagged two-relation lines for the repartition join.  All are
//! deterministic given an RNG stream, and all are *actually processed*
//! by the functional engine in tests and examples.

pub mod corpus;
pub mod exim_log;
pub mod join_log;
pub mod sort_records;
