//! Synthetic workload data generators.
//!
//! Stand-ins for the paper's 8 GB inputs (§V.A): a Zipf-distributed text
//! corpus for WordCount/Grep and a realistic Exim mainlog for the parsing
//! benchmark.  Both are deterministic given an RNG stream, and both are
//! *actually processed* by the functional engine in tests and examples.

pub mod corpus;
pub mod exim_log;
