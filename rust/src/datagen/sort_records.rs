//! Fixed-width record generator for the terasort-like sort benchmark.
//!
//! Mirrors teragen's shape at line granularity: every record is a
//! `key\tpayload` line with a 10-character random key and a fixed-width
//! filler payload, so record count scales linearly with target size and
//! the sort's shuffle volume tracks input volume byte-for-byte.

use crate::util::rng::Rng;

/// Key width in characters (teragen uses 10-byte keys).
const KEY_LEN: usize = 10;
/// Payload width in characters.
const PAYLOAD_LEN: usize = 32;

fn key(rng: &mut Rng) -> String {
    // Uppercase letters only: keys collate identically as bytes and as
    // UTF-8 strings, so the functional sort order is unambiguous.
    (0..KEY_LEN)
        .map(|_| (b'A' + rng.range_u64(0, 26) as u8) as char)
        .collect()
}

fn payload(rng: &mut Rng, seq: u64) -> String {
    // A sequence number followed by repeated filler, padded to width —
    // mirrors teragen's "rowid + filler" payload layout.
    let filler = (b'a' + rng.range_u64(0, 26) as u8) as char;
    let head = format!("{seq:010}-");
    let fill = PAYLOAD_LEN - head.len();
    let mut p = head;
    for _ in 0..fill {
        p.push(filler);
    }
    p
}

/// Generate roughly `target_bytes` of `key\tpayload` records.
pub fn generate(rng: &mut Rng, target_bytes: usize) -> String {
    let mut out = String::with_capacity(target_bytes + 64);
    let mut seq = 0u64;
    while out.len() < target_bytes {
        out.push_str(&key(rng));
        out.push('\t');
        out.push_str(&payload(rng, seq));
        out.push('\n');
        seq += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(&mut Rng::new(7), 4_000);
        let b = generate(&mut Rng::new(7), 4_000);
        assert_eq!(a, b);
    }

    #[test]
    fn records_are_fixed_width() {
        let data = generate(&mut Rng::new(1), 10_000);
        for line in data.lines() {
            let (k, p) = line.split_once('\t').expect("tab-separated");
            assert_eq!(k.len(), KEY_LEN, "bad key {k:?}");
            assert_eq!(p.len(), PAYLOAD_LEN, "bad payload {p:?}");
            assert!(k.bytes().all(|b| b.is_ascii_uppercase()));
        }
    }

    #[test]
    fn payload_sequence_numbers_are_unique() {
        let data = generate(&mut Rng::new(2), 8_000);
        let mut seqs: Vec<&str> = data
            .lines()
            .map(|l| &l[KEY_LEN + 1..KEY_LEN + 11])
            .collect();
        let n = seqs.len();
        seqs.sort();
        seqs.dedup();
        assert_eq!(seqs.len(), n, "duplicate sequence numbers");
    }

    #[test]
    fn size_tracks_target() {
        for target in [1_000, 20_000] {
            let data = generate(&mut Rng::new(3), target);
            let record = KEY_LEN + 1 + PAYLOAD_LEN + 1;
            assert!(data.len() >= target);
            assert!(data.len() < target + record);
        }
    }
}
