//! Two-relation tagged-record generator for the repartition-join
//! benchmark.
//!
//! Emits interleaved `L\tkey\tpayload` and `R\tkey\tpayload` lines over
//! a shared Zipf-skewed key space, so a handful of hot keys carry many
//! records on both sides and their per-key cross products dominate the
//! reduce stage — the skew the join app exists to model.

use crate::util::rng::{Rng, Zipf};

/// Shared key-space size; small enough that hot keys repeat on both
/// sides even in modest inputs.
const KEY_SPACE: u64 = 500;
/// Zipf exponent for key popularity (hot head, long tail).
const SKEW: f64 = 1.2;
/// Fraction of lines belonging to the left relation.
const LEFT_SHARE: f64 = 0.5;

fn payload(rng: &mut Rng, side: &str, seq: u64) -> String {
    format!("{side}{seq:08}-{:04x}", rng.next_u64() & 0xFFFF)
}

/// Generate roughly `target_bytes` of interleaved tagged join input.
pub fn generate(rng: &mut Rng, target_bytes: usize) -> String {
    let zipf = Zipf::new(KEY_SPACE, SKEW);
    let mut out = String::with_capacity(target_bytes + 64);
    let mut seq = 0u64;
    while out.len() < target_bytes {
        let key = zipf.sample(rng);
        let (tag, side) = if rng.bool(LEFT_SHARE) { ("L", "l") } else { ("R", "r") };
        out.push_str(&format!(
            "{tag}\tk{key:04}\t{}\n",
            payload(rng, side, seq)
        ));
        seq += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn deterministic() {
        let a = generate(&mut Rng::new(11), 6_000);
        let b = generate(&mut Rng::new(11), 6_000);
        assert_eq!(a, b);
    }

    #[test]
    fn every_line_is_well_tagged() {
        let data = generate(&mut Rng::new(1), 12_000);
        for line in data.lines() {
            let mut cols = line.split('\t');
            let tag = cols.next().unwrap();
            assert!(tag == "L" || tag == "R", "bad tag in {line:?}");
            let key = cols.next().expect("key column");
            assert!(key.starts_with('k') && key.len() == 5, "bad key {key:?}");
            assert!(!cols.next().expect("payload column").is_empty());
        }
    }

    #[test]
    fn both_relations_are_represented() {
        let data = generate(&mut Rng::new(2), 12_000);
        let left = data.lines().filter(|l| l.starts_with("L\t")).count();
        let right = data.lines().filter(|l| l.starts_with("R\t")).count();
        let total = left + right;
        assert!(left as f64 > 0.3 * total as f64);
        assert!(right as f64 > 0.3 * total as f64);
    }

    #[test]
    fn key_distribution_is_skewed() {
        let data = generate(&mut Rng::new(3), 40_000);
        let mut counts: BTreeMap<&str, u64> = BTreeMap::new();
        for line in data.lines() {
            let key = line.split('\t').nth(1).unwrap();
            *counts.entry(key).or_default() += 1;
        }
        let total: u64 = counts.values().sum();
        let max = *counts.values().max().unwrap();
        // The hottest key carries far more than a uniform share.
        assert!(max as f64 > 10.0 * total as f64 / KEY_SPACE as f64);
        // The hot key appears on both sides (so it actually joins).
        let hot = counts.iter().max_by_key(|(_, c)| **c).unwrap().0;
        assert!(data.lines().any(|l| l.starts_with(&format!("L\t{hot}\t"))));
        assert!(data.lines().any(|l| l.starts_with(&format!("R\t{hot}\t"))));
    }
}
