//! Zipf-distributed text corpus generator.
//!
//! Natural-language word frequencies follow Zipf's law, which is what
//! gives WordCount its characteristic combiner efficiency (a few words
//! dominate every split).  The vocabulary mixes a hand-picked head of
//! common English words with a synthetic tail (`wN` tokens), so generated
//! text is both humanly plausible and unbounded in vocabulary size.

use crate::util::rng::{Rng, Zipf};

/// Head of the vocabulary: most frequent English words.
const HEAD: &[&str] = &[
    "the", "of", "and", "a", "to", "in", "is", "you", "that", "it", "he",
    "was", "for", "on", "are", "as", "with", "his", "they", "i", "at",
    "be", "this", "have", "from", "or", "one", "had", "by", "word", "but",
    "not", "what", "all", "were", "we", "when", "your", "can", "said",
    "there", "use", "an", "each", "which", "she", "do", "how", "their",
    "if", "will", "up", "other", "about", "out", "many", "then", "them",
    "these", "so", "some", "her", "would", "make", "like", "him", "into",
    "time", "has", "look", "two", "more", "write", "go", "see", "number",
    "no", "way", "could", "people", "my", "than", "first", "water",
    "been", "call", "who", "oil", "its", "now", "find", "long", "down",
    "day", "did", "get", "come", "made", "may", "part",
];

/// Vocabulary size (head + synthetic tail ranks).
pub const VOCAB: u64 = 50_000;

/// Zipf exponent for English-like text.
pub const ZIPF_S: f64 = 1.07;

/// Word for a 1-based Zipf rank.
pub fn word_for_rank(rank: u64) -> String {
    debug_assert!(rank >= 1);
    if (rank as usize) <= HEAD.len() {
        HEAD[rank as usize - 1].to_string()
    } else {
        format!("w{rank}")
    }
}

/// Generate roughly `target_bytes` of text: lines of 6..14 words.
pub fn generate(rng: &mut Rng, target_bytes: usize) -> String {
    let zipf = Zipf::new(VOCAB, ZIPF_S);
    let mut out = String::with_capacity(target_bytes + 64);
    while out.len() < target_bytes {
        let words = rng.range_usize(6, 15);
        for i in 0..words {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(&word_for_rank(zipf.sample(rng)));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn deterministic() {
        let a = generate(&mut Rng::new(1), 10_000);
        let b = generate(&mut Rng::new(1), 10_000);
        assert_eq!(a, b);
        let c = generate(&mut Rng::new(2), 10_000);
        assert_ne!(a, c);
    }

    #[test]
    fn size_close_to_target() {
        let text = generate(&mut Rng::new(3), 100_000);
        assert!(text.len() >= 100_000);
        assert!(text.len() < 100_000 + 200, "overshoot bounded by one line");
    }

    #[test]
    fn lines_have_expected_word_counts() {
        let text = generate(&mut Rng::new(4), 20_000);
        for line in text.lines() {
            let n = line.split_whitespace().count();
            assert!((6..15).contains(&n), "line with {n} words");
        }
    }

    #[test]
    fn frequency_is_zipfian() {
        let text = generate(&mut Rng::new(5), 400_000);
        let mut freq: HashMap<&str, u64> = HashMap::new();
        for w in text.split_whitespace() {
            *freq.entry(w).or_insert(0) += 1;
        }
        // "the" (rank 1) must dominate, and the head must outweigh the tail.
        let the = freq.get("the").copied().unwrap_or(0);
        let of = freq.get("of").copied().unwrap_or(0);
        assert!(the > of, "rank 1 above rank 2");
        let total: u64 = freq.values().sum();
        let head: u64 = HEAD.iter().filter_map(|w| freq.get(w)).sum();
        assert!(
            head as f64 > 0.5 * total as f64,
            "Zipf head {head}/{total} too light"
        );
        // Vocabulary is genuinely large (tail words appear).
        assert!(freq.len() > 1000, "vocab {}", freq.len());
    }
}
