//! Exim mainlog generator.
//!
//! Emits the arrival (`<=`), delivery (`=>`), and `Completed` lines of
//! interleaved mail transactions in Exim's mainlog format, as produced by
//! a busy 2011 mail server — the workload of the paper's second benchmark.
//! Transactions interleave (messages complete out of order), so the
//! grouping work done by the MapReduce job is non-trivial.

use crate::util::rng::Rng;

const DOMAINS: &[&str] = &[
    "example.org", "example.net", "mail.example.com", "uni.sydney.edu.au",
    "nicta.com.au", "gmail.example", "corp.example",
];

const USERS: &[&str] = &[
    "alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi",
    "ivan", "judy", "mallory", "oscar", "peggy", "trent", "victor",
];

fn base62(rng: &mut Rng, n: usize) -> String {
    const ALPHA: &[u8] = b"0123456789ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz";
    (0..n).map(|_| ALPHA[rng.range_usize(0, 62)] as char).collect()
}

/// A synthetic Exim message id: `xxxxxx-yyyyyy-zz`.
pub fn message_id(rng: &mut Rng) -> String {
    format!("1{}-{}-{}", base62(rng, 5), base62(rng, 6), base62(rng, 2))
}

fn addr(rng: &mut Rng) -> String {
    format!("{}@{}", rng.choice(USERS), rng.choice(DOMAINS))
}

fn timestamp(secs: u64) -> String {
    // Fixed virtual day starting 2011-07-04 00:00:00 (paper era).
    let h = (secs / 3600) % 24;
    let m = (secs / 60) % 60;
    let s = secs % 60;
    format!("2011-07-04 {h:02}:{m:02}:{s:02}")
}

/// Generate roughly `target_bytes` of mainlog.  Transactions overlap in
/// time; ~3% of lines are non-transaction daemon chatter.
pub fn generate(rng: &mut Rng, target_bytes: usize) -> String {
    let mut out = String::with_capacity(target_bytes + 256);
    let mut clock: u64 = 8 * 3600; // busy period starts 08:00
    while out.len() < target_bytes {
        clock += rng.range_u64(0, 3);
        if rng.bool(0.03) {
            out.push_str(&format!(
                "{} exim 4.69 daemon: queue run started\n",
                timestamp(clock)
            ));
            continue;
        }
        let id = message_id(rng);
        let size = rng.range_u64(600, 40_000);
        out.push_str(&format!(
            "{} {} <= {} H=mx.{} [10.0.{}.{}] S={}\n",
            timestamp(clock),
            id,
            addr(rng),
            rng.choice(DOMAINS),
            rng.range_u64(0, 256),
            rng.range_u64(1, 255),
            size,
        ));
        // 1..=3 deliveries, a second or two apart.
        for _ in 0..rng.range_u64(1, 4) {
            clock += rng.range_u64(0, 2);
            out.push_str(&format!(
                "{} {} => {} R=dnslookup T=remote_smtp\n",
                timestamp(clock),
                id,
                addr(rng),
            ));
        }
        clock += rng.range_u64(0, 2);
        out.push_str(&format!("{} {} Completed\n", timestamp(clock), id));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::exim;

    #[test]
    fn deterministic() {
        let a = generate(&mut Rng::new(1), 5_000);
        let b = generate(&mut Rng::new(1), 5_000);
        assert_eq!(a, b);
    }

    #[test]
    fn lines_parse_with_the_benchmark_parser() {
        let log = generate(&mut Rng::new(2), 50_000);
        let mut with_id = 0;
        let mut without = 0;
        for line in log.lines() {
            if exim::message_id(line).is_some() {
                with_id += 1;
            } else {
                without += 1;
            }
        }
        assert!(with_id > 0);
        // Daemon chatter exists but is rare.
        assert!(without > 0);
        assert!((without as f64) < 0.08 * (with_id + without) as f64);
    }

    #[test]
    fn transactions_are_complete() {
        let log = generate(&mut Rng::new(3), 80_000);
        use std::collections::HashMap;
        let mut arrivals: HashMap<String, (u32, u32, u32)> = HashMap::new();
        for line in log.lines() {
            if let Some(id) = exim::message_id(line) {
                let e = arrivals.entry(id.to_string()).or_default();
                if line.contains(" <= ") {
                    e.0 += 1;
                } else if line.contains(" => ") {
                    e.1 += 1;
                } else if line.ends_with("Completed") {
                    e.2 += 1;
                }
            }
        }
        // All but possibly the final (truncated) transaction are complete.
        let complete = arrivals
            .values()
            .filter(|(a, d, c)| *a == 1 && *d >= 1 && *c == 1)
            .count();
        assert!(complete as f64 > 0.98 * arrivals.len() as f64);
    }

    #[test]
    fn timestamps_format() {
        assert_eq!(timestamp(8 * 3600 + 62), "2011-07-04 08:01:02");
        let log = generate(&mut Rng::new(4), 2_000);
        for line in log.lines() {
            assert!(line.starts_with("2011-07-04 "), "bad line {line}");
        }
    }
}
