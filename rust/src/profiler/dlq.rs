//! Dead-letter queue for quarantined repetitions.
//!
//! When a rep panics past the executor's retry policy it must not abort
//! the campaign — and it must not silently vanish either.  The executor
//! quarantines it here: a versioned binary record carrying the full
//! [`StoreKey`], the attempt count, and the (truncated) panic message,
//! so `mrtuner dlq list|retry|clear` can inspect and drain the queue
//! later.
//!
//! # On-disk layout
//!
//! The queue lives in a `dlq/` subdirectory of the profile store (the
//! store's [`super::store::ProfileStore::refresh`] fingerprinting only
//! matches store-named files in the top directory, so the queue never
//! perturbs store change detection):
//!
//! ```text
//! store/
//!   dlq/
//!     dlq-<pid>-<n>-<t>.bin   one append per quarantine event
//! ```
//!
//! Each file is an 8-byte header (magic `MRDQ` + little-endian version)
//! followed by length-prefixed records — the same framing discipline as
//! the store's binary v3 codec, with the same tolerance rules on read: a
//! garbled payload of plausible length is skipped record-by-record, a
//! torn length prefix ends the file.  Every writer creates its **own**
//! uniquely-named file (pid + nonce + nanos, exactly like store
//! segments), so concurrent cooperative drainers never interleave
//! writes and `append` needs no locking.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH}; // mrlint: allow(determinism) — wall clock names DLQ files only, never simulation state

use crate::apps::AppId;
use crate::util::bytes::hex_u64;

use super::store::StoreKey;

/// Magic prefix of every DLQ file.
const DLQ_MAGIC: [u8; 4] = *b"MRDQ";
/// DLQ file header: magic + little-endian u32 format version.
const DLQ_HEADER_LEN: usize = 8;
/// DLQ record format version; bump when the record schema changes.
pub const DLQ_FORMAT_VERSION: u32 = 1;
/// Sanity bound on a record's length prefix; anything larger is framing
/// corruption (a real record is well under 1 KiB).
const MAX_DLQ_RECORD_LEN: usize = 2048;
/// Panic messages are truncated to this many bytes on encode — the DLQ
/// stores enough to diagnose, not arbitrary payloads.
const MAX_ERROR_LEN: usize = 512;

/// File-name uniqueness within one process (mirrors the store's segment
/// counter).
static DLQ_COUNTER: AtomicU64 = AtomicU64::new(0);

const DLQ_PREFIX: &str = "dlq-";
const DLQ_SUFFIX: &str = ".bin";

/// One quarantined repetition: its persistent identity, how many times
/// the executor tried it, and the last failure message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DlqRecord {
    /// Persistent identity of the failed rep (same key space as the
    /// profile store, so a retried rep lands exactly where the campaign
    /// expected it).
    pub key: StoreKey,
    /// Simulation attempts made before quarantining.
    pub attempts: u32,
    /// Last panic message, truncated to 512 bytes at encode time.
    pub error: String,
}

/// The 8-byte header every DLQ file starts with.
fn dlq_header() -> [u8; DLQ_HEADER_LEN] {
    let mut h = [0u8; DLQ_HEADER_LEN];
    h[..4].copy_from_slice(&DLQ_MAGIC);
    h[4..].copy_from_slice(&DLQ_FORMAT_VERSION.to_le_bytes());
    h
}

/// Exact encoded payload size of one record (no length prefix).
fn payload_len(rec: &DlqRecord, err_len: usize) -> usize {
    // 3 u64s + 5 u32s + app length byte + app name + error length (u16)
    // + error bytes
    3 * 8 + 5 * 4 + 1 + rec.key.app.name().len() + 2 + err_len
}

/// Serialize one record as a length-prefixed binary frame, the error
/// message truncated to [`MAX_ERROR_LEN`] bytes (on a char boundary).
pub fn encode_dlq_record(rec: &DlqRecord) -> Vec<u8> {
    let mut err_len = rec.error.len().min(MAX_ERROR_LEN);
    while !rec.error.is_char_boundary(err_len) {
        err_len -= 1;
    }
    let len = payload_len(rec, err_len);
    debug_assert!(len <= MAX_DLQ_RECORD_LEN);
    let mut out = Vec::with_capacity(4 + len);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    let start = out.len();
    out.extend_from_slice(&rec.key.cluster.to_le_bytes());
    out.extend_from_slice(&rec.key.base_seed.to_le_bytes());
    out.extend_from_slice(&rec.key.input_gb_bits.to_le_bytes());
    out.extend_from_slice(&rec.key.num_mappers.to_le_bytes());
    out.extend_from_slice(&rec.key.num_reducers.to_le_bytes());
    out.extend_from_slice(&rec.key.block_mb.to_le_bytes());
    out.extend_from_slice(&rec.key.rep.to_le_bytes());
    out.extend_from_slice(&rec.attempts.to_le_bytes());
    let name = rec.key.app.name().as_bytes();
    out.push(name.len() as u8);
    out.extend_from_slice(name);
    out.extend_from_slice(&(err_len as u16).to_le_bytes());
    out.extend_from_slice(&rec.error.as_bytes()[..err_len]);
    debug_assert_eq!(out.len() - start, len);
    out
}

/// Bounds-checked little-endian reader over one payload.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| "dlq record truncated".to_string())?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

/// Decode one payload (the bytes after a record's length prefix).
fn decode_payload(b: &[u8]) -> Result<DlqRecord, String> {
    let mut c = Cursor { b, i: 0 };
    let cluster = c.u64()?;
    let base_seed = c.u64()?;
    let input_gb_bits = c.u64()?;
    let num_mappers = c.u32()?;
    let num_reducers = c.u32()?;
    let block_mb = c.u32()?;
    let rep = c.u32()?;
    let attempts = c.u32()?;
    let app_len = c.u8()? as usize;
    let app_bytes = c.take(app_len)?;
    let app = AppId::parse(
        std::str::from_utf8(app_bytes)
            .map_err(|_| "dlq record: app name not UTF-8".to_string())?,
    )?;
    let err_len = c.u16()? as usize;
    let err_bytes = c.take(err_len)?;
    let error = std::str::from_utf8(err_bytes)
        .map_err(|_| "dlq record: error message not UTF-8".to_string())?
        .to_string();
    if c.i != b.len() {
        return Err("dlq record: trailing payload bytes".into());
    }
    Ok(DlqRecord {
        key: StoreKey {
            cluster,
            app,
            num_mappers,
            num_reducers,
            input_gb_bits,
            block_mb,
            rep,
            base_seed,
        },
        attempts,
        error,
    })
}

/// Decode one framed record from the front of `bytes`, returning the
/// record and the total bytes consumed (prefix + payload) so callers can
/// walk a concatenated record stream.
pub fn decode_dlq_record(bytes: &[u8]) -> Result<(DlqRecord, usize), String> {
    if bytes.len() < 4 {
        return Err("dlq record truncated (length prefix)".into());
    }
    let len =
        u32::from_le_bytes(bytes[0..4].try_into().expect("4 bytes")) as usize;
    if len == 0 || len > MAX_DLQ_RECORD_LEN {
        return Err(format!("dlq record: implausible length {len}"));
    }
    let end = 4 + len;
    if bytes.len() < end {
        return Err("dlq record truncated (payload)".into());
    }
    let rec = decode_payload(&bytes[4..end])?;
    Ok((rec, end))
}

/// Whether `name` is a DLQ data file.
fn is_dlq_file(name: &str) -> bool {
    name.starts_with(DLQ_PREFIX) && name.ends_with(DLQ_SUFFIX)
}

/// Every DLQ file under `dir`, sorted by name (a missing directory is an
/// empty queue, not an error).
fn dlq_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) if !dir.exists() => return Ok(Vec::new()),
        Err(e) => return Err(format!("dlq: read {}: {e}", dir.display())),
    };
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("dlq: read dir entry: {e}"))?;
        if is_dlq_file(&entry.file_name().to_string_lossy()) {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

/// Append `records` to the queue at `dir` (created if needed) as one
/// fresh uniquely-named file — concurrent quarantiners never share a
/// file, so no locking is needed.  An empty batch writes nothing.
pub fn append(dir: &Path, records: &[DlqRecord]) -> Result<(), String> {
    if records.is_empty() {
        return Ok(());
    }
    fs::create_dir_all(dir)
        .map_err(|e| format!("dlq: create {}: {e}", dir.display()))?;
    let nonce = DLQ_COUNTER.fetch_add(1, Ordering::Relaxed);
    // mrlint: allow(determinism) — uniqueness salt for the file name; no simulated quantity derives from it
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let name = format!(
        "{DLQ_PREFIX}{:08x}-{:04x}-{}{DLQ_SUFFIX}",
        std::process::id(),
        nonce,
        hex_u64(nanos)
    );
    let path = dir.join(name);
    let mut bytes = Vec::with_capacity(DLQ_HEADER_LEN + records.len() * 128);
    bytes.extend_from_slice(&dlq_header());
    for rec in records {
        bytes.extend_from_slice(&encode_dlq_record(rec));
    }
    fs::write(&path, &bytes)
        .map_err(|e| format!("dlq: write {}: {e}", path.display()))
}

/// Fold the framed records of one DLQ file (bytes already read) into
/// `out`, tolerating corruption exactly like the store's load path: a
/// bad header skips the file, a garbled payload of plausible length
/// skips that record, a torn length prefix ends the file.
fn load_bytes(path: &Path, bytes: &[u8], out: &mut Vec<DlqRecord>) {
    if bytes.is_empty() {
        return;
    }
    if bytes.len() < DLQ_HEADER_LEN || bytes[..4] != DLQ_MAGIC {
        eprintln!("dlq: skipping non-DLQ file {}", path.display());
        return;
    }
    let ver = u32::from_le_bytes(
        bytes[4..DLQ_HEADER_LEN].try_into().expect("4 bytes"),
    );
    if !(1..=DLQ_FORMAT_VERSION).contains(&ver) {
        // A whole file of a newer build: skip and preserve.
        return;
    }
    let mut i = DLQ_HEADER_LEN;
    let mut first_bad = true;
    while i < bytes.len() {
        match decode_dlq_record(&bytes[i..]) {
            Ok((rec, consumed)) => {
                out.push(rec);
                i += consumed;
            }
            Err(e) => {
                // Try to resync on the frame boundary; a torn or
                // implausible prefix ends the file instead.
                let Some(prefix) = bytes.get(i..i + 4) else {
                    eprintln!(
                        "dlq: truncated record tail in {}",
                        path.display()
                    );
                    return;
                };
                let len = u32::from_le_bytes(
                    prefix.try_into().expect("4 bytes"),
                ) as usize;
                if len == 0
                    || len > MAX_DLQ_RECORD_LEN
                    || i + 4 + len > bytes.len()
                {
                    eprintln!(
                        "dlq: truncated/garbled record tail in {}",
                        path.display()
                    );
                    return;
                }
                if first_bad {
                    first_bad = false;
                    eprintln!(
                        "dlq: skipping corrupt record(s) in {}: {e}",
                        path.display()
                    );
                }
                i += 4 + len;
            }
        }
    }
}

/// Read every record in the queue at `dir`, deduplicated by key (the
/// occurrence with the most attempts wins; later files break ties) and
/// sorted by key for deterministic listing.  A missing directory is an
/// empty queue.
pub fn load(dir: &Path) -> Result<Vec<DlqRecord>, String> {
    let mut raw = Vec::new();
    for path in dlq_files(dir)? {
        let bytes = fs::read(&path)
            .map_err(|e| format!("dlq: read {}: {e}", path.display()))?;
        load_bytes(&path, &bytes, &mut raw);
    }
    let mut by_key: std::collections::BTreeMap<StoreKey, DlqRecord> =
        std::collections::BTreeMap::new();
    for rec in raw {
        match by_key.entry(rec.key) {
            std::collections::btree_map::Entry::Occupied(mut e) => {
                if rec.attempts >= e.get().attempts {
                    e.insert(rec);
                }
            }
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(rec);
            }
        }
    }
    let mut out: Vec<DlqRecord> = by_key.into_values().collect();
    out.sort_by_key(|r| r.key);
    Ok(out)
}

/// Remove every DLQ file under `dir`, returning the number of distinct
/// quarantined reps that were dropped.
pub fn clear(dir: &Path) -> Result<usize, String> {
    let records = load(dir)?;
    for path in dlq_files(dir)? {
        fs::remove_file(&path)
            .map_err(|e| format!("dlq: remove {}: {e}", path.display()))?;
    }
    Ok(records.len())
}

/// Drain the queue: read every record, then remove the files backing
/// them — the `dlq retry` primitive (retry failures are re-appended by
/// the caller).
pub fn take(dir: &Path) -> Result<Vec<DlqRecord>, String> {
    let records = load(dir)?;
    for path in dlq_files(dir)? {
        fs::remove_file(&path)
            .map_err(|e| format!("dlq: remove {}: {e}", path.display()))?;
    }
    Ok(records)
}

/// The queue directory for a profile store rooted at `store_dir`.
pub fn dlq_dir(store_dir: &Path) -> PathBuf {
    store_dir.join("dlq")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_dlq_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn random_record(rng: &mut Rng) -> DlqRecord {
        let apps = AppId::all();
        let err_len = rng.range_u64(0, 40) as usize;
        let error: String = (0..err_len)
            .map(|_| char::from(b'a' + (rng.range_u64(0, 26) as u8)))
            .collect();
        DlqRecord {
            // Every numeric field gets arbitrary bits — input_gb_bits in
            // particular sweeps NaN payloads, infinities, subnormals.
            key: StoreKey {
                cluster: rng.next_u64(),
                app: apps[rng.range_u64(0, apps.len() as u64) as usize],
                num_mappers: rng.next_u64() as u32,
                num_reducers: rng.next_u64() as u32,
                input_gb_bits: rng.next_u64(),
                block_mb: rng.next_u64() as u32,
                rep: rng.next_u64() as u32,
                base_seed: rng.next_u64(),
            },
            attempts: rng.next_u64() as u32,
            error,
        }
    }

    #[test]
    fn prop_record_round_trips_any_bits() {
        forall("dlq round-trip", 200, |rng| {
            let rec = random_record(rng);
            let bytes = encode_dlq_record(&rec);
            let (back, consumed) = decode_dlq_record(&bytes).unwrap();
            assert_eq!(back, rec);
            assert_eq!(consumed, bytes.len());
        });
    }

    #[test]
    fn nan_payload_bits_round_trip_exactly() {
        let mut rec = DlqRecord {
            key: StoreKey {
                cluster: 1,
                app: AppId::Grep,
                num_mappers: 16,
                num_reducers: 4,
                input_gb_bits: f64::NAN.to_bits() | 0xDEAD,
                block_mb: 64,
                rep: 2,
                base_seed: 42,
            },
            attempts: 3,
            error: "injected fault".into(),
        };
        let (back, _) = decode_dlq_record(&encode_dlq_record(&rec)).unwrap();
        assert_eq!(back.key.input_gb_bits, rec.key.input_gb_bits);
        assert!(back.key.input_gb().is_nan());
        rec.key.input_gb_bits = f64::NEG_INFINITY.to_bits();
        let (back, _) = decode_dlq_record(&encode_dlq_record(&rec)).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn oversized_error_is_truncated_on_char_boundary() {
        let rec = DlqRecord {
            key: StoreKey {
                cluster: 0,
                app: AppId::WordCount,
                num_mappers: 1,
                num_reducers: 1,
                input_gb_bits: 0,
                block_mb: 64,
                rep: 0,
                base_seed: 0,
            },
            attempts: 1,
            // 'é' is 2 bytes; 300 of them straddle the 512-byte cap on
            // an odd boundary, so naive truncation would split a char.
            error: "é".repeat(300),
        };
        let (back, _) = decode_dlq_record(&encode_dlq_record(&rec)).unwrap();
        assert!(back.error.len() <= MAX_ERROR_LEN);
        assert!(back.error.chars().all(|c| c == 'é'));
    }

    #[test]
    fn prop_truncated_tail_recovers_complete_records() {
        forall("dlq truncated tail", 60, |rng| {
            let n = rng.range_u64(1, 5) as usize;
            let recs: Vec<DlqRecord> =
                (0..n).map(|_| random_record(rng)).collect();
            let mut bytes = dlq_header().to_vec();
            let mut boundaries = vec![bytes.len()];
            for rec in &recs {
                bytes.extend_from_slice(&encode_dlq_record(rec));
                boundaries.push(bytes.len());
            }
            // Cut anywhere strictly inside the record stream: every
            // record wholly before the cut must survive, nothing after.
            let cut = rng.range_u64(
                DLQ_HEADER_LEN as u64,
                bytes.len() as u64,
            ) as usize;
            let complete =
                boundaries.iter().filter(|&&b| b <= cut).count() - 1;
            let mut out = Vec::new();
            load_bytes(Path::new("test"), &bytes[..cut], &mut out);
            assert_eq!(out, recs[..complete].to_vec());
        });
    }

    #[test]
    fn prop_garbled_payload_is_skipped_record_by_record() {
        forall("dlq garbled record", 60, |rng| {
            let good = [random_record(rng), random_record(rng)];
            let mut bad = encode_dlq_record(&random_record(rng));
            // Garble the payload (not the length prefix): flip the app
            // name length byte region so decode fails but framing holds.
            let idx = 4 + 3 * 8 + 5 * 4;
            bad[idx] = 0xFF;
            let mut bytes = dlq_header().to_vec();
            bytes.extend_from_slice(&encode_dlq_record(&good[0]));
            bytes.extend_from_slice(&bad);
            bytes.extend_from_slice(&encode_dlq_record(&good[1]));
            let mut out = Vec::new();
            load_bytes(Path::new("test"), &bytes, &mut out);
            assert_eq!(out, good.to_vec(), "both good records recovered");
        });
    }

    #[test]
    fn append_load_clear_lifecycle() {
        let dir = tmp("lifecycle");
        assert_eq!(load(&dir).unwrap(), Vec::new(), "missing dir is empty");
        let mut rng = Rng::new(7);
        let a = random_record(&mut rng);
        let mut b = random_record(&mut rng);
        append(&dir, &[a.clone()]).unwrap();
        append(&dir, &[b.clone()]).unwrap();
        // A re-quarantine of the same key with more attempts wins dedup.
        let mut b2 = b.clone();
        b2.attempts = b.attempts.wrapping_add(1);
        b2.error = "second failure".into();
        append(&dir, &[b2.clone()]).unwrap();
        b = b2;
        let mut want = vec![a.clone(), b.clone()];
        want.sort_by_key(|r| r.key);
        assert_eq!(load(&dir).unwrap(), want);
        // take drains; clear on the now-empty queue removes nothing.
        assert_eq!(take(&dir).unwrap(), want);
        assert_eq!(load(&dir).unwrap(), Vec::new());
        assert_eq!(clear(&dir).unwrap(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn newer_version_files_are_skipped_and_preserved() {
        let dir = tmp("newver");
        fs::create_dir_all(&dir).unwrap();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&DLQ_MAGIC);
        bytes.extend_from_slice(&(DLQ_FORMAT_VERSION + 1).to_le_bytes());
        bytes.extend_from_slice(&[0xAB; 32]);
        let path = dir.join("dlq-future.bin");
        fs::write(&path, &bytes).unwrap();
        assert_eq!(load(&dir).unwrap(), Vec::new());
        assert!(path.exists(), "future file preserved for a newer build");
        let _ = fs::remove_dir_all(&dir);
    }
}
