//! One experiment: an (app, M, R) setting run `REPS` times and averaged.

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::util::stats;

use super::executor::CampaignExecutor;

/// The paper repeats every experiment five times and keeps the mean
/// (§IV.A: "we run an experiment five times and then the mean of these
/// total execution time values is chosen").
pub const REPS: u32 = 5;

/// An experiment setting: the paper's two studied configuration parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ExperimentSpec {
    /// Application under test.
    pub app: AppId,
    /// The paper's first parameter: number of map tasks.
    pub num_mappers: u32,
    /// The paper's second parameter: number of reduce tasks.
    pub num_reducers: u32,
}

impl ExperimentSpec {
    /// Spec for `(app, M, R)`.
    pub fn new(app: AppId, m: u32, r: u32) -> ExperimentSpec {
        ExperimentSpec { app, num_mappers: m, num_reducers: r }
    }

    /// Parameter row for the regression: (p1, p2) = (M, R).
    pub fn params(&self) -> [f64; 2] {
        [self.num_mappers as f64, self.num_reducers as f64]
    }
}

/// Profiled outcome of one experiment.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    /// The setting that was profiled.
    pub spec: ExperimentSpec,
    /// The training/evaluation target: mean of the rep times.
    pub mean_time_s: f64,
    /// Per-repetition observations (kept for variance diagnostics).
    pub rep_times_s: Vec<f64>,
}

impl ExperimentResult {
    /// Run-to-run spread of the repetitions (temporal noise).
    pub fn rep_stddev(&self) -> f64 {
        stats::stddev(&self.rep_times_s)
    }
}

/// Profiled outcome of one experiment with **every** modeled output: the
/// paper's mean time plus the companion works' mean CPU seconds and mean
/// shuffle/HDFS bytes — what
/// [`CampaignExecutor::run_specs_full`] returns.
///
/// Byte-means are `None` when any repetition of the setting lacks its
/// counters (a quarantined rep): null, never silently wrong, and the
/// campaign still completes.  The time mean goes NaN in the same case.
#[derive(Clone, Debug)]
pub struct FullExperimentResult {
    /// The setting that was profiled.
    pub spec: ExperimentSpec,
    /// The paper's target: mean of the rep times.
    pub mean_time_s: f64,
    /// Mean total CPU seconds (arXiv 1203.4054's target).
    pub mean_cpu_s: f64,
    /// Mean shuffle bytes (arXiv 1206.2016's target), if every rep
    /// carried its counters.
    pub mean_shuffle_bytes: Option<f64>,
    /// Mean HDFS bytes, if every rep carried its counters.
    pub mean_hdfs_bytes: Option<f64>,
    /// Per-repetition times (kept for variance diagnostics).
    pub rep_times_s: Vec<f64>,
}

/// Run one experiment: `reps` simulated executions with distinct run seeds
/// (modeling the paper's five wall-clock runs), averaged.
///
/// `base_seed` identifies the profiling session; each repetition derives
/// `seed = hash(base_seed, spec, rep)` so experiments are independent and
/// the whole campaign is reproducible.  The HDFS layout is a session-level
/// artifact (planned once per `(base_seed, shape)` and shared by all
/// repetitions — see [`crate::mr::JobContext`]); this is a convenience
/// wrapper over a one-shot serial [`CampaignExecutor`], so it agrees
/// bit-for-bit with executor-driven campaigns.
pub fn run_experiment(
    cluster: &Cluster,
    spec: &ExperimentSpec,
    reps: u32,
    base_seed: u64,
) -> ExperimentResult {
    CampaignExecutor::serial()
        .run_specs(cluster, std::slice::from_ref(spec), reps, base_seed)
        .pop()
        .expect("one spec in, one result out")
}

/// Derive the run seed for one repetition of one setting within a
/// profiling session — the executor's determinism contract hinges on this
/// depending only on `(base_seed, spec, rep)`.
pub(crate) fn mix(base: u64, spec: &ExperimentSpec, rep: u32) -> u64 {
    let mut h = base ^ 0x9E37_79B9_7F4A_7C15;
    for v in [
        spec.app as u64,
        spec.num_mappers as u64,
        spec.num_reducers as u64,
        rep as u64,
    ] {
        h ^= v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = h.rotate_left(23).wrapping_mul(0x94D0_49BB_1331_11EB);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_reps_averaged() {
        let cluster = Cluster::paper_cluster();
        let spec = ExperimentSpec::new(AppId::WordCount, 20, 5);
        let res = run_experiment(&cluster, &spec, REPS, 42);
        assert_eq!(res.rep_times_s.len(), 5);
        let mean = res.rep_times_s.iter().sum::<f64>() / 5.0;
        assert!((res.mean_time_s - mean).abs() < 1e-9);
        // Reps differ (temporal noise) but cluster around the mean.
        assert!(res.rep_stddev() > 0.0);
        assert!(res.rep_stddev() < 0.2 * res.mean_time_s);
    }

    #[test]
    fn reproducible_for_same_session_seed() {
        let cluster = Cluster::paper_cluster();
        let spec = ExperimentSpec::new(AppId::EximParse, 10, 10);
        let a = run_experiment(&cluster, &spec, 3, 7);
        let b = run_experiment(&cluster, &spec, 3, 7);
        assert_eq!(a.rep_times_s, b.rep_times_s);
        let c = run_experiment(&cluster, &spec, 3, 8);
        assert_ne!(a.rep_times_s, c.rep_times_s);
    }

    #[test]
    fn distinct_specs_get_distinct_streams() {
        let cluster = Cluster::paper_cluster();
        let a = run_experiment(
            &cluster,
            &ExperimentSpec::new(AppId::WordCount, 20, 5),
            2,
            1,
        );
        let b = run_experiment(
            &cluster,
            &ExperimentSpec::new(AppId::WordCount, 20, 6),
            2,
            1,
        );
        // Different settings must not share per-rep noise draws.
        assert_ne!(a.rep_times_s[0], b.rep_times_s[0]);
    }

    #[test]
    fn params_row() {
        let spec = ExperimentSpec::new(AppId::Grep, 15, 30);
        assert_eq!(spec.params(), [15.0, 30.0]);
    }
}
