//! The default [`StoreBackend`]: one store **directory** of binary v3
//! segments plus a compacted index — the PR 2/3/5 single-directory
//! store, restructured so that loading is **lazy** (constructing the
//! backend is a few path checks; the data scan runs on first access)
//! and compaction is an explicit pass ([`FileBackend::compact`]) that
//! callers — the sharded facade's background thread, the CLI, benches —
//! run off the open path.

use std::collections::{BTreeSet, HashMap};
use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use super::codec::{
    bin_header, decode_payload, decode_record, encode_record_bin_into,
    frame_len, BIN_HEADER_LEN, BIN_MAGIC,
};
use super::key::{RecordError, StoreKey};
use super::{StoreBackend, StoreStats, STORE_FORMAT_VERSION};
use crate::mr::RepOutcome;
use crate::util::bytes::hex_u64;

pub(crate) const INDEX_FILE: &str = "index.bin";
pub(crate) const LEGACY_INDEX_FILE: &str = "index.jsonl";
pub(crate) const COMPACT_LOCK: &str = "compact.lock";

/// A `compact.lock` older than this is assumed to be the debris of a
/// crashed process (a compaction pass takes well under a second) and is
/// reclaimed, so one crash can never disable compaction forever.
const STALE_COMPACT_LOCK: Duration = Duration::from_secs(600);

/// Distinguishes session segments from everything else in the directory.
pub(crate) const SEGMENT_PREFIX: &str = "seg-";
pub(crate) const SEGMENT_SUFFIX: &str = ".bin";
pub(crate) const LEGACY_SEGMENT_SUFFIX: &str = ".jsonl";

/// Makes segment names unique when one process opens several stores (or
/// several executors share a directory) within one clock tick.
static SEG_COUNTER: AtomicU64 = AtomicU64::new(0);

struct SegmentWriter {
    file: fs::File,
    lock: PathBuf,
}

impl SegmentWriter {
    /// Create a fresh uniquely-named binary segment (header written
    /// immediately), taking its liveness lock *first* so a concurrent
    /// compaction never deletes it underneath us.
    fn create(dir: &Path) -> Result<SegmentWriter, String> {
        fs::create_dir_all(dir)
            .map_err(|e| format!("store: create dir {}: {e}", dir.display()))?;
        let path = dir.join(fresh_segment_name());
        let lock = lock_path(&path);
        let mut lf = OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&lock)
            .map_err(|e| format!("store: create lock {}: {e}", lock.display()))?;
        let _ = writeln!(lf, "{}", std::process::id());
        let mut file = match OpenOptions::new()
            .append(true)
            .create_new(true)
            .open(&path)
        {
            Ok(f) => f,
            Err(e) => {
                let _ = fs::remove_file(&lock);
                return Err(format!(
                    "store: create segment {}: {e}",
                    path.display()
                ));
            }
        };
        if let Err(e) = file.write_all(&bin_header()) {
            let _ = fs::remove_file(&lock);
            return Err(format!(
                "store: write segment header {}: {e}",
                path.display()
            ));
        }
        Ok(SegmentWriter { file, lock })
    }
}

impl Drop for SegmentWriter {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.lock);
    }
}

/// A unique name for a new segment file in this process.
pub(crate) fn fresh_segment_name() -> String {
    let nonce = SEG_COUNTER.fetch_add(1, Ordering::Relaxed);
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!(
        "{SEGMENT_PREFIX}{:08x}-{:04x}-{}{SEGMENT_SUFFIX}",
        std::process::id(),
        nonce,
        hex_u64(nanos)
    )
}

/// One resident record: the outcome plus its last-hit **touch**
/// generation (persisted in v3 records; 0 for data migrated from JSONL
/// stores, which therefore evicts first under a cap).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct StoredRep {
    pub(crate) outcome: RepOutcome,
    pub(crate) touch: u64,
}

struct Inner {
    /// Key → stored record (held as the very `f64`s that were
    /// decoded/produced, so every bit round-trips by construction).
    entries: HashMap<StoreKey, StoredRep>,
    /// Key of every record this backend instance has accepted, in
    /// acceptance order: the on-disk records found at load (sorted, so
    /// the order is deterministic), then every `put`/`refresh`
    /// insertion.  `journal.len()` is the backend's **generation**;
    /// consumers tail it by remembering the generation they last read.
    /// Keys only, so the journal does not double resident memory; a key
    /// whose record was upgraded (CPU figure added) appears twice, and
    /// a key evicted by a later compaction simply stops resolving.
    journal: Vec<StoreKey>,
    /// Encoded binary frames not yet appended to this session's segment.
    dirty: Vec<u8>,
    /// Records represented in `dirty` (the `pending()` count).
    dirty_count: usize,
    /// Keys whose touch generation changed since the last flush (lookup
    /// hits and re-puts of known values).  Flush appends a fresh frame
    /// per touched key so recency survives the process — that is what
    /// makes cross-session LRU eviction meaningful.  Only populated
    /// when the backend has a size cap: an uncapped warm run must stay
    /// write-free (the frames have no consumer without eviction).
    /// BTreeSet so the flush order is deterministic.
    touched: BTreeSet<StoreKey>,
    /// Monotonic touch clock, seeded from the largest touch on disk.
    clock: u64,
    /// Lazily created on first flush, so sessions with nothing to
    /// persist (reads without a cap, inspection) leave no file behind.
    writer: Option<SegmentWriter>,
    /// What loading saw on disk, plus every compaction pass since.
    stats: StoreStats,
}

/// The file-format [`StoreBackend`]: segments + index in one directory.
///
/// Construction records the configuration only; the directory is
/// scanned **lazily** on first access, so building a router over many
/// shards costs nothing for the shards a session never touches, and a
/// capped open of a huge store returns immediately.  Compaction —
/// folding segments into `index.bin`, evicting to the size cap,
/// deleting merged files — runs only inside [`FileBackend::compact`].
pub struct FileBackend {
    dir: PathBuf,
    cap: Option<u64>,
    /// `false` for inspection sessions (`peek`): never compact, so an
    /// observer can never rewrite files under another session's feet.
    /// Writes are still allowed — a peek session that `put`s flushes
    /// segments like any other.
    compact_allowed: bool,
    state: Mutex<Option<Inner>>,
    /// Per-file refresh bookkeeping: store file name → length as of the
    /// last successful ingest of that file.  [`FileBackend::refresh`]
    /// re-parses only files whose length changed (segments are
    /// append-only; the index is replaced wholesale by compaction), so
    /// an idle poll is a directory stat and a steady-state poll costs
    /// the changed files, not the whole store.
    refresh_state: Mutex<HashMap<String, u64>>,
}

impl FileBackend {
    /// Backend over `dir` with an optional size cap (bytes) enforced at
    /// compaction.  `compact_allowed = false` makes this an inspection
    /// session: [`FileBackend::compact`] becomes a no-op.  The
    /// directory is not created (or read) until first use.
    pub fn new(
        dir: &Path,
        cap: Option<u64>,
        compact_allowed: bool,
    ) -> FileBackend {
        FileBackend {
            dir: dir.to_path_buf(),
            cap,
            compact_allowed,
            state: Mutex::new(None),
            refresh_state: Mutex::new(HashMap::new()),
        }
    }

    /// The **eager** open the pre-sharding store performed: load the
    /// whole directory *and* run a compaction pass before returning.
    /// This is the single-index baseline the `bench store` comparison
    /// measures the lazy sharded open against.
    pub fn open_eager(
        dir: &Path,
        cap: Option<u64>,
    ) -> Result<FileBackend, String> {
        let backend = FileBackend::new(dir, cap, true);
        backend.compact()?;
        Ok(backend)
    }

    /// Directory this backend stores into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Lock the in-memory state, recovering from poison: a panicking
    /// writer leaves records it already journaled intact, and every
    /// mutation path re-validates against the on-disk generation, so
    /// continuing with the inner value is safe.
    fn lock_state(&self) -> MutexGuard<'_, Option<Inner>> {
        match self.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                self.state.clear_poison();
                poisoned.into_inner()
            }
        }
    }

    /// Load the directory into memory if this is the first access.
    fn inner<'a>(&self, state: &'a mut Option<Inner>) -> &'a mut Inner {
        state.get_or_insert_with(|| self.load())
    }

    /// Lock the refresh fingerprint map, recovering from poison — it only
    /// memoizes file lengths, and a stale entry just causes a re-read.
    fn lock_refresh_state(&self) -> MutexGuard<'_, HashMap<String, u64>> {
        match self.refresh_state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                self.refresh_state.clear_poison();
                poisoned.into_inner()
            }
        }
    }

    fn load(&self) -> Inner {
        let scan = match scan_dir(&self.dir) {
            Ok(scan) => scan,
            Err(e) => {
                // A lazy load has no Result channel; serve an empty view
                // and make sure compaction can never run from it.
                eprintln!(
                    "store: load {} failed ({e}); treating as empty",
                    self.dir.display()
                );
                let mut scan = Scan::empty();
                scan.index_unreadable = true;
                scan.stats.corrupt_segments += 1;
                scan
            }
        };
        let mut stats = scan.stats;
        stats.entries = scan.entries.len();
        // Seed the journal with everything on disk, sorted by key so the
        // initial generation's contents are deterministic.
        let mut journal: Vec<StoreKey> = scan.entries.keys().copied().collect();
        journal.sort();
        let clock = scan.entries.values().map(|sr| sr.touch).max().unwrap_or(0);
        Inner {
            entries: scan.entries,
            journal,
            dirty: Vec::new(),
            dirty_count: 0,
            touched: BTreeSet::new(),
            clock,
            writer: None,
            stats,
        }
    }

    /// Whether a compaction pass would plausibly do work, answerable
    /// **without** loading the store: an unlocked segment to fold, a
    /// legacy JSONL file to rewrite, or an index over the size cap.
    /// The facade's background thread uses this to skip clean shards.
    pub fn needs_compaction(&self) -> bool {
        if !self.compact_allowed {
            return false;
        }
        if self.dir.join(LEGACY_INDEX_FILE).exists() {
            return true;
        }
        if let Some(cap) = self.cap {
            let len = fs::metadata(self.dir.join(INDEX_FILE))
                .map(|m| m.len())
                .unwrap_or(0);
            if len > cap {
                return true;
            }
        }
        match segment_paths(&self.dir) {
            Ok(paths) => paths.iter().any(|p| !segment_is_locked(p)),
            Err(_) => false,
        }
    }

    /// Fold already-decoded records into the resident view as if they
    /// had been read from this backend's own files: no dirty marking,
    /// no segment writes — the sharded facade uses this to surface a
    /// legacy single-directory store's records through the shards when
    /// migration is not allowed to write (inspection opens, or a busy
    /// migration lock).
    pub(crate) fn preload(&self, records: Vec<(StoreKey, StoredRep)>) {
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        let mut fresh: Vec<StoreKey> = Vec::new();
        for (key, sr) in records {
            inner.clock = inner.clock.max(sr.touch);
            let known = inner.entries.contains_key(&key);
            fold_entry(&mut inner.entries, key, sr);
            if !known {
                fresh.push(key);
            }
        }
        fresh.sort();
        inner.journal.extend(fresh.iter().copied());
        inner.stats.entries = inner.entries.len();
    }

    /// Flush with the state lock already held (compaction flushes first
    /// so every resident record is on disk before the pass scans).
    fn flush_locked(&self, inner: &mut Inner) -> Result<(), String> {
        if inner.dirty.is_empty() && inner.touched.is_empty() {
            return Ok(());
        }
        if inner.writer.is_none() {
            inner.writer = Some(SegmentWriter::create(&self.dir)?);
        }
        let mut buf =
            Vec::with_capacity(inner.dirty.len() + 96 * inner.touched.len());
        buf.extend_from_slice(&inner.dirty);
        // Recency bumps travel as full (deduplicating) record frames; the
        // next compaction folds them and keeps the newest touch.
        for key in &inner.touched {
            if let Some(sr) = inner.entries.get(key) {
                encode_record_bin_into(key, &sr.outcome, sr.touch, &mut buf);
            }
        }
        let Some(writer) = inner.writer.as_mut() else {
            return Err("store: segment writer unavailable".to_string());
        };
        writer
            .file
            .write_all(&buf)
            .map_err(|e| format!("store: append failed: {e}"))?;
        writer
            .file
            .flush()
            .map_err(|e| format!("store: flush failed: {e}"))?;
        inner.dirty.clear();
        inner.dirty_count = 0;
        inner.touched.clear();
        Ok(())
    }
}

impl StoreBackend for FileBackend {
    fn get(&self, key: &StoreKey) -> Option<RepOutcome> {
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        match inner.entries.get_mut(key) {
            Some(sr) => {
                inner.clock += 1;
                sr.touch = inner.clock;
                if self.cap.is_some() {
                    inner.touched.insert(*key);
                }
                Some(sr.outcome)
            }
            None => None,
        }
    }

    fn lookup(&self, key: &StoreKey) -> Option<RepOutcome> {
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        inner.entries.get(key).map(|sr| sr.outcome)
    }

    fn put(&self, key: StoreKey, outcome: RepOutcome) -> bool {
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        inner.clock += 1;
        let clock = inner.clock;
        let known = match inner.entries.get_mut(&key) {
            Some(old)
                if old.outcome.same_bits(&outcome)
                    || outcome.downgrades(&old.outcome) =>
            {
                // Re-putting a known value is a use: recency only.
                old.touch = clock;
                if self.cap.is_some() {
                    inner.touched.insert(key);
                }
                true
            }
            _ => false,
        };
        if !known {
            inner.entries.insert(key, StoredRep { outcome, touch: clock });
            inner.journal.push(key);
            encode_record_bin_into(&key, &outcome, clock, &mut inner.dirty);
            inner.dirty_count += 1;
        }
        !known
    }

    fn flush(&self) -> Result<(), String> {
        let mut state = self.lock_state();
        // An untouched backend has nothing buffered: flushing must not
        // force the load (drop flushes every shard of a sharded store,
        // including the ones this session never looked at).
        match state.as_mut() {
            Some(inner) => self.flush_locked(inner),
            None => Ok(()),
        }
    }

    fn generation(&self) -> u64 {
        let mut state = self.lock_state();
        self.inner(&mut state).journal.len() as u64
    }

    fn read_since(
        &self,
        generation: u64,
    ) -> (Vec<(StoreKey, RepOutcome)>, u64) {
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        let from = (generation as usize).min(inner.journal.len());
        let records = inner
            .journal
            .get(from..)
            .unwrap_or_default()
            .iter()
            // A journaled key may have been evicted by a compaction pass
            // since (never a paper-plane key — those are pinned, and they
            // are the only keys the trainer tails).
            .filter_map(|k| {
                inner.entries.get(k).map(|sr| (*k, sr.outcome))
            })
            .collect();
        (records, inner.journal.len() as u64)
    }

    fn refresh(&self) -> Result<u64, String> {
        let fingerprint = dir_fingerprint(&self.dir)?;
        let changed: Vec<(String, u64)> = {
            let state = self.lock_refresh_state();
            fingerprint
                .iter()
                .filter(|(name, len)| state.get(name) != Some(len))
                .cloned()
                .collect()
        };
        if changed.is_empty() {
            // Still force the initial load: a refresh's promise is that
            // the view is current afterwards, even for an empty dir.
            let mut state = self.lock_state();
            self.inner(&mut state);
            return Ok(0);
        }
        // Re-parse only the changed files, tolerating (and logging)
        // corruption exactly like the load pass.
        let mut parsed: HashMap<StoreKey, StoredRep> = HashMap::new();
        let mut stats = StoreStats::default();
        let mut ingested: Vec<(String, u64)> = Vec::new();
        for (name, len) in changed {
            let path = self.dir.join(&name);
            match fs::read(&path) {
                Ok(bytes) => {
                    let _ =
                        ingest_bytes(&path, &bytes, &mut parsed, &mut stats);
                    ingested.push((name, len));
                }
                // Deleted mid-refresh (racing compaction): its records
                // are in the rewritten index, whose length changed too.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => eprintln!(
                    "store: refresh skipping unreadable {}: {e}",
                    path.display()
                ),
            }
        }
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        let mut fresh: Vec<(StoreKey, StoredRep)> = Vec::new();
        for (key, sr) in parsed {
            inner.clock = inner.clock.max(sr.touch);
            match inner.entries.get_mut(&key) {
                Some(old) => {
                    // Another session used this record: keep the newest
                    // recency, but never downgrade a full outcome.
                    old.touch = old.touch.max(sr.touch);
                    if sr.outcome.upgrades(&old.outcome) {
                        fresh.push((
                            key,
                            StoredRep {
                                outcome: sr.outcome,
                                touch: old.touch,
                            },
                        ));
                    }
                }
                None => fresh.push((key, sr)),
            }
        }
        // Sort so concurrent writers' records land in the journal in a
        // deterministic order whatever the directory scan produced.
        fresh.sort_by(|a, b| a.0.cmp(&b.0));
        let new_records = fresh.len() as u64;
        for (key, sr) in fresh {
            inner.entries.insert(key, sr);
            inner.journal.push(key);
        }
        drop(state);
        let mut state = self.lock_refresh_state();
        // Forget files compaction removed, so the map stays bounded by
        // the live file set ...
        state.retain(|name, _| fingerprint.iter().any(|(n, _)| n == name));
        // ... and record the pre-read lengths of what was ingested (a
        // write landing mid-read makes the next poll re-read that file —
        // the safe direction).
        for (name, len) in ingested {
            state.insert(name, len);
        }
        Ok(new_records)
    }

    /// One guarded compaction pass: flush, re-scan the directory, evict
    /// to the cap, rewrite the index atomically, delete merged
    /// segments.  Holds the in-memory state lock throughout, so readers
    /// of **this shard** wait while it compacts — that is exactly the
    /// stop-the-world cost the sharded facade amortizes by compacting
    /// one shard at a time off the open path.
    fn compact(&self) -> Result<StoreStats, String> {
        if !self.compact_allowed {
            return Ok(StoreStats::default());
        }
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        self.flush_locked(inner)?;
        let Some(_guard) = CompactGuard::acquire(&self.dir) else {
            eprintln!(
                "store: compaction lock busy for {}; skipping pass",
                self.dir.display()
            );
            return Ok(StoreStats::default());
        };
        // Everything resident is now on disk (our own records in our
        // locked segment), so a fresh scan under the lock is the
        // authoritative write set — using it, rather than memory, keeps
        // another session's evictions durable (no resurrection).
        let mut scan = scan_dir(&self.dir)?;
        let mut pass = scan.stats;
        let over_cap =
            self.cap.is_some_and(|cap| index_bytes(&scan.entries) > cap);
        if scan.mergeable.is_empty() && !scan.legacy_index && !over_cap {
            return Ok(pass); // nothing to do
        }
        if scan.index_unreadable {
            // Rewriting the index now would replace the (unreadable but
            // possibly recoverable) old index with segment data only.
            // Leave everything in place for manual recovery.
            eprintln!(
                "store: index unreadable; compaction disabled to avoid \
                 data loss"
            );
            return Ok(pass);
        }
        let evicted = match self.cap {
            Some(cap) => evict_to_cap(&mut scan.entries, cap),
            None => Vec::new(),
        };
        write_index(&self.dir, &scan.entries)?;
        for p in &scan.mergeable {
            // Best-effort; also reclaim a dead writer's leftover lock so
            // it stops shadowing opens.
            let _ = fs::remove_file(p);
            let _ = fs::remove_file(lock_path(p));
        }
        // The legacy index is folded into the binary one; drop it so it
        // cannot resurrect records.
        let _ = fs::remove_file(self.dir.join(LEGACY_INDEX_FILE));
        pass.compacted = true;
        pass.merged_segments = scan.mergeable.len();
        pass.evicted = evicted.len();
        if !evicted.is_empty() {
            eprintln!(
                "store: size cap: evicted {} least-recently-used record(s) \
                 from {}",
                evicted.len(),
                self.dir.display()
            );
        }
        // Reconcile memory with the compacted view: drop what eviction
        // removed, fold in (and journal) records other sessions flushed
        // that the scan surfaced.
        for (key, _) in &evicted {
            inner.entries.remove(key);
        }
        let mut fresh: Vec<StoreKey> = Vec::new();
        for (key, sr) in scan.entries {
            let known = inner.entries.contains_key(&key);
            inner.clock = inner.clock.max(sr.touch);
            fold_entry(&mut inner.entries, key, sr);
            if !known {
                fresh.push(key);
            }
        }
        fresh.sort();
        inner.journal.extend(fresh.iter().copied());
        inner.stats.merged_segments += pass.merged_segments;
        inner.stats.evicted += pass.evicted;
        inner.stats.compacted = true;
        pass.entries = inner.entries.len();
        Ok(pass)
    }

    fn stats(&self) -> StoreStats {
        let mut state = self.lock_state();
        let inner = self.inner(&mut state);
        let mut s = inner.stats;
        s.entries = inner.entries.len();
        s.bytes = index_bytes(&inner.entries);
        s.pending = inner.dirty_count;
        s
    }

    fn len(&self) -> usize {
        let mut state = self.lock_state();
        self.inner(&mut state).entries.len()
    }

    fn pending(&self) -> usize {
        // An unloaded shard has buffered nothing; don't force the load.
        self.lock_state().as_ref().map_or(0, |inner| inner.dirty_count)
    }
}

impl Drop for FileBackend {
    fn drop(&mut self) {
        if let Err(e) = self.flush() {
            eprintln!("store: flush on drop failed: {e}");
        }
        // Dropping `state` drops the SegmentWriter, releasing its lock.
    }
}

// --------------------------------------------------- directory scanning

/// Everything one pass over a store directory learns.
pub(crate) struct Scan {
    pub(crate) entries: HashMap<StoreKey, StoredRep>,
    /// Segments safe to fold into the index and delete: readable, not
    /// held by a live writer, and free of newer-version records (legacy
    /// JSONL segments *are* mergeable — migration rewrites them as v3).
    pub(crate) mergeable: Vec<PathBuf>,
    pub(crate) stats: StoreStats,
    /// The index existed but could not be read (or belongs to a newer
    /// build) — compaction must not rewrite it from segment data alone.
    pub(crate) index_unreadable: bool,
    /// A readable legacy JSONL index is present: compaction should run
    /// even with no segments to fold, so the index is rewritten as v3.
    pub(crate) legacy_index: bool,
}

impl Scan {
    fn empty() -> Scan {
        Scan {
            entries: HashMap::new(),
            mergeable: Vec::new(),
            stats: StoreStats::default(),
            index_unreadable: false,
            legacy_index: false,
        }
    }
}

/// Read the index and every segment under `dir` into memory, tolerating
/// (and tallying) corruption.  A missing directory is an empty store.
/// Load order is deterministic (legacy index, binary index, then
/// segments in sorted name order), and by determinism of the simulator
/// any duplicate keys carry equal values, so later-wins is harmless —
/// with one exception handled in [`fold_entry`]: a CPU-less
/// (v1-migrated) duplicate never displaces a full outcome, whatever the
/// load order.  Duplicate touches resolve to the maximum (newest use).
pub(crate) fn scan_dir(dir: &Path) -> Result<Scan, String> {
    let mut scan = Scan::empty();
    if !dir.exists() {
        return Ok(scan);
    }
    for (name, legacy) in [(LEGACY_INDEX_FILE, true), (INDEX_FILE, false)] {
        let path = dir.join(name);
        match fs::read(&path) {
            Ok(bytes) => {
                let stale_before = scan.stats.stale_lines;
                let ok = ingest_bytes(
                    &path,
                    &bytes,
                    &mut scan.entries,
                    &mut scan.stats,
                );
                if !ok || scan.stats.stale_lines != stale_before {
                    // Unreadable, or written by a newer build: either way
                    // this open does not know the index's full contents.
                    scan.index_unreadable = true;
                } else if legacy {
                    scan.legacy_index = true;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                scan.stats.corrupt_segments += 1;
                scan.index_unreadable = true;
                eprintln!(
                    "store: skipping unreadable index {}: {e}",
                    path.display()
                );
            }
        }
    }

    for path in segment_paths(dir)? {
        scan.stats.segments_seen += 1;
        let locked = segment_is_locked(&path);
        match fs::read(&path) {
            Ok(bytes) => {
                let stale_before = scan.stats.stale_lines;
                let readable = ingest_bytes(
                    &path,
                    &bytes,
                    &mut scan.entries,
                    &mut scan.stats,
                );
                // A locked segment is still being written; one with
                // newer-version content belongs to another build.  Both
                // are merged-from but never deleted.
                if readable
                    && !locked
                    && scan.stats.stale_lines == stale_before
                {
                    scan.mergeable.push(path);
                }
            }
            // Raced with another process's compaction: the segment's
            // records are in the index that pass wrote.  Not corruption.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                scan.stats.corrupt_segments += 1;
                eprintln!(
                    "store: skipping unreadable segment {}: {e}",
                    path.display()
                );
            }
        }
    }
    Ok(scan)
}

/// Fold one decoded record into the in-memory map: later wins, except a
/// partial outcome (missing CPU or byte figures) never displaces a
/// fuller one, and the touch resolves to the newest (maximum) generation
/// either side has seen.
pub(crate) fn fold_entry(
    entries: &mut HashMap<StoreKey, StoredRep>,
    key: StoreKey,
    rep: StoredRep,
) {
    match entries.get_mut(&key) {
        Some(old) => {
            old.touch = old.touch.max(rep.touch);
            if !rep.outcome.downgrades(&old.outcome) {
                old.outcome = rep.outcome;
            }
        }
        None => {
            entries.insert(key, rep);
        }
    }
}

/// Fold one store file's bytes into `entries`, dispatching on format:
/// binary v3/v4 (`MRTS` magic) or legacy JSONL.  Returns `false` when the
/// file as a whole could not be used (not UTF-8 JSONL, torn binary
/// header, or a newer binary version) — such files are never merged.
pub(crate) fn ingest_bytes(
    path: &Path,
    bytes: &[u8],
    entries: &mut HashMap<StoreKey, StoredRep>,
    stats: &mut StoreStats,
) -> bool {
    if bytes.is_empty() {
        return true;
    }
    if bytes.starts_with(&BIN_MAGIC) {
        let Some(ver) = super::codec::le_u32_at(bytes, 4) else {
            // Torn header write: no records to recover.
            stats.corrupt_lines += 1;
            eprintln!(
                "store: truncated binary header in {}",
                path.display()
            );
            return true;
        };
        if !(3..=STORE_FORMAT_VERSION).contains(&ver) {
            // A whole file of a newer build: skip and preserve.
            stats.stale_lines += 1;
            return true;
        }
        load_bin_records(path, bytes, entries, stats);
        true
    } else {
        match std::str::from_utf8(bytes) {
            Ok(text) => {
                load_lines(path, text, entries, stats);
                true
            }
            Err(_) => {
                stats.corrupt_segments += 1;
                eprintln!(
                    "store: skipping non-UTF-8, non-binary file {}",
                    path.display()
                );
                false
            }
        }
    }
}

/// Walk the framed records of a binary store file (header already
/// validated), tolerating corruption: a garbled payload of plausible
/// length is skipped record-by-record; a torn length prefix ends the
/// file (nothing after it can be re-synchronized).
fn load_bin_records(
    path: &Path,
    bytes: &[u8],
    entries: &mut HashMap<StoreKey, StoredRep>,
    stats: &mut StoreStats,
) {
    let mut i = BIN_HEADER_LEN;
    let mut first_bad = true;
    while i < bytes.len() {
        let Some(len) =
            super::codec::le_u32_at(bytes, i).map(|l| l as usize)
        else {
            stats.corrupt_lines += 1;
            eprintln!(
                "store: truncated record tail in {}",
                path.display()
            );
            return;
        };
        if len == 0 || len > super::codec::MAX_RECORD_LEN {
            stats.corrupt_lines += 1;
            eprintln!(
                "store: truncated/garbled record tail in {}",
                path.display()
            );
            return;
        }
        let Some(payload) = bytes.get(i + 4..i + 4 + len) else {
            stats.corrupt_lines += 1;
            eprintln!(
                "store: truncated/garbled record tail in {}",
                path.display()
            );
            return;
        };
        match decode_payload(payload) {
            Ok((key, outcome, touch)) => {
                fold_entry(entries, key, StoredRep { outcome, touch });
            }
            Err(e) => {
                stats.corrupt_lines += 1;
                if first_bad {
                    first_bad = false;
                    eprintln!(
                        "store: skipping corrupt record(s) in {}: {e}",
                        path.display()
                    );
                }
            }
        }
        i += 4 + len;
    }
}

/// Fold every decodable JSONL line of `text` into `entries`, tallying
/// skips and migrations.  Duplicate-key resolution is [`fold_entry`]'s.
fn load_lines(
    path: &Path,
    text: &str,
    entries: &mut HashMap<StoreKey, StoredRep>,
    stats: &mut StoreStats,
) {
    let mut first_bad = true;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match decode_record(line) {
            Ok((key, outcome, ver)) => {
                if ver < STORE_FORMAT_VERSION {
                    stats.migrated_lines += 1;
                }
                // JSONL predates touch tracking: migrated records start
                // at generation 0, i.e. coldest — first out under a cap.
                fold_entry(entries, key, StoredRep { outcome, touch: 0 });
            }
            Err(RecordError::StaleVersion(_)) => stats.stale_lines += 1,
            Err(RecordError::Corrupt(e)) => {
                stats.corrupt_lines += 1;
                if first_bad {
                    first_bad = false;
                    eprintln!(
                        "store: skipping corrupt line(s) in {}: {e}",
                        path.display()
                    );
                }
            }
        }
    }
}

// --------------------------------------------- locks, paths, compaction

/// Liveness-lock path for a segment file (`<segment>.lock`).
pub(crate) fn lock_path(segment: &Path) -> PathBuf {
    let name = segment
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    segment.with_file_name(format!("{name}.lock"))
}

/// Whether `segment` is held by a **live** writer.  Lock files carry the
/// writer's pid; a lock whose process is gone (crashed writer) no longer
/// protects the segment, so compaction can reclaim it.  An empty or
/// garbled lock is treated as live — it may be mid-creation.
pub(crate) fn segment_is_locked(segment: &Path) -> bool {
    let lock = lock_path(segment);
    match fs::read_to_string(&lock) {
        Err(_) if !lock.exists() => false,
        Err(_) => true, // unreadable lock: assume live
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid_alive(pid),
            Err(_) => true, // pid not written yet: assume live
        },
    }
}

/// Stores are per-machine (the lock protocol relies on a shared pid
/// namespace), so /proc is authoritative on Linux; elsewhere be
/// conservative and treat every lock holder as alive.
#[cfg(target_os = "linux")]
pub(crate) fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn pid_alive(_pid: u32) -> bool {
    true
}

/// Whether `name` is a store data file (index or segment, either format).
pub(crate) fn is_store_file(name: &str) -> bool {
    name == INDEX_FILE
        || name == LEGACY_INDEX_FILE
        || (name.starts_with(SEGMENT_PREFIX)
            && (name.ends_with(SEGMENT_SUFFIX)
                || name.ends_with(LEGACY_SEGMENT_SUFFIX)))
}

/// `(name, length)` of every store file (index + segments) under `dir`,
/// sorted by name — the cheap change detector behind refresh.  Segments
/// are append-only and compaction replaces whole files, so any new
/// record changes some file's length (or the file set).  A missing
/// directory fingerprints as empty.
fn dir_fingerprint(dir: &Path) -> Result<Vec<(String, u64)>, String> {
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Vec::new())
        }
        Err(e) => return Err(format!("store: read {}: {e}", dir.display())),
    };
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if !is_store_file(&name) {
            continue;
        }
        // A file deleted mid-scan (racing compaction) counts as length 0;
        // the next pass sees the final state.
        let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
        out.push((name, len));
    }
    out.sort();
    Ok(out)
}

/// All segment files under `dir` (binary and legacy), sorted by name.
/// A missing directory holds none.
fn segment_paths(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Vec::new())
        }
        Err(e) => return Err(format!("store: read {}: {e}", dir.display())),
    };
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with(SEGMENT_PREFIX)
            && (name.ends_with(SEGMENT_SUFFIX)
                || name.ends_with(LEGACY_SEGMENT_SUFFIX))
        {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

/// Exact byte size of the binary index [`write_index`] would produce.
pub(crate) fn index_bytes(entries: &HashMap<StoreKey, StoredRep>) -> u64 {
    BIN_HEADER_LEN as u64
        + entries
            .iter()
            .map(|(k, sr)| frame_len(k, &sr.outcome) as u64)
            .sum::<u64>()
}

/// Drop least-recently-used records until the index fits `cap` bytes,
/// returning what was removed (so a failed index rewrite can restore
/// them).  Paper-plane repetitions are pinned — they are the online
/// trainer's training data ([`crate::coordinator::Trainer`] tails
/// exactly those keys) and must never vanish between two of its polls.
/// Eviction order is deterministic: ascending `(touch, key)`.  When
/// pinned records alone exceed the cap, everything unpinned goes and
/// the overshoot is kept (with a warning) rather than dropping
/// training data.
pub(crate) fn evict_to_cap(
    entries: &mut HashMap<StoreKey, StoredRep>,
    cap: u64,
) -> Vec<(StoreKey, StoredRep)> {
    let mut total = index_bytes(entries);
    if total <= cap {
        return Vec::new();
    }
    let mut candidates: Vec<(u64, StoreKey)> = entries
        .iter()
        .filter(|(k, _)| !k.is_paper_plane())
        .map(|(k, sr)| (sr.touch, *k))
        .collect();
    candidates.sort();
    let mut evicted = Vec::new();
    for (_, key) in candidates {
        if total <= cap {
            break;
        }
        if let Some(sr) = entries.remove(&key) {
            total -= frame_len(&key, &sr.outcome) as u64;
            evicted.push((key, sr));
        }
    }
    if total > cap {
        eprintln!(
            "store: size cap {cap} B is below the pinned paper-plane \
             records ({total} B); keeping them anyway"
        );
    }
    evicted
}

/// Rewrite the index from `entries` as binary v3 via write-to-temp +
/// atomic rename.  Must only be called while holding the
/// [`CompactGuard`].
fn write_index(
    dir: &Path,
    entries: &HashMap<StoreKey, StoredRep>,
) -> Result<(), String> {
    // Key-sorted records make the index byte-deterministic: compacting an
    // already-compact store rewrites the identical file (idempotence).
    let mut records: Vec<(&StoreKey, &StoredRep)> = entries.iter().collect();
    records.sort_by(|a, b| a.0.cmp(b.0));
    let mut body =
        Vec::with_capacity(BIN_HEADER_LEN + records.len() * 96);
    body.extend_from_slice(&bin_header());
    for (key, sr) in records {
        encode_record_bin_into(key, &sr.outcome, sr.touch, &mut body);
    }
    let tmp = dir.join(format!("{INDEX_FILE}.tmp-{}", std::process::id()));
    fs::write(&tmp, &body)
        .map_err(|e| format!("write {}: {e}", tmp.display()))?;
    fs::rename(&tmp, dir.join(INDEX_FILE))
        .map_err(|e| format!("rename {}: {e}", tmp.display()))
}

/// Delete every store file directly under `dir` (index, segments, locks,
/// leftover temp files — binary and legacy JSONL alike).  Returns how
/// many files were removed; a missing directory is an empty store, not
/// an error.  Shard subdirectories are the facade's to clear.
pub(crate) fn clear_dir_files(dir: &Path) -> Result<usize, String> {
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
        Err(e) => return Err(format!("store: read {}: {e}", dir.display())),
    };
    let mut removed = 0;
    for entry in rd {
        let entry =
            entry.map_err(|e| format!("store: read dir entry: {e}"))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let ours = name == INDEX_FILE
            || name == LEGACY_INDEX_FILE
            || name == COMPACT_LOCK
            || name.starts_with(&format!("{INDEX_FILE}.tmp-"))
            || name.starts_with(&format!("{LEGACY_INDEX_FILE}.tmp-"))
            || (name.starts_with(SEGMENT_PREFIX)
                && (name.ends_with(SEGMENT_SUFFIX)
                    || name.ends_with(LEGACY_SEGMENT_SUFFIX)
                    || name.ends_with(&format!("{SEGMENT_SUFFIX}.lock"))
                    || name.ends_with(&format!(
                        "{LEGACY_SEGMENT_SUFFIX}.lock"
                    ))));
        if ours {
            fs::remove_file(entry.path())
                .map_err(|e| format!("store: remove {name}: {e}"))?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// Holds `compact.lock` for the duration of one scan-and-rewrite pass.
pub(crate) struct CompactGuard {
    path: PathBuf,
}

impl CompactGuard {
    pub(crate) fn acquire(dir: &Path) -> Option<CompactGuard> {
        let path = dir.join(COMPACT_LOCK);
        for attempt in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&path)
            {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    return Some(CompactGuard { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    // A crashed compactor must not disable compaction
                    // forever: reclaim locks far older than any real
                    // pass and retry once.
                    if attempt == 0 && compact_lock_is_stale(&path) {
                        eprintln!(
                            "store: reclaiming stale {}",
                            path.display()
                        );
                        let _ = fs::remove_file(&path);
                        continue;
                    }
                    return None;
                }
                Err(_) => return None,
            }
        }
        None
    }
}

fn compact_lock_is_stale(path: &Path) -> bool {
    fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|t| t.elapsed().ok())
        .map(|age| age > STALE_COMPACT_LOCK)
        .unwrap_or(false)
}

impl Drop for CompactGuard {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppId;
    use crate::util::json::Json;

    fn key(m: u32, r: u32, rep: u32, seed: u64) -> StoreKey {
        StoreKey {
            cluster: 0xDEAD_BEEF_0BAD_F00D,
            app: AppId::WordCount,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
            block_mb: StoreKey::PAPER_BLOCK_MB,
            rep,
            base_seed: seed,
        }
    }

    /// A record line exactly as the v1 (PR 2) store wrote it.
    fn v1_line(k: &StoreKey, time_s: f64) -> String {
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("cluster", Json::Str(hex_u64(k.cluster))),
            ("app", Json::Str(k.app.name().to_string())),
            ("m", Json::Num(k.num_mappers as f64)),
            ("r", Json::Num(k.num_reducers as f64)),
            ("rep", Json::Num(k.rep as f64)),
            ("seed", Json::Str(hex_u64(k.base_seed))),
            ("bits", Json::Str(hex_u64(time_s.to_bits()))),
            ("t", Json::Num(time_s)),
        ])
        .to_string()
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "mrtuner_filebackend_unit_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn lazy_backend_loads_on_first_access_only() {
        let dir = tmp_dir("lazy");
        {
            let b = FileBackend::new(&dir, None, true);
            assert!(b.put(key(20, 5, 0, 1), RepOutcome::full(10.0, 1.0)));
            b.flush().unwrap();
        }
        // Construction alone must not create, read, or lock anything.
        let b = FileBackend::new(&dir, None, true);
        assert!(b.state.lock().unwrap().is_none(), "no load yet");
        assert_eq!(
            b.get(&key(20, 5, 0, 1)),
            Some(RepOutcome::full(10.0, 1.0)),
            "first access loads"
        );
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn put_returns_whether_journaled() {
        let dir = tmp_dir("putbool");
        let b = FileBackend::new(&dir, None, true);
        let k = key(5, 5, 0, 7);
        assert!(b.put(k, RepOutcome::full(3.5, 0.5)), "new key journaled");
        assert!(
            !b.put(k, RepOutcome::full(3.5, 0.5)),
            "identical value is recency only"
        );
        assert!(
            !b.put(k, RepOutcome::time_only(3.5)),
            "downgrade never journaled"
        );
        let k2 = key(6, 6, 0, 7);
        assert!(b.put(k2, RepOutcome::time_only(9.0)));
        assert!(
            b.put(k2, RepOutcome::full(9.0, 1.0)),
            "CPU upgrade re-journaled"
        );
        assert_eq!(b.generation(), 3);
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_segment_survives_compaction_and_answers_v3_lookup() {
        let dir = tmp_dir("migrate");
        std::fs::create_dir_all(&dir).unwrap();
        let k = key(20, 5, 0, 7);
        std::fs::write(
            dir.join("seg-cafe0000-0000-legacy.jsonl"),
            format!(
                "{}\n{}\n",
                v1_line(&k, 100.5),
                v1_line(&key(20, 5, 1, 7), 101.5)
            ),
        )
        .unwrap();
        {
            let b = FileBackend::open_eager(&dir, None).unwrap();
            let st = b.stats();
            assert_eq!(st.migrated_lines, 2);
            assert_eq!(
                st.merged_segments, 1,
                "v1 segment folded, not orphaned"
            );
            assert_eq!(st.stale_lines, 0);
            assert_eq!(b.get(&k), Some(RepOutcome::time_only(100.5)));
        }
        // The rewritten index is pure v3 binary and still answers after
        // reopen.
        let recs =
            super::super::read_file_records(&dir.join(INDEX_FILE)).unwrap();
        assert_eq!(recs.len(), 2);
        assert!(recs.iter().all(|(_, _, v)| *v == STORE_FORMAT_VERSION));
        assert!(!dir.join(LEGACY_INDEX_FILE).exists());
        let b = FileBackend::open_eager(&dir, None).unwrap();
        assert_eq!(b.stats().migrated_lines, 0, "migration is one-time");
        assert_eq!(b.get(&k), Some(RepOutcome::time_only(100.5)));
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_jsonl_index_is_rewritten_as_binary() {
        let dir = tmp_dir("legacy_index");
        std::fs::create_dir_all(&dir).unwrap();
        let k = key(10, 10, 0, 3);
        std::fs::write(
            dir.join(LEGACY_INDEX_FILE),
            format!(
                "{}\n",
                super::super::encode_record(&k, &RepOutcome::full(5.0, 1.0))
            ),
        )
        .unwrap();
        {
            // No segments at all — the legacy index alone triggers the
            // upgrade compaction.
            let b = FileBackend::new(&dir, None, true);
            assert!(b.needs_compaction(), "legacy index wants a rewrite");
            let pass = b.compact().unwrap();
            assert!(pass.compacted);
            assert_eq!(b.get(&k), Some(RepOutcome::full(5.0, 1.0)));
        }
        assert!(dir.join(INDEX_FILE).exists());
        assert!(!dir.join(LEGACY_INDEX_FILE).exists());
        let b = FileBackend::new(&dir, None, true);
        assert!(!b.needs_compaction(), "already compact");
        assert_eq!(b.get(&k), Some(RepOutcome::full(5.0, 1.0)));
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_binary_file_is_preserved_not_merged() {
        let dir = tmp_dir("stale_bin");
        std::fs::create_dir_all(&dir).unwrap();
        // A segment written by a hypothetical v5 build.
        let mut future = Vec::new();
        future.extend_from_slice(&BIN_MAGIC);
        future.extend_from_slice(&5u32.to_le_bytes());
        future.extend_from_slice(&[1, 2, 3, 4]);
        let seg = dir.join("seg-feed0000-0000-future.bin");
        std::fs::write(&seg, &future).unwrap();
        let b = FileBackend::open_eager(&dir, None).unwrap();
        let st = b.stats();
        assert_eq!(st.stale_lines, 1, "future file counted as stale");
        assert_eq!(st.corrupt_lines, 0);
        assert!(seg.exists(), "preserved for the build that understands it");
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_outcome_beats_migrated_duplicate_in_any_load_order() {
        let k = key(10, 10, 0, 1);
        let full = RepOutcome::full(55.0, 44.0);
        for lines in [
            // v1-migrated first, upgrade second ...
            format!(
                "{}\n{}\n",
                v1_line(&k, 55.0),
                super::super::encode_record(&k, &full)
            ),
            // ... and the reverse: the full outcome must win either way.
            format!(
                "{}\n{}\n",
                super::super::encode_record(&k, &full),
                v1_line(&k, 55.0)
            ),
        ] {
            let mut entries = HashMap::new();
            let mut stats = StoreStats::default();
            load_lines(Path::new("test"), &lines, &mut entries, &mut stats);
            assert_eq!(
                stats.migrated_lines, 2,
                "v1 and v2 lines both migrate"
            );
            assert_eq!(entries.get(&k).map(|sr| sr.outcome), Some(full));
        }
    }

    #[test]
    fn read_since_skips_evicted_keys() {
        let dir = tmp_dir("evict_journal");
        // A capped backend small enough that filler must go.
        let b = FileBackend::new(&dir, Some(600), true);
        // Pinned paper-plane records plus off-plane filler.
        for rep in 0..3 {
            b.put(key(20, 5, rep, 1), RepOutcome::full(100.0 + rep as f64, 1.0));
        }
        for i in 0..20u32 {
            b.put(
                StoreKey {
                    cluster: 1,
                    app: AppId::WordCount,
                    num_mappers: 5 + i,
                    num_reducers: 7,
                    input_gb_bits: 2.0f64.to_bits(),
                    block_mb: 128,
                    rep: 0,
                    base_seed: 2,
                },
                RepOutcome::full(10.0 + i as f64, 0.5),
            );
        }
        let g = b.generation();
        assert_eq!(g, 23);
        let pass = b.compact().unwrap();
        assert!(pass.evicted > 0, "cap forced eviction: {pass}");
        // The journal still spans 23 keys, but evicted ones no longer
        // resolve — read_since serves only the resident records.
        let (records, g2) = b.read_since(0);
        assert_eq!(g2, 23);
        assert_eq!(records.len(), 23 - pass.evicted);
        assert!(records.iter().filter(|(k, _)| k.is_paper_plane()).count() == 3);
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_is_noop_without_permission_or_work() {
        let dir = tmp_dir("noop");
        let b = FileBackend::new(&dir, None, false);
        b.put(key(5, 5, 0, 1), RepOutcome::full(1.0, 0.1));
        b.flush().unwrap();
        assert!(!b.needs_compaction());
        let pass = b.compact().unwrap();
        assert!(!pass.compacted, "inspection sessions never compact");
        assert!(!dir.join(INDEX_FILE).exists());
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
