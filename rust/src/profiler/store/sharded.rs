//! [`ProfileStore`] — the public store facade: N [`StoreBackend`] shards
//! behind one key-routed API, a cross-shard change journal, legacy
//! single-directory migration, and background compaction.
//!
//! Routing is **per-application**: a key's shard is the FNV-1a hash of
//! its application name modulo the shard count, pinned on disk by
//! `shards.meta` the first time a store is opened.  All of one app's
//! records — including the paper-plane repetitions the trainer tails —
//! live in one shard, so a trainer cursor never spans shards and two
//! campaigns profiling different apps never contend on each other's
//! segment or compaction locks.

use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;

use super::file_backend::{
    clear_dir_files, fresh_segment_name, is_store_file, lock_path, scan_dir,
    CompactGuard, FileBackend, StoredRep, INDEX_FILE, LEGACY_INDEX_FILE,
};
use super::key::StoreKey;
use super::memory_backend::MemoryBackend;
use super::{codec, StoreBackend, StoreStats};
use crate::apps::AppId;
use crate::mr::RepOutcome;

/// Shard count for stores that have never pinned one (no `shards.meta`,
/// no `--store-shards`, no `MRTUNER_STORE_SHARDS`).
pub const DEFAULT_STORE_SHARDS: usize = 4;

/// Upper bound on the shard count — beyond this, per-shard cap slices
/// and directory fan-out stop paying for themselves.
const MAX_STORE_SHARDS: usize = 64;

/// Marker file pinning the shard count for the store's lifetime.
const SHARDS_META_FILE: &str = "shards.meta";

/// How a [`ProfileStore`] is opened.
#[derive(Clone, Copy, Debug)]
pub struct StoreOptions {
    /// Total size cap in bytes, divided evenly across shards and
    /// enforced by per-shard compaction (LRU eviction, paper-plane
    /// records pinned).  `None` = unbounded.
    pub cap_bytes: Option<u64>,
    /// Requested shard count.  An existing `shards.meta` always wins —
    /// the on-disk layout is already laid out — with a note when they
    /// disagree.  `None` = `MRTUNER_STORE_SHARDS`, else what the
    /// directory layout implies, else [`DEFAULT_STORE_SHARDS`].
    pub shards: Option<usize>,
    /// Inspection mode (`peek`): never compact, never migrate, never
    /// write `shards.meta`.  Puts are still accepted (a peeking session
    /// that simulates may flush its own segments); only rewriting of
    /// *other* sessions' files is off-limits.
    pub read_only: bool,
    /// Spawn the background compaction thread (one pass, shard by
    /// shard, joined on drop).  Turn off for latency-controlled opens
    /// (benches) or when compaction runs explicitly
    /// ([`ProfileStore::compact_now`]).
    pub background_compaction: bool,
}

impl Default for StoreOptions {
    fn default() -> StoreOptions {
        StoreOptions {
            cap_bytes: None,
            shards: None,
            read_only: false,
            background_compaction: true,
        }
    }
}

/// Cross-shard change journal: the facade-level acceptance log that
/// gives consumers ([`crate::coordinator::Trainer`], resume diffing)
/// one monotonic generation over all shards.
struct Journal {
    /// Keys in facade acceptance order.  `keys.len()` is the store's
    /// generation; outcomes resolve through the owning shard at read
    /// time, so an evicted key simply stops resolving.
    keys: Vec<StoreKey>,
    /// Per-shard backend generation up to which `keys` is current.
    cursors: Vec<u64>,
}

/// Persistent, sharded profile store — see the [module
/// docs](super) for the layout and invariants.
///
/// ```
/// # use mrtuner::profiler::store::{ProfileStore, StoreKey};
/// # use mrtuner::mr::RepOutcome;
/// # use mrtuner::apps::AppId;
/// # let dir = std::env::temp_dir().join(format!("mrtuner_doc_store_{}", std::process::id()));
/// # let _ = std::fs::remove_dir_all(&dir);
/// let key = StoreKey {
///     cluster: 0xABCD, app: AppId::WordCount,
///     num_mappers: 20, num_reducers: 5,
///     input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
///     block_mb: StoreKey::PAPER_BLOCK_MB,
///     rep: 0, base_seed: 42,
/// };
/// {
///     let store = ProfileStore::open(&dir).unwrap();
///     store.put(key, RepOutcome::full(1523.25, 96.5));
///     store.flush().unwrap();
/// }   // drop joins the compactor and flushes
///
/// let store = ProfileStore::open(&dir).unwrap();
/// assert_eq!(store.get(&key), Some(RepOutcome::full(1523.25, 96.5)));
/// # drop(store);
/// # let _ = std::fs::remove_dir_all(&dir);
/// ```
pub struct ProfileStore {
    /// Store root (empty for memory-backed stores).  The DLQ and
    /// cooperative leases live directly under it, outside any shard.
    dir: PathBuf,
    shards: Vec<Arc<dyn StoreBackend>>,
    journal: Mutex<Journal>,
    /// What opening saw: legacy-migration tallies, root-scan corruption
    /// counts.  Folded into [`ProfileStore::stats`].
    open_stats: StoreStats,
    stop: Arc<AtomicBool>,
    compactor: Mutex<Option<JoinHandle<()>>>,
}

impl ProfileStore {
    /// Open (creating if needed) the store under `dir` with defaults:
    /// unbounded, background compaction on.
    pub fn open(dir: &Path) -> Result<ProfileStore, String> {
        ProfileStore::open_with_opts(dir, StoreOptions::default())
    }

    /// Open with a total size cap in bytes (`None` = unbounded).
    pub fn open_capped(
        dir: &Path,
        cap_bytes: Option<u64>,
    ) -> Result<ProfileStore, String> {
        ProfileStore::open_with_opts(
            dir,
            StoreOptions { cap_bytes, ..StoreOptions::default() },
        )
    }

    /// Open for inspection: no compaction, no migration, no meta write —
    /// a peeking session never rewrites files under other sessions.
    pub fn peek(dir: &Path) -> Result<ProfileStore, String> {
        ProfileStore::open_with_opts(
            dir,
            StoreOptions {
                read_only: true,
                background_compaction: false,
                ..StoreOptions::default()
            },
        )
    }

    /// A store with no disk underneath ([`MemoryBackend`] shards):
    /// read-through/write-back semantics for ephemeral campaigns and
    /// tests, leaving no files behind.  `flush` is a no-op and nothing
    /// survives the process.
    pub fn memory() -> ProfileStore {
        let shards: Vec<Arc<dyn StoreBackend>> = (0..DEFAULT_STORE_SHARDS)
            .map(|_| {
                Arc::new(MemoryBackend::new(None)) as Arc<dyn StoreBackend>
            })
            .collect();
        let cursors = vec![0; shards.len()];
        ProfileStore {
            dir: PathBuf::new(),
            shards,
            journal: Mutex::new(Journal { keys: Vec::new(), cursors }),
            open_stats: StoreStats::default(),
            stop: Arc::new(AtomicBool::new(false)),
            compactor: Mutex::new(None),
        }
    }

    /// The fully explicit open everything above delegates to.
    pub fn open_with_opts(
        dir: &Path,
        opts: StoreOptions,
    ) -> Result<ProfileStore, String> {
        let n = resolve_shard_count(dir, &opts);
        if !opts.read_only {
            fs::create_dir_all(dir).map_err(|e| {
                format!("store: create dir {}: {e}", dir.display())
            })?;
            pin_shard_count(dir, n);
        }
        // Even split; a cap below one byte per shard still caps at 1 so
        // eviction pressure is never silently dropped.
        let shard_cap = opts.cap_bytes.map(|c| (c / n as u64).max(1));
        let files: Vec<Arc<FileBackend>> = (0..n)
            .map(|i| {
                Arc::new(FileBackend::new(
                    &shard_dir(dir, i),
                    shard_cap,
                    !opts.read_only,
                ))
            })
            .collect();
        let mut open_stats =
            migrate_legacy_root(dir, &files, opts.read_only);
        // Migration tallies are about what the *open* did; live counts
        // come from the shards.
        open_stats.entries = 0;
        open_stats.bytes = 0;
        open_stats.pending = 0;

        let stop = Arc::new(AtomicBool::new(false));
        let compactor = if opts.background_compaction && !opts.read_only {
            let thread_shards = files.clone();
            let thread_stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("store-compact".to_string())
                .spawn(move || {
                    // One incremental pass: shard at a time, cheap
                    // needs-work probe first, compact.lock arbitrates
                    // with other processes.
                    for b in thread_shards {
                        if thread_stop.load(Ordering::Relaxed) {
                            return;
                        }
                        if !b.needs_compaction() {
                            continue;
                        }
                        if let Err(e) = b.compact() {
                            eprintln!(
                                "store: background compaction of {}: {e}",
                                b.dir().display()
                            );
                        }
                    }
                })
                .ok()
        } else {
            None
        };

        let shards: Vec<Arc<dyn StoreBackend>> = files
            .into_iter()
            .map(|f| f as Arc<dyn StoreBackend>)
            .collect();
        let cursors = vec![0; shards.len()];
        Ok(ProfileStore {
            dir: dir.to_path_buf(),
            shards,
            journal: Mutex::new(Journal { keys: Vec::new(), cursors }),
            open_stats,
            stop,
            compactor: Mutex::new(compactor),
        })
    }

    /// Store root directory.  Empty for memory-backed stores; the DLQ
    /// and cooperative leases are rooted here, never inside a shard.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of shards behind this store.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard_for(&self, key: &StoreKey) -> usize {
        shard_of(key.app, self.shards.len())
    }

    /// Shard `i`'s backend.  Every internal index is produced by
    /// [`ProfileStore::shard_for`] or ranges over `0..shards.len()`.
    fn shard(&self, i: usize) -> &Arc<dyn StoreBackend> {
        // mrlint: allow(panic_free) — i comes from shard_for (idx % shards.len()) or 0..len
        &self.shards[i]
    }

    /// Lock the facade journal, recovering from poison — the journal is
    /// a cursor cache over the shards' own journals, so the worst a
    /// poisoned update can leave behind is a stale cursor, which the
    /// next `pull` re-reads.
    fn lock_journal(&self) -> MutexGuard<'_, Journal> {
        match self.journal.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                self.journal.clear_poison();
                poisoned.into_inner()
            }
        }
    }

    /// Drain shard `i`'s backend journal into the facade journal.
    /// Lock order is always facade-journal **then** shard — every shard
    /// call that itself locks shard state happens while we hold the
    /// journal lock, and no shard ever calls back into the facade.
    fn pull(&self, i: usize) -> u64 {
        let mut journal = self.lock_journal();
        let cursor = journal.cursors.get(i).copied().unwrap_or(0);
        let (records, generation) = self.shard(i).read_since(cursor);
        if let Some(c) = journal.cursors.get_mut(i) {
            *c = generation;
        }
        let fresh = records.len() as u64;
        journal.keys.extend(records.into_iter().map(|(k, _)| k));
        fresh
    }

    fn pull_all(&self) -> u64 {
        (0..self.shards.len()).map(|i| self.pull(i)).sum()
    }

    /// Stored outcome for `key`, if any prior session simulated it (a
    /// hit bumps the record's LRU recency).
    pub fn get(&self, key: &StoreKey) -> Option<RepOutcome> {
        let i = self.shard_for(key);
        let out = self.shard(i).get(key);
        // First touch lazily loads the shard; surface what it found.
        self.pull(i);
        out
    }

    /// Record a freshly simulated outcome; returns whether the store's
    /// generation advanced (new key or CPU upgrade — not a re-put).
    pub fn put(&self, key: StoreKey, outcome: RepOutcome) -> bool {
        let i = self.shard_for(key);
        let journaled = self.shard(i).put(key, outcome);
        self.pull(i);
        journaled
    }

    /// Persist buffered records in every touched shard.  Shards this
    /// session never accessed are left untouched (no lazy load).
    pub fn flush(&self) -> Result<(), String> {
        for shard in &self.shards {
            shard.flush()?;
        }
        Ok(())
    }

    /// Monotonic change counter across all shards: records found on
    /// disk plus every later insertion.  Forces all shards to load.
    pub fn generation(&self) -> u64 {
        self.pull_all();
        self.lock_journal().keys.len() as u64
    }

    /// Every record accepted after `generation`, plus the new
    /// generation to pass back next time.  An upsert log: keys repeat
    /// on in-place upgrade, and a key evicted since it was journaled is
    /// skipped.
    pub fn read_since(
        &self,
        generation: u64,
    ) -> (Vec<(StoreKey, RepOutcome)>, u64) {
        self.pull_all();
        let journal = self.lock_journal();
        let from = (generation as usize).min(journal.keys.len());
        let records = journal
            .keys
            .get(from..)
            .unwrap_or_default()
            .iter()
            .filter_map(|k| {
                // lookup, not get: replaying the journal is not a use
                // and must not distort LRU recency.
                self.shard(self.shard_for(k)).lookup(k).map(|o| (*k, o))
            })
            .collect();
        (records, journal.keys.len() as u64)
    }

    /// Fold in records written by other sessions since the last poll,
    /// returning how many were new to this store instance.  The first
    /// call on a lazily opened store also counts what was already on
    /// disk.
    pub fn refresh(&self) -> Result<u64, String> {
        let mut fresh = 0;
        for (i, shard) in self.shards.iter().enumerate() {
            shard.refresh()?;
            fresh += self.pull(i);
        }
        Ok(fresh)
    }

    /// Run one full compaction pass over every shard **now**, on this
    /// thread, and return the merged pass stats.  This is the CLI
    /// `store compact` path; campaigns rely on the background thread
    /// instead.
    pub fn compact_now(&self) -> Result<StoreStats, String> {
        let mut total = StoreStats::default();
        for (i, shard) in self.shards.iter().enumerate() {
            total.absorb(&shard.compact()?);
            // Compaction may have surfaced other sessions' records.
            self.pull(i);
        }
        total.entries = self.len();
        Ok(total)
    }

    /// Combined stats: what opening saw (migration tallies) plus every
    /// shard's cumulative counters.  Forces all shards to load.
    pub fn stats(&self) -> StoreStats {
        let mut total = self.open_stats;
        for shard in &self.shards {
            total.absorb(&shard.stats());
        }
        total
    }

    /// Per-shard stats snapshots, indexed by shard.  Forces all shards
    /// to load.
    pub fn shard_stats(&self) -> Vec<StoreStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }

    /// Distinct records resident across all shards (forces loads).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    /// Whether no shard holds any record (forces loads).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records buffered but not yet persisted, across all shards.
    /// Never forces a lazy load (an untouched shard has nothing
    /// pending).
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.pending()).sum()
    }

    /// Delete the store under `dir` — shard directories, legacy root
    /// files, locks, temp debris, and the shard-count marker — and
    /// return how many files were removed.  DLQ files and the `leases/`
    /// directory are *not* store data and are left alone.  A missing
    /// directory is an empty store.
    pub fn clear(dir: &Path) -> Result<usize, String> {
        let mut removed = clear_dir_files(dir)?;
        for sdir in shard_dirs_present(dir) {
            removed += clear_dir_files(&sdir)?;
            // Only if nothing foreign was left inside.
            let _ = fs::remove_dir(&sdir);
        }
        if fs::remove_file(dir.join(SHARDS_META_FILE)).is_ok() {
            removed += 1;
        }
        Ok(removed)
    }
}

impl Drop for ProfileStore {
    fn drop(&mut self) {
        // Stop-flag then join: a mid-pass compactor finishes its current
        // shard and exits before the backends start flushing.
        self.stop.store(true, Ordering::Relaxed);
        if let Ok(mut h) = self.compactor.lock() {
            if let Some(handle) = h.take() {
                let _ = handle.join();
            }
        }
        if let Err(e) = self.flush() {
            eprintln!("store: flush on drop failed: {e}");
        }
    }
}

// ------------------------------------------------ routing and layout

/// Stable shard index for an application: FNV-1a over the app name,
/// modulo the shard count.  Depends on nothing but the name and `n`, so
/// a key's shard never moves between opens, processes, or builds.
pub(crate) fn shard_of(app: AppId, n: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in app.name().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % n.max(1) as u64) as usize
}

/// Directory of shard `i` under the store root.
pub(crate) fn shard_dir(root: &Path, i: usize) -> PathBuf {
    root.join(format!("shard-{i:02}"))
}

/// Existing `shard-NN` directories under `root`, sorted.
fn shard_dirs_present(root: &Path) -> Vec<PathBuf> {
    let Ok(rd) = fs::read_dir(root) else {
        return Vec::new();
    };
    let mut out: Vec<PathBuf> = rd
        .flatten()
        .filter(|e| {
            let name = e.file_name();
            let name = name.to_string_lossy().into_owned();
            name.strip_prefix("shard-").is_some_and(|digits| {
                digits.len() == 2
                    && digits.bytes().all(|b| b.is_ascii_digit())
            }) && e.path().is_dir()
        })
        .map(|e| e.path())
        .collect();
    out.sort();
    out
}

/// Decide the shard count for this open.  Precedence: an existing
/// `shards.meta` (the layout on disk is authoritative — a conflicting
/// request gets a note, not a reshard), then the explicit option
/// (`--store-shards`), then `MRTUNER_STORE_SHARDS`, then whatever the
/// existing `shard-NN` directories imply, then the default.
fn resolve_shard_count(dir: &Path, opts: &StoreOptions) -> usize {
    if let Some(n) = read_shard_meta(dir) {
        if let Some(asked) = opts.shards {
            if asked != n {
                eprintln!(
                    "store: {} pins {n} shard(s); ignoring request for \
                     {asked}",
                    dir.join(SHARDS_META_FILE).display()
                );
            }
        }
        return n;
    }
    let requested = opts.shards.or_else(|| {
        std::env::var("MRTUNER_STORE_SHARDS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
    });
    if let Some(n) = requested {
        let clamped = n.clamp(1, MAX_STORE_SHARDS);
        if clamped != n {
            eprintln!(
                "store: shard count {n} out of range; using {clamped}"
            );
        }
        return clamped;
    }
    // Meta-less sharded layout (e.g. created by an inspection session):
    // the highest shard directory present implies the count.
    let dirs = shard_dirs_present(dir);
    if let Some(last) = dirs.last() {
        let name = last.file_name().unwrap_or_default().to_string_lossy();
        if let Some(digits) = name.strip_prefix("shard-") {
            if let Ok(i) = digits.parse::<usize>() {
                return (i + 1).clamp(1, MAX_STORE_SHARDS);
            }
        }
    }
    DEFAULT_STORE_SHARDS
}

fn read_shard_meta(dir: &Path) -> Option<usize> {
    let text = fs::read_to_string(dir.join(SHARDS_META_FILE)).ok()?;
    let n = parse_shard_meta(&text)?;
    if (1..=MAX_STORE_SHARDS).contains(&n) {
        Some(n)
    } else {
        eprintln!(
            "store: ignoring {} with out-of-range shard count {n}",
            dir.join(SHARDS_META_FILE).display()
        );
        None
    }
}

fn parse_shard_meta(text: &str) -> Option<usize> {
    let rest = text.split("\"shards\"").nth(1)?;
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let digits: String =
        rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// Pin the shard count on disk, first writer wins (`create_new`): two
/// concurrent first opens with different requests converge on whichever
/// meta landed, because every later resolution reads it back.
fn pin_shard_count(dir: &Path, n: usize) {
    let path = dir.join(SHARDS_META_FILE);
    match OpenOptions::new().write(true).create_new(true).open(&path) {
        Ok(mut f) => {
            let _ = write!(f, "{{\"v\":1,\"shards\":{n}}}");
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {}
        Err(e) => {
            eprintln!("store: write {}: {e}", path.display())
        }
    }
}

// ------------------------------------------- legacy-layout migration

/// Migrate a legacy **single-directory** store (PR 2-5 layout: index
/// and segments directly under the root) into the shard directories.
///
/// The happy path — compaction lock acquired, root index readable —
/// rewrites every root record into one migration segment per owning
/// shard (v3 frames, key-sorted, touches preserved: `get()` through the
/// shards is byte-identical to the legacy store), then deletes the root
/// index and every unlocked root segment.  Root segments held by a
/// live writer (an old, pre-sharding build still running) are read but
/// left in place; the next compacting open migrates them once the
/// writer is gone.
///
/// When migration must not write — inspection opens, the migration lock
/// busy in another process, or an unreadable root index — the root
/// records are instead *preloaded* into the shard backends: visible to
/// this session, nothing on disk touched.
///
/// Returns the tallies of whatever was done (migrated line counts,
/// corruption seen, `compacted` set when the layout was rewritten).
fn migrate_legacy_root(
    root: &Path,
    shards: &[Arc<FileBackend>],
    read_only: bool,
) -> StoreStats {
    if !legacy_root_present(root) {
        return StoreStats::default();
    }
    let n = shards.len();
    // Writable path: take the root compact.lock so two migrating opens
    // never double-write, and an old build's compaction never runs
    // mid-migration.
    let guard = if read_only { None } else { CompactGuard::acquire(root) };
    let scan = match scan_dir(root) {
        Ok(scan) => scan,
        Err(e) => {
            eprintln!(
                "store: cannot read legacy store at {}: {e}; continuing \
                 with shards only",
                root.display()
            );
            return StoreStats {
                corrupt_segments: 1,
                ..StoreStats::default()
            };
        }
    };
    let mut stats = scan.stats;
    let can_rewrite = guard.is_some() && !scan.index_unreadable;
    let mut by_shard: Vec<Vec<(StoreKey, StoredRep)>> =
        (0..n).map(|_| Vec::new()).collect();
    for (key, rep) in scan.entries {
        if let Some(bucket) = by_shard.get_mut(shard_of(key.app, n)) {
            bucket.push((key, rep));
        }
    }
    if !can_rewrite {
        if !read_only && guard.is_none() {
            eprintln!(
                "store: legacy migration lock busy at {}; serving legacy \
                 records without rewriting",
                root.display()
            );
        }
        if scan.index_unreadable {
            eprintln!(
                "store: legacy index at {} unreadable; serving what was \
                 recovered, leaving files for manual repair",
                root.display()
            );
        }
        for (shard, records) in shards.iter().zip(by_shard) {
            if !records.is_empty() {
                shard.preload(records);
            }
        }
        return stats;
    }
    // Write one v3 migration segment per populated shard, then retire
    // the root files it replaces.  Written via temp + rename so a crash
    // can never leave a half-written file with a valid segment name.
    let mut wrote = 0;
    for (i, (shard, mut records)) in
        shards.iter().zip(by_shard).enumerate()
    {
        if records.is_empty() {
            continue;
        }
        records.sort_by(|a, b| a.0.cmp(&b.0));
        let sdir = shard_dir(root, i);
        if let Err(e) = fs::create_dir_all(&sdir) {
            eprintln!("store: create {}: {e}; migration aborted", sdir.display());
            shard.preload(records);
            continue;
        }
        let mut body = codec::bin_header().to_vec();
        for (key, sr) in &records {
            codec::encode_record_bin_into(
                key,
                &sr.outcome,
                sr.touch,
                &mut body,
            );
        }
        let tmp = sdir.join(format!("mig-{}.tmp", std::process::id()));
        let write = fs::write(&tmp, &body)
            .and_then(|()| fs::rename(&tmp, sdir.join(fresh_segment_name())));
        match write {
            Ok(()) => wrote += 1,
            Err(e) => {
                eprintln!(
                    "store: migration write into {} failed: {e}; serving \
                     legacy records in place",
                    sdir.display()
                );
                let _ = fs::remove_file(&tmp);
                shard.preload(records);
            }
        }
    }
    if wrote > 0 {
        // The shard segments now own these records; retire the legacy
        // layout (everything a live writer does not still hold).
        for path in &scan.mergeable {
            let _ = fs::remove_file(path);
            let _ = fs::remove_file(lock_path(path));
        }
        let _ = fs::remove_file(root.join(INDEX_FILE));
        let _ = fs::remove_file(root.join(LEGACY_INDEX_FILE));
        stats.compacted = true;
        stats.merged_segments = scan.mergeable.len();
        eprintln!(
            "store: migrated legacy single-directory store at {} into {n} \
             shard(s)",
            root.display()
        );
    }
    stats
}

/// Whether `root` still holds a legacy single-directory store: an index
/// or any segment file directly at the root (shard data lives one level
/// down; the DLQ's `dlq-*.bin` files do not match).
fn legacy_root_present(root: &Path) -> bool {
    let Ok(rd) = fs::read_dir(root) else {
        return false;
    };
    rd.flatten().any(|e| {
        is_store_file(&e.file_name().to_string_lossy())
            && e.path().is_file()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppId;

    fn key(app: AppId, m: u32, r: u32, rep: u32, seed: u64) -> StoreKey {
        StoreKey {
            cluster: 0xDEAD_BEEF_0BAD_F00D,
            app,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
            block_mb: StoreKey::PAPER_BLOCK_MB,
            rep,
            base_seed: seed,
        }
    }

    fn ext4_key(i: u32) -> StoreKey {
        StoreKey {
            cluster: 0xDEAD_BEEF_0BAD_F00D,
            app: AppId::WordCount,
            num_mappers: 5 + i,
            num_reducers: 7,
            input_gb_bits: (2.0f64).to_bits(),
            block_mb: 128,
            rep: 0,
            base_seed: 1,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "mrtuner_sharded_unit_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn put_get_flush_reopen_across_shards() {
        let dir = tmp_dir("roundtrip");
        {
            let store = ProfileStore::open(&dir).unwrap();
            assert!(store.is_empty());
            for app in [AppId::WordCount, AppId::EximParse, AppId::Grep] {
                store.put(key(app, 20, 5, 0, 42), RepOutcome::full(100.5, 1.25));
            }
            assert_eq!(store.pending(), 3);
            store.flush().unwrap();
            assert_eq!(store.pending(), 0);
        }
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.len(), 3);
        for app in [AppId::WordCount, AppId::EximParse, AppId::Grep] {
            assert_eq!(
                store.get(&key(app, 20, 5, 0, 42)),
                Some(RepOutcome::full(100.5, 1.25)),
                "{app:?} survives reopen"
            );
        }
        drop(store);
        assert!(ProfileStore::clear(&dir).unwrap() >= 1);
        let store = ProfileStore::peek(&dir).unwrap();
        assert!(store.is_empty(), "clear removed every shard");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_routing_is_stable_and_meta_pinned() {
        // Pure-function stability: same app, same n, same shard.
        for app in [AppId::WordCount, AppId::EximParse, AppId::Grep] {
            assert_eq!(shard_of(app, 4), shard_of(app, 4));
            assert!(shard_of(app, 4) < 4);
            assert_eq!(shard_of(app, 1), 0);
        }
        let dir = tmp_dir("meta");
        {
            let store = ProfileStore::open_with_opts(
                &dir,
                StoreOptions { shards: Some(2), ..StoreOptions::default() },
            )
            .unwrap();
            assert_eq!(store.shard_count(), 2);
            store.put(key(AppId::Grep, 4, 2, 0, 7), RepOutcome::time_only(9.0));
            store.flush().unwrap();
        }
        // A later open asking for 8 shards is overruled by the meta: the
        // record must stay findable.
        let store = ProfileStore::open_with_opts(
            &dir,
            StoreOptions { shards: Some(8), ..StoreOptions::default() },
        )
        .unwrap();
        assert_eq!(store.shard_count(), 2, "shards.meta wins");
        assert_eq!(
            store.get(&key(AppId::Grep, 4, 2, 0, 7)),
            Some(RepOutcome::time_only(9.0))
        );
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn generation_counts_disk_and_live_insertions() {
        let dir = tmp_dir("generation");
        {
            let store = ProfileStore::open(&dir).unwrap();
            assert_eq!(store.generation(), 0);
            store.put(key(AppId::WordCount, 20, 5, 0, 1), RepOutcome::full(100.0, 1.0));
            store.put(key(AppId::EximParse, 20, 5, 1, 1), RepOutcome::full(101.0, 2.0));
            assert_eq!(store.generation(), 2);
            // Re-putting a known value is not a change.
            store.put(key(AppId::WordCount, 20, 5, 0, 1), RepOutcome::full(100.0, 1.0));
            assert_eq!(store.generation(), 2);
            store.flush().unwrap();
        }
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.generation(), 2, "disk records count");
        let (all, generation) = store.read_since(0);
        assert_eq!(all.len(), 2);
        let (fresh, g2) = store.read_since(generation);
        assert!(fresh.is_empty());
        assert_eq!(g2, generation);
        store.put(key(AppId::Grep, 30, 5, 0, 1), RepOutcome::full(200.0, 3.0));
        let (fresh, g3) = store.read_since(generation);
        assert_eq!(fresh.len(), 1);
        assert_eq!(g3, generation + 1);
        assert!(store.read_since(u64::MAX).0.is_empty());
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_picks_up_other_sessions_records() {
        let dir = tmp_dir("refresh");
        let reader = ProfileStore::open(&dir).unwrap();
        // Force the shards to load *before* the writer writes, so the
        // later pickup is genuinely refresh's doing, not lazy loading's.
        assert_eq!(reader.generation(), 0);
        {
            let writer = ProfileStore::open(&dir).unwrap();
            writer.put(
                key(AppId::WordCount, 10, 10, 0, 9),
                RepOutcome::full(55.0, 5.0),
            );
            writer.flush().unwrap();
        }
        assert!(
            reader.get(&key(AppId::WordCount, 10, 10, 0, 9)).is_none(),
            "not visible before refresh"
        );
        assert_eq!(reader.refresh().unwrap(), 1);
        assert_eq!(
            reader.get(&key(AppId::WordCount, 10, 10, 0, 9)),
            Some(RepOutcome::full(55.0, 5.0))
        );
        assert_eq!(reader.refresh().unwrap(), 0, "idempotent");
        drop(reader);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_respects_cap_and_pins_paper_plane() {
        let dir = tmp_dir("evict");
        {
            let store = ProfileStore::open(&dir).unwrap();
            for rep in 0..3 {
                store.put(
                    key(AppId::WordCount, 20, 5, rep, 1),
                    RepOutcome::full(100.0 + rep as f64, 1.0),
                );
            }
            for i in 0..50 {
                store.put(ext4_key(i), RepOutcome::full(10.0 + i as f64, 0.5));
            }
            store.flush().unwrap();
        }
        let store = ProfileStore::open_with_opts(
            &dir,
            StoreOptions {
                cap_bytes: Some(2048),
                background_compaction: false,
                ..StoreOptions::default()
            },
        )
        .unwrap();
        let pass = store.compact_now().unwrap();
        assert!(pass.compacted && pass.evicted > 0, "cap enforced: {pass}");
        for rep in 0..3 {
            assert!(
                store.get(&key(AppId::WordCount, 20, 5, rep, 1)).is_some(),
                "paper-plane rep {rep} pinned"
            );
        }
        assert!(store.get(&ext4_key(0)).is_none(), "coldest evicted");
        drop(store);
        // Eviction is durable: an uncapped reopen does not resurrect.
        let store = ProfileStore::open(&dir).unwrap();
        assert!(store.get(&ext4_key(0)).is_none());
        assert!(store.get(&key(AppId::WordCount, 20, 5, 0, 1)).is_some());
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn capped_open_without_pressure_evicts_nothing() {
        let dir = tmp_dir("nopressure");
        {
            let store = ProfileStore::open(&dir).unwrap();
            for i in 0..10 {
                store.put(ext4_key(i), RepOutcome::full(1.0 + i as f64, 0.1));
            }
            store.flush().unwrap();
        }
        let store =
            ProfileStore::open_capped(&dir, Some(1024 * 1024)).unwrap();
        assert_eq!(store.compact_now().unwrap().evicted, 0);
        assert_eq!(store.len(), 10);
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_dir_store_migrates_bit_identically() {
        let dir = tmp_dir("migrate_layout");
        // Build a legacy store the only way that exists now: write v3
        // files directly at the root, exactly as the pre-sharding build
        // laid them out.
        std::fs::create_dir_all(&dir).unwrap();
        let mut keys = Vec::new();
        let mut body = codec::bin_header().to_vec();
        for app in [AppId::WordCount, AppId::EximParse, AppId::Grep] {
            for rep in 0..4 {
                let k = key(app, 20, 5, rep, 11);
                let o = RepOutcome::full(
                    1000.0 + rep as f64 + 0.125,
                    9.5 + rep as f64,
                );
                codec::encode_record_bin_into(&k, &o, rep as u64, &mut body);
                keys.push((k, o));
            }
        }
        std::fs::write(dir.join(INDEX_FILE), &body).unwrap();
        {
            let store = ProfileStore::open(&dir).unwrap();
            let st = store.stats();
            assert!(st.compacted, "layout migration ran: {st}");
            for (k, o) in &keys {
                assert_eq!(store.get(k), Some(*o), "bit-identical get");
            }
        }
        assert!(
            !dir.join(INDEX_FILE).exists(),
            "legacy root index retired"
        );
        assert!(!shard_dirs_present(&dir).is_empty());
        // Reopen: migration is one-time, records still served.
        let store = ProfileStore::open(&dir).unwrap();
        assert!(!store.stats().compacted || store.stats().merged_segments > 0);
        for (k, o) in &keys {
            assert_eq!(store.get(k), Some(*o));
        }
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn peek_of_legacy_store_reads_without_rewriting() {
        let dir = tmp_dir("peek_legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let k = key(AppId::WordCount, 20, 5, 0, 3);
        let o = RepOutcome::full(77.0, 7.0);
        let mut body = codec::bin_header().to_vec();
        codec::encode_record_bin_into(&k, &o, 5, &mut body);
        std::fs::write(dir.join(INDEX_FILE), &body).unwrap();
        let before = std::fs::read(dir.join(INDEX_FILE)).unwrap();
        {
            let store = ProfileStore::peek(&dir).unwrap();
            assert_eq!(store.get(&k), Some(o), "legacy records visible");
        }
        assert_eq!(
            std::fs::read(dir.join(INDEX_FILE)).unwrap(),
            before,
            "peek rewrote nothing"
        );
        assert!(
            !dir.join(SHARDS_META_FILE).exists(),
            "peek pins no shard count"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn memory_store_round_trips_without_files() {
        let store = ProfileStore::memory();
        let k = key(AppId::EximParse, 8, 4, 0, 5);
        assert!(store.put(k, RepOutcome::full(12.0, 1.5)));
        assert_eq!(store.get(&k), Some(RepOutcome::full(12.0, 1.5)));
        assert_eq!(store.pending(), 0);
        store.flush().unwrap();
        assert_eq!(store.generation(), 1);
        let (records, g) = store.read_since(0);
        assert_eq!((records.len(), g), (1, 1));
        assert_eq!(store.refresh().unwrap(), 0);
        assert!(store.dir().as_os_str().is_empty());
        assert_eq!(store.shard_count(), DEFAULT_STORE_SHARDS);
    }

    #[test]
    fn shard_meta_parses_and_survives_garbage() {
        assert_eq!(parse_shard_meta("{\"v\":1,\"shards\":4}"), Some(4));
        assert_eq!(parse_shard_meta("{ \"shards\" : 16 }"), Some(16));
        assert_eq!(parse_shard_meta("{\"v\":1}"), None);
        assert_eq!(parse_shard_meta("garbage"), None);
    }
}
