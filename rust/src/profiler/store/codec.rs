//! Record codecs: the binary v4 frame format every store file is
//! written in (v3 payloads decode natively with bytes absent), and the
//! legacy JSONL (v1/v2) codec migrated on read.

use std::fs;
use std::path::Path;

use super::key::{RecordError, StoreKey};
use super::STORE_FORMAT_VERSION;
use crate::apps::AppId;
use crate::mr::{RepBytes, RepOutcome};
use crate::util::bytes::{hex_u64, parse_hex_u64};
use crate::util::json::{parse, Json};

/// Version written by the legacy JSONL record codec ([`encode_record`]).
pub(crate) const JSONL_RECORD_VERSION: u32 = 2;

/// Magic prefix of every binary (v3/v4) store file.
pub(crate) const BIN_MAGIC: [u8; 4] = *b"MRTS";
/// Binary file header: magic + little-endian u32 format version.
pub(crate) const BIN_HEADER_LEN: usize = 8;
/// Sanity bound on a record's length prefix; anything larger is framing
/// corruption (a real record is well under 128 bytes).
pub(crate) const MAX_RECORD_LEN: usize = 4096;

// ------------------------------------------------- legacy JSONL codec

/// Serialize one `(key, per-rep outcome)` record as a **legacy v2 JSON
/// line** — the format PR 2/PR 3 builds wrote.  Kept for store-upgrade
/// tests and tooling; the store itself writes the binary v3 codec
/// ([`encode_record_bin`]) since PR 5.
pub fn encode_record(key: &StoreKey, outcome: &RepOutcome) -> String {
    // "t"/"cpu" are redundant human-readable copies; the hex "bits"
    // fields are authoritative.  "cbits"/"cpu" are omitted when the CPU
    // figure is unknown (v1-migrated data).
    let mut pairs = vec![
        ("v", Json::Num(JSONL_RECORD_VERSION as f64)),
        ("cluster", Json::Str(hex_u64(key.cluster))),
        ("app", Json::Str(key.app.name().to_string())),
        ("m", Json::Num(key.num_mappers as f64)),
        ("r", Json::Num(key.num_reducers as f64)),
        ("igb", Json::Str(hex_u64(key.input_gb_bits))),
        ("blk", Json::Num(key.block_mb as f64)),
        ("rep", Json::Num(key.rep as f64)),
        ("seed", Json::Str(hex_u64(key.base_seed))),
        ("bits", Json::Str(hex_u64(outcome.time_s.to_bits()))),
        ("t", Json::Num(outcome.time_s)),
    ];
    if let Some(cpu) = outcome.cpu_s {
        pairs.push(("cbits", Json::Str(hex_u64(cpu.to_bits()))));
        pairs.push(("cpu", Json::Num(cpu)));
    }
    Json::obj(pairs).to_string()
}

/// Decode a legacy JSONL record line written by [`encode_record`] (v2)
/// or by the v1 store, returning the key, the outcome, and the version
/// the line was written under.
///
/// v1 lines are migrated on the fly: their key lands at the paper-default
/// input/block values (the only point v1 could describe) and the CPU
/// figure is absent — they are never orphaned, and compaction rewrites
/// them as v3 binary.
pub fn decode_record(
    line: &str,
) -> Result<(StoreKey, RepOutcome, u32), RecordError> {
    let v = parse(line).map_err(RecordError::Corrupt)?;
    let ver = v.req_u64("v").map_err(RecordError::Corrupt)?;
    let decode = |legacy_v1: bool| -> Result<(StoreKey, RepOutcome), String> {
        let (input_gb_bits, block_mb) = if legacy_v1 {
            (StoreKey::PAPER_INPUT_GB.to_bits(), StoreKey::PAPER_BLOCK_MB)
        } else {
            (parse_hex_u64(v.req_str("igb")?)?, v.req_u32("blk")?)
        };
        let key = StoreKey {
            cluster: parse_hex_u64(v.req_str("cluster")?)?,
            app: AppId::parse(v.req_str("app")?)?,
            num_mappers: v.req_u32("m")?,
            num_reducers: v.req_u32("r")?,
            input_gb_bits,
            block_mb,
            rep: v.req_u32("rep")?,
            base_seed: parse_hex_u64(v.req_str("seed")?)?,
        };
        let time_s = f64::from_bits(parse_hex_u64(v.req_str("bits")?)?);
        let cpu_s = match v.get("cbits") {
            None => None,
            Some(j) => Some(f64::from_bits(parse_hex_u64(
                j.as_str().ok_or("cbits: expected hex string")?,
            )?)),
        };
        // JSONL predates byte capture entirely; migrated records gain
        // their counters on first re-simulation.
        Ok((key, RepOutcome { time_s, cpu_s, bytes: None }))
    };
    match ver {
        2 => decode(false)
            .map(|(k, o)| (k, o, 2))
            .map_err(RecordError::Corrupt),
        1 => decode(true)
            .map(|(k, o)| (k, o, 1))
            .map_err(RecordError::Corrupt),
        other => Err(RecordError::StaleVersion(other)),
    }
}

// ------------------------------------------------------ binary v4 codec

/// Exact encoded payload size of one binary record (no length prefix).
pub(crate) fn payload_len(key: &StoreKey, outcome: &RepOutcome) -> usize {
    // 5 u64s + 4 u32s + app length byte + app name + cpu flag (+ cpu
    // bits) + bytes flag (+ shuffle/hdfs u64s)
    5 * 8
        + 4 * 4
        + 1
        + key.app.name().len()
        + 1
        + if outcome.cpu_s.is_some() { 8 } else { 0 }
        + 1
        + if outcome.bytes.is_some() { 16 } else { 0 }
}

/// Exact on-disk size of one framed binary record (length prefix
/// included) — what the size-cap accounting sums.
pub(crate) fn frame_len(key: &StoreKey, outcome: &RepOutcome) -> usize {
    4 + payload_len(key, outcome)
}

/// The 8-byte header every binary store file starts with.
pub(crate) fn bin_header() -> [u8; BIN_HEADER_LEN] {
    let [m0, m1, m2, m3] = BIN_MAGIC;
    let [v0, v1, v2, v3] = STORE_FORMAT_VERSION.to_le_bytes();
    [m0, m1, m2, m3, v0, v1, v2, v3]
}

/// Read a little-endian `u32` at byte offset `at`, if `bytes` is long
/// enough — the panic-free building block for header and frame parsing.
pub(crate) fn le_u32_at(bytes: &[u8], at: usize) -> Option<u32> {
    let end = at.checked_add(4)?;
    let src = bytes.get(at..end)?;
    let mut arr = [0u8; 4];
    for (dst, b) in arr.iter_mut().zip(src) {
        *dst = *b;
    }
    Some(u32::from_le_bytes(arr))
}

/// Append one framed binary record to `out`.
pub(crate) fn encode_record_bin_into(
    key: &StoreKey,
    outcome: &RepOutcome,
    touch: u64,
    out: &mut Vec<u8>,
) {
    let len = payload_len(key, outcome);
    debug_assert!(len <= MAX_RECORD_LEN);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    let start = out.len();
    out.extend_from_slice(&key.cluster.to_le_bytes());
    out.extend_from_slice(&key.base_seed.to_le_bytes());
    out.extend_from_slice(&key.input_gb_bits.to_le_bytes());
    out.extend_from_slice(&outcome.time_s.to_bits().to_le_bytes());
    out.extend_from_slice(&touch.to_le_bytes());
    out.extend_from_slice(&key.num_mappers.to_le_bytes());
    out.extend_from_slice(&key.num_reducers.to_le_bytes());
    out.extend_from_slice(&key.block_mb.to_le_bytes());
    out.extend_from_slice(&key.rep.to_le_bytes());
    let name = key.app.name().as_bytes();
    out.push(name.len() as u8);
    out.extend_from_slice(name);
    match outcome.cpu_s {
        Some(cpu) => {
            out.push(1);
            out.extend_from_slice(&cpu.to_bits().to_le_bytes());
        }
        None => out.push(0),
    }
    match outcome.bytes {
        Some(b) => {
            out.push(1);
            out.extend_from_slice(&b.shuffle.to_le_bytes());
            out.extend_from_slice(&b.hdfs.to_le_bytes());
        }
        None => out.push(0),
    }
    debug_assert_eq!(out.len() - start, len);
}

/// Serialize one record as a length-prefixed **binary v4** frame: the
/// format the store's segments and index are written in since PR 5
/// (byte counters since PR 10).
/// Every `u64`/`f64` is stored as raw little-endian bits, so arbitrary
/// bit patterns — NaN payloads included — round-trip exactly.  `touch`
/// is the record's last-hit generation (drives LRU eviction under a
/// size cap).
pub fn encode_record_bin(
    key: &StoreKey,
    outcome: &RepOutcome,
    touch: u64,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(frame_len(key, outcome));
    encode_record_bin_into(key, outcome, touch, &mut out);
    out
}

/// Bounds-checked little-endian reader over one binary payload.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .i
            .checked_add(n)
            .ok_or_else(|| "binary record truncated".to_string())?;
        let s = self
            .b
            .get(self.i..end)
            .ok_or_else(|| "binary record truncated".to_string())?;
        self.i = end;
        Ok(s)
    }

    /// `take(N)` copied into a fixed array, for `from_le_bytes`.
    fn array<const N: usize>(&mut self) -> Result<[u8; N], String> {
        let src = self.take(N)?;
        let mut arr = [0u8; N];
        for (dst, b) in arr.iter_mut().zip(src) {
            *dst = *b;
        }
        Ok(arr)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(u8::from_le_bytes(self.array()?))
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.array()?))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.array()?))
    }
}

/// Decode one binary payload (the bytes after a record's length prefix).
pub(crate) fn decode_payload(
    b: &[u8],
) -> Result<(StoreKey, RepOutcome, u64), String> {
    let mut c = Cursor { b, i: 0 };
    let cluster = c.u64()?;
    let base_seed = c.u64()?;
    let input_gb_bits = c.u64()?;
    let time_bits = c.u64()?;
    let touch = c.u64()?;
    let num_mappers = c.u32()?;
    let num_reducers = c.u32()?;
    let block_mb = c.u32()?;
    let rep = c.u32()?;
    let app_len = c.u8()? as usize;
    let app_bytes = c.take(app_len)?;
    let app = AppId::parse(
        std::str::from_utf8(app_bytes)
            .map_err(|_| "binary record: app name not UTF-8".to_string())?,
    )?;
    let cpu_s = match c.u8()? {
        0 => None,
        1 => Some(f64::from_bits(c.u64()?)),
        other => return Err(format!("binary record: bad cpu flag {other}")),
    };
    // A v3 payload ends here; v4 appends a bytes flag (+ counters).
    // Cursor-exhausted means a v3 record: decode with bytes absent — the
    // in-place migration path, no rewrite needed.
    let bytes = if c.i == b.len() {
        None
    } else {
        match c.u8()? {
            0 => None,
            1 => Some(RepBytes { shuffle: c.u64()?, hdfs: c.u64()? }),
            other => {
                return Err(format!("binary record: bad bytes flag {other}"))
            }
        }
    };
    if c.i != b.len() {
        return Err("binary record: trailing payload bytes".into());
    }
    Ok((
        StoreKey {
            cluster,
            app,
            num_mappers,
            num_reducers,
            input_gb_bits,
            block_mb,
            rep,
            base_seed,
        },
        RepOutcome { time_s: f64::from_bits(time_bits), cpu_s, bytes },
        touch,
    ))
}

/// Decode one framed binary record produced by [`encode_record_bin`]
/// from the front of `bytes`.  Returns the record, its touch generation,
/// and the total bytes consumed (prefix + payload), so callers can walk
/// a concatenated record stream.
pub fn decode_record_bin(
    bytes: &[u8],
) -> Result<(StoreKey, RepOutcome, u64, usize), String> {
    let Some(len) = le_u32_at(bytes, 0).map(|l| l as usize) else {
        return Err("binary record truncated (length prefix)".into());
    };
    if len == 0 || len > MAX_RECORD_LEN {
        return Err(format!("binary record: implausible length {len}"));
    }
    let end = 4 + len;
    let payload = bytes
        .get(4..end)
        .ok_or_else(|| "binary record truncated (payload)".to_string())?;
    let (key, outcome, touch) = decode_payload(payload)?;
    Ok((key, outcome, touch, end))
}

/// Strictly decode every record in one store file — binary v3 or legacy
/// JSONL — returning each record with the version it was stored under
/// (the file version for binary, the per-line `"v"` for JSONL).  Any
/// corruption is an error: this is the store-inspection/tooling path,
/// not the fault-tolerant load path.
pub fn read_file_records(
    path: &Path,
) -> Result<Vec<(StoreKey, RepOutcome, u32)>, String> {
    let bytes =
        fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let mut out = Vec::new();
    if bytes.is_empty() {
        return Ok(out);
    }
    if bytes.starts_with(&BIN_MAGIC) {
        let Some(ver) = le_u32_at(&bytes, 4) else {
            return Err("truncated binary store header".into());
        };
        if !(3..=STORE_FORMAT_VERSION).contains(&ver) {
            return Err(format!("unsupported binary store version {ver}"));
        }
        let mut i = BIN_HEADER_LEN;
        while i < bytes.len() {
            let tail = bytes.get(i..).unwrap_or_default();
            let (key, outcome, _touch, used) = decode_record_bin(tail)?;
            out.push((key, outcome, ver));
            i += used;
        }
    } else {
        let text = std::str::from_utf8(&bytes)
            .map_err(|_| format!("{}: not UTF-8", path.display()))?;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, outcome, ver) =
                decode_record(line).map_err(|e| format!("{e:?}"))?;
            out.push((key, outcome, ver));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: u32, r: u32, rep: u32, seed: u64) -> StoreKey {
        StoreKey {
            cluster: 0xDEAD_BEEF_0BAD_F00D,
            app: AppId::WordCount,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
            block_mb: StoreKey::PAPER_BLOCK_MB,
            rep,
            base_seed: seed,
        }
    }

    /// A record line exactly as the v1 (PR 2) store wrote it.
    fn v1_line(k: &StoreKey, time_s: f64) -> String {
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("cluster", Json::Str(hex_u64(k.cluster))),
            ("app", Json::Str(k.app.name().to_string())),
            ("m", Json::Num(k.num_mappers as f64)),
            ("r", Json::Num(k.num_reducers as f64)),
            ("rep", Json::Num(k.rep as f64)),
            ("seed", Json::Str(hex_u64(k.base_seed))),
            ("bits", Json::Str(hex_u64(time_s.to_bits()))),
            ("t", Json::Num(time_s)),
        ])
        .to_string()
    }

    #[test]
    fn jsonl_record_round_trips_bit_exactly() {
        for (i, t) in
            [1523.25, 0.1 + 0.2, f64::MIN_POSITIVE, 1e300].iter().enumerate()
        {
            let mut k = key(20, 5, i as u32, u64::MAX - i as u64);
            k.input_gb_bits = (1.5 + i as f64).to_bits();
            k.block_mb = 32 << i;
            for outcome in
                [RepOutcome::full(*t, t * 4.0 + 1.0), RepOutcome::time_only(*t)]
            {
                let line = encode_record(&k, &outcome);
                let (k2, o2, ver) = decode_record(&line).unwrap();
                assert_eq!(k2, k);
                assert_eq!(ver, JSONL_RECORD_VERSION);
                assert!(o2.same_bits(&outcome));
            }
        }
    }

    #[test]
    fn binary_record_round_trips_bit_exactly() {
        for (i, t) in
            [1523.25, 0.1 + 0.2, f64::MIN_POSITIVE, 1e300, f64::NAN]
                .iter()
                .enumerate()
        {
            let mut k = key(20, 5, i as u32, u64::MAX - i as u64);
            k.input_gb_bits = (1.5 + i as f64).to_bits();
            k.block_mb = 32 << i;
            for outcome in [
                RepOutcome::full(*t, t * 4.0 + 1.0),
                RepOutcome::time_only(*t),
                RepOutcome::with_bytes(
                    *t,
                    t * 4.0 + 1.0,
                    RepBytes {
                        shuffle: u64::MAX - i as u64,
                        hdfs: 1 + (i as u64) << 40,
                    },
                ),
            ] {
                let frame = encode_record_bin(&k, &outcome, 77 + i as u64);
                assert_eq!(frame.len(), frame_len(&k, &outcome));
                let (k2, o2, touch, used) = decode_record_bin(&frame).unwrap();
                assert_eq!(k2, k);
                assert_eq!(touch, 77 + i as u64);
                assert_eq!(used, frame.len());
                assert!(o2.same_bits(&outcome));
            }
        }
    }

    /// A v3 frame is a v4 frame minus the bytes section: strip the
    /// trailing bytes flag and shrink the length prefix to fabricate
    /// what a PR 5–9 build actually wrote, then decode it with today's
    /// codec.
    fn v3_frame(k: &StoreKey, outcome: &RepOutcome, touch: u64) -> Vec<u8> {
        assert!(outcome.bytes.is_none(), "v3 cannot carry bytes");
        let mut frame = encode_record_bin(k, outcome, touch);
        assert_eq!(*frame.last().unwrap(), 0, "bytes-absent flag");
        frame.pop();
        let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) - 1;
        frame[0..4].copy_from_slice(&len.to_le_bytes());
        frame
    }

    #[test]
    fn v3_payloads_decode_natively_with_bytes_absent() {
        for t in [1523.25, f64::NAN, f64::from_bits(0x7FF8_DEAD_BEEF_0001)] {
            for outcome in
                [RepOutcome::full(t, t * 2.0), RepOutcome::time_only(t)]
            {
                let k = key(12, 7, 1, 99);
                let frame = v3_frame(&k, &outcome, 5);
                let (k2, o2, touch, used) = decode_record_bin(&frame).unwrap();
                assert_eq!(k2, k);
                assert_eq!(touch, 5);
                assert_eq!(used, frame.len());
                assert!(o2.same_bits(&outcome));
                assert_eq!(o2.bytes, None);
            }
        }
    }

    #[test]
    fn binary_decode_rejects_bad_bytes_flag() {
        let k = key(5, 5, 0, 1);
        let mut frame =
            encode_record_bin(&k, &RepOutcome::full(2.0, 3.0), 9);
        let last = frame.len() - 1;
        frame[last] = 7;
        assert!(decode_record_bin(&frame).unwrap_err().contains("bytes flag"));
    }

    #[test]
    fn binary_decode_rejects_truncation_and_garbage() {
        let frame = encode_record_bin(
            &key(5, 5, 0, 1),
            &RepOutcome::full(2.0, 3.0),
            9,
        );
        for cut in [0, 3, 4, frame.len() - 1] {
            assert!(decode_record_bin(&frame[..cut]).is_err(), "cut {cut}");
        }
        // A garbled length prefix is implausible, not a panic.
        let mut bad = frame.clone();
        bad[0] = 0xFF;
        bad[1] = 0xFF;
        bad[2] = 0xFF;
        bad[3] = 0x7F;
        assert!(decode_record_bin(&bad).is_err());
        // Trailing payload bytes are rejected (payload must be exact).
        let mut padded = frame.clone();
        let len = u32::from_le_bytes(padded[0..4].try_into().unwrap()) + 1;
        padded[0..4].copy_from_slice(&len.to_le_bytes());
        padded.push(0);
        assert!(decode_record_bin(&padded).is_err());
    }

    #[test]
    fn decode_classifies_stale_and_corrupt() {
        let line = encode_record(&key(5, 5, 0, 1), &RepOutcome::full(2.0, 3.0));
        let stale = line.replace("\"v\":2", "\"v\":999");
        assert_eq!(
            decode_record(&stale),
            Err(RecordError::StaleVersion(999))
        );
        for bad in
            ["", "not json", "{\"v\":2}", "{\"v\":1}", "{\"x\":2}", "[1,2,3]"]
        {
            match decode_record(bad) {
                Err(RecordError::Corrupt(_)) => {}
                other => panic!("expected corrupt for {bad:?}, got {other:?}"),
            }
        }
    }

    #[test]
    fn v1_lines_migrate_to_paper_default_keys() {
        let k = key(20, 5, 3, 42);
        let (k2, o2, ver) = decode_record(&v1_line(&k, 1523.25)).unwrap();
        assert_eq!(ver, 1);
        // The migrated key lands exactly where the 2-parameter executor
        // path keys its reps: the paper-default input/block plane.
        assert_eq!(k2, k);
        assert_eq!(k2.input_gb(), StoreKey::PAPER_INPUT_GB);
        assert_eq!(k2.block_mb, StoreKey::PAPER_BLOCK_MB);
        assert!(k2.is_paper_plane());
        assert_eq!(o2, RepOutcome::time_only(1523.25));
    }
}
