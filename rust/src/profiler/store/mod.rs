//! Persistent, versioned, **sharded** on-disk profile store.
//!
//! Profiling is the expensive phase of the paper's pipeline — every
//! setting is simulated repeatedly before regression modeling can begin —
//! and PR 1's in-memory executor cache only helps within one process.
//! This store spills that cache to disk so *any* CLI invocation
//! (`profile`, `fig3`, `fig4`, `table1`, `e2e`, `serve`, scheduler
//! what-ifs) warm-starts from every prior session on the machine.
//!
//! # Module layout
//!
//! * [`key`] — [`StoreKey`], the persistent identity of one repetition.
//! * [`codec`] — the binary v4 record codec (reads v3 natively) plus
//!   the legacy JSONL (v1/v2) codec it migrates from.
//! * [`file_backend`] — [`FileBackend`], one store *directory*:
//!   segments, index, locks, compaction, LRU eviction.  This is the old
//!   single-directory store, loaded **lazily** (opening is a few file
//!   stats; the data scan happens on first access).
//! * [`memory_backend`] — [`MemoryBackend`], the same contract with no
//!   disk underneath, for fast tests and ephemeral campaigns.
//! * [`sharded`] — [`ProfileStore`], the public facade: routes every
//!   key to one of N shards by a stable hash of `StoreKey.app`, keeps
//!   the cross-shard change journal, migrates legacy single-directory
//!   stores, and compacts shards one at a time on a background thread.
//!
//! # On-disk layout
//!
//! A store is a directory of shard directories:
//!
//! ```text
//! store/
//!   shards.meta             shard count marker (written once, wins over
//!                           any later --store-shards request)
//!   compact.lock            held while migrating a legacy store layout
//!   dlq-*.bin, leases/      dead-letter queue + cooperative leases
//!                           (not store data; always at the root)
//!   shard-00/
//!     index.bin             compacted records (binary v4, atomic replace)
//!     seg-<pid>-<n>-<t>.bin append-only segment, one per writing session
//!     seg-....bin.lock      liveness lock while that segment is open
//!     compact.lock          held briefly while rewriting this shard
//!   shard-01/ ...
//!   index.bin, seg-*.bin    legacy single-directory store files — read,
//!                           migrated into the shards by the first
//!                           compacting open, bit-identical
//! ```
//!
//! Store formats **v3/v4** are binary: a file is an 8-byte header
//! (magic `MRTS` + little-endian version) followed by length-prefixed
//! records (see [`codec::encode_record_bin`]).  Every `u64` and `f64`
//! travels as its raw little-endian bits, so stored values are the same
//! bit-identical rep results the executor produces — which is what makes
//! warm runs byte-identical to cold ones.  v4 appends optional
//! shuffle/HDFS byte counters; v3 payloads decode natively with bytes
//! absent.  The previous JSONL formats (v1 from PR 2, v2 from PR 3) are
//! still decoded on read and never orphaned.
//!
//! # Sharding invariant
//!
//! A key's shard is a pure function of its application name and the
//! store's shard count, and the shard count is pinned by `shards.meta`
//! the first time the store is opened — so **a key's shard is stable
//! across opens, processes, and builds**.  Per-app affinity keeps the
//! trainer's paper-plane records, and any `read_since` cursor over them,
//! inside one shard; two campaigns writing disjoint apps never contend
//! on each other's segment or compaction locks.
//!
//! # Size cap and eviction
//!
//! A capped open (`--store-max-mb` / `MRTUNER_STORE_MAX_MB`) divides the
//! budget evenly across shards; when a shard's compaction would exceed
//! its slice, the least-recently-used records are dropped first.
//! Records carry a **touch** — the generation at which they were last
//! written or answered a lookup — and capped sessions persist their
//! lookup recency at flush.  Repetitions on the paper plane (input 8 GB,
//! block 64 MB) are **pinned**: they are the online trainer's training
//! data and are never evicted, whatever the cap.
//!
//! # Concurrency and crash safety
//!
//! * Every writing session appends to its **own** uniquely-named segment
//!   file inside each shard it touches, so two processes sharing a store
//!   never interleave writes.
//! * A live segment is marked by a `.lock` file carrying the writer's
//!   pid; compaction merges a locked segment's flushed records but never
//!   deletes the file under a live writer.
//! * Compaction is **incremental and off the open path**: opening
//!   returns in milliseconds whatever the store size, and a background
//!   thread (joined on drop) compacts one shard at a time under that
//!   shard's `compact.lock` — write-to-temp + atomic rename, losers of
//!   the lock race just skip the shard.
//! * Corruption is tolerated, never fatal: an unreadable file or a
//!   truncated/garbled record is counted, logged to stderr, and skipped.
//!   Files or records of a *newer* format version than
//!   [`STORE_FORMAT_VERSION`] are skipped and preserved for whichever
//!   build understands them.

pub mod codec;
pub mod file_backend;
pub mod key;
pub mod memory_backend;
pub mod sharded;

pub use codec::{
    decode_record, decode_record_bin, encode_record, encode_record_bin,
    read_file_records,
};
pub use file_backend::FileBackend;
pub use key::{RecordError, StoreKey};
pub use memory_backend::MemoryBackend;
pub use sharded::{ProfileStore, StoreOptions, DEFAULT_STORE_SHARDS};

pub(crate) use file_backend::pid_alive;

use crate::mr::RepOutcome;

/// Store format version; bump when the record schema changes.
///
/// * **v1** (PR 2): JSONL; 2-parameter keys `(cluster, app, m, r, rep,
///   seed)` holding a bare execution time.
/// * **v2** (PR 3): JSONL; keys additionally carry `input_gb`/`block_mb`
///   (the extended 4-parameter sweep axes) and records hold a
///   [`RepOutcome`] — total time plus total CPU seconds.
/// * **v3** (PR 5): binary segments and index — length-prefixed records
///   behind an `MRTS` file header, raw little-endian bit round-trip for
///   every `u64`/`f64`, plus a persisted last-hit **touch** generation
///   that drives size-capped LRU eviction.
/// * **v4** (PR 10): records additionally carry the deterministic
///   shuffle/HDFS byte counters ([`crate::mr::RepBytes`]) behind a
///   presence flag appended after the CPU section.  v3 payloads decode
///   natively with `bytes` absent — no rewrite on read — and are
///   upgraded in place on the first re-simulation, exactly as v1
///   records gained their CPU figure under v2.
///
/// The **sharded layout** (PR 8) is a directory arrangement, not a
/// record format: shard files are plain v4 files, and legacy
/// single-directory v1/v2/v3 stores are migrated into shards on the
/// first compacting open with bit-identical contents.  Readers skip
/// (and preserve) files or records of any *newer* version.
pub const STORE_FORMAT_VERSION: u32 = 4;

/// One storage engine under the [`ProfileStore`] facade: the contract
/// every backend (file, memory, future remote) must honor so the
/// executor, trainer, DLQ, and CLI never touch a concrete format.
///
/// Implementations are internally synchronized — every method takes
/// `&self` and is safe to call from the executor's worker threads.  The
/// determinism invariant the whole system rests on carries over: equal
/// keys always map to bit-equal outcomes, so duplicate folding in any
/// order is sound.
pub trait StoreBackend: Send + Sync {
    /// Stored outcome for `key`, if any prior session simulated it.  A
    /// hit bumps the record's recency (it was just *used*), so hot
    /// records survive size-capped eviction.
    fn get(&self, key: &StoreKey) -> Option<RepOutcome>;

    /// Like [`StoreBackend::get`] but without the recency bump — the
    /// read-only resolve used when replaying the change journal.
    fn lookup(&self, key: &StoreKey) -> Option<RepOutcome>;

    /// Record a freshly simulated outcome.  Returns `true` when the
    /// record was **journaled** (new key, or a partial record — missing
    /// CPU or byte figures — upgraded in place): exactly when the
    /// backend's generation advanced.  Re-putting a known value only
    /// bumps recency and returns `false`; a put that would *lose* a
    /// recorded figure ([`RepOutcome::downgrades`]) is treated the same
    /// way — the fuller record wins.
    fn put(&self, key: StoreKey, outcome: RepOutcome) -> bool;

    /// Persist buffered records (a no-op for memory backends).
    fn flush(&self) -> Result<(), String>;

    /// Monotonic change counter: how many records this backend instance
    /// has accepted so far (records found on disk plus every later
    /// insertion).
    fn generation(&self) -> u64;

    /// Every record accepted after `generation`, plus the generation
    /// that snapshot corresponds to (pass it back next time).  The
    /// stream is an upsert log: a key may repeat when its record was
    /// upgraded in place; a key evicted since it was journaled is
    /// skipped.
    fn read_since(&self, generation: u64)
        -> (Vec<(StoreKey, RepOutcome)>, u64);

    /// Fold in records written by *other* sessions since the last poll,
    /// returning how many were new to this instance.
    fn refresh(&self) -> Result<u64, String>;

    /// Run one compaction pass now — fold segments into the index,
    /// evict to the size cap, delete merged files — and return that
    /// pass's stats.  A no-op (with `compacted == false`) when there is
    /// nothing to do or another process holds the compaction lock.
    fn compact(&self) -> Result<StoreStats, String>;

    /// Cumulative stats: what loading saw on disk plus every compaction
    /// pass since, with `entries`/`bytes`/`pending` refreshed live.
    fn stats(&self) -> StoreStats;

    /// Distinct records currently resident.
    fn len(&self) -> usize;

    /// Whether no records are resident.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records buffered but not yet persisted.
    fn pending(&self) -> usize;
}

/// What a backend saw on disk plus the live resident/pending counts.
/// Per-shard snapshots add across shards into the store-wide totals
/// ([`StoreStats::absorb`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Distinct records currently loaded.
    pub entries: usize,
    /// Exact byte size of a compacted index holding the resident
    /// records (the figure the size cap is enforced against).
    pub bytes: u64,
    /// Records buffered but not yet persisted.
    pub pending: usize,
    /// Segment files present when the store was opened.
    pub segments_seen: usize,
    /// Segments folded into the index (and deleted) by compaction.
    pub merged_segments: usize,
    /// Files that could not be read at all (skipped, logged).
    pub corrupt_segments: usize,
    /// Undecodable lines/records inside otherwise readable files.
    pub corrupt_lines: usize,
    /// Lines — or whole binary files — of a *newer* store-format version
    /// (skipped, preserved).
    pub stale_lines: usize,
    /// Legacy JSONL (v1/v2) lines migrated on read into v3 records
    /// (rewritten as binary by the next compaction).
    pub migrated_lines: usize,
    /// Records dropped by size-capped LRU eviction (never paper-plane
    /// reps — those are pinned).
    pub evicted: usize,
    /// Whether a compaction pass rewrote an index.
    pub compacted: bool,
}

impl StoreStats {
    /// Fold another snapshot (one shard, or one compaction pass) into
    /// this one: counters add, `compacted` ORs.
    pub fn absorb(&mut self, other: &StoreStats) {
        self.entries += other.entries;
        self.bytes += other.bytes;
        self.pending += other.pending;
        self.segments_seen += other.segments_seen;
        self.merged_segments += other.merged_segments;
        self.corrupt_segments += other.corrupt_segments;
        self.corrupt_lines += other.corrupt_lines;
        self.stale_lines += other.stale_lines;
        self.migrated_lines += other.migrated_lines;
        self.evicted += other.evicted;
        self.compacted |= other.compacted;
    }
}

impl std::fmt::Display for StoreStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "entries={} bytes={} pending={} segments_seen={} merged={} \
             corrupt_segments={} corrupt_lines={} stale_lines={} \
             migrated={} evicted={} compacted={}",
            self.entries,
            self.bytes,
            self.pending,
            self.segments_seen,
            self.merged_segments,
            self.corrupt_segments,
            self.corrupt_lines,
            self.stale_lines,
            self.migrated_lines,
            self.evicted,
            self.compacted
        )
    }
}
