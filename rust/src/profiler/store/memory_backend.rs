//! A [`StoreBackend`] with no disk underneath.
//!
//! Everything lives in one mutex-guarded map: `flush` is a no-op,
//! `refresh` never finds other sessions' records (there is no shared
//! medium), and `compact` only enforces the size cap.  Two uses: fast
//! store-suite tests that exercise the trait contract without touching
//! the filesystem, and ephemeral campaigns (`--store-mem`) that want
//! read-through/write-back semantics without leaving files behind.

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard};

use super::file_backend::{evict_to_cap, fold_entry, index_bytes, StoredRep};
use super::key::StoreKey;
use super::{StoreBackend, StoreStats};
use crate::mr::RepOutcome;

struct Inner {
    entries: HashMap<StoreKey, StoredRep>,
    /// Acceptance-order key log; `journal.len()` is the generation.
    journal: Vec<StoreKey>,
    /// Monotonic touch clock driving LRU eviction under a cap.
    clock: u64,
    /// Records dropped by capped compaction so far.
    evicted: usize,
    compacted: bool,
}

/// In-memory [`StoreBackend`]: the [`super::FileBackend`] contract —
/// journal, generation, CPU/bytes-upgrade folding, capped LRU eviction
/// with paper-plane pinning — minus persistence.
pub struct MemoryBackend {
    cap: Option<u64>,
    inner: Mutex<Inner>,
}

impl MemoryBackend {
    /// An empty backend with an optional size cap in bytes (enforced by
    /// [`StoreBackend::compact`] against the records' index-encoded
    /// size, exactly like the file backend's cap).
    pub fn new(cap: Option<u64>) -> MemoryBackend {
        MemoryBackend {
            cap,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                journal: Vec::new(),
                clock: 0,
                evicted: 0,
                compacted: false,
            }),
        }
    }

    /// Lock the map, recovering from poison — a panicking caller leaves
    /// the already-applied puts intact, which is the same view a crashed
    /// process would reload from a file-backed store.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                self.inner.clear_poison();
                poisoned.into_inner()
            }
        }
    }
}

impl Default for MemoryBackend {
    fn default() -> MemoryBackend {
        MemoryBackend::new(None)
    }
}

impl StoreBackend for MemoryBackend {
    fn get(&self, key: &StoreKey) -> Option<RepOutcome> {
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        inner.entries.get_mut(key).map(|sr| {
            sr.touch = clock;
            sr.outcome
        })
    }

    fn lookup(&self, key: &StoreKey) -> Option<RepOutcome> {
        self.lock().entries.get(key).map(|sr| sr.outcome)
    }

    fn put(&self, key: StoreKey, outcome: RepOutcome) -> bool {
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.entries.get_mut(&key) {
            Some(old)
                if old.outcome.same_bits(&outcome)
                    || outcome.downgrades(&old.outcome) =>
            {
                old.touch = clock;
                false
            }
            _ => {
                inner
                    .entries
                    .insert(key, StoredRep { outcome, touch: clock });
                inner.journal.push(key);
                true
            }
        }
    }

    fn flush(&self) -> Result<(), String> {
        Ok(()) // nothing to persist to
    }

    fn generation(&self) -> u64 {
        self.lock().journal.len() as u64
    }

    fn read_since(
        &self,
        generation: u64,
    ) -> (Vec<(StoreKey, RepOutcome)>, u64) {
        let inner = self.lock();
        let from = (generation as usize).min(inner.journal.len());
        let records = inner
            .journal
            .get(from..)
            .unwrap_or_default()
            .iter()
            .filter_map(|k| inner.entries.get(k).map(|sr| (*k, sr.outcome)))
            .collect();
        (records, inner.journal.len() as u64)
    }

    fn refresh(&self) -> Result<u64, String> {
        Ok(0) // no shared medium: there are no other sessions to see
    }

    fn compact(&self) -> Result<StoreStats, String> {
        let mut inner = self.lock();
        let mut pass = StoreStats::default();
        if let Some(cap) = self.cap {
            let dropped = evict_to_cap(&mut inner.entries, cap);
            if !dropped.is_empty() {
                inner.evicted += dropped.len();
                inner.compacted = true;
                pass.evicted = dropped.len();
                pass.compacted = true;
            }
        }
        pass.entries = inner.entries.len();
        Ok(pass)
    }

    fn stats(&self) -> StoreStats {
        let inner = self.lock();
        StoreStats {
            entries: inner.entries.len(),
            bytes: index_bytes(&inner.entries),
            evicted: inner.evicted,
            compacted: inner.compacted,
            ..StoreStats::default()
        }
    }

    fn len(&self) -> usize {
        self.lock().entries.len()
    }

    fn pending(&self) -> usize {
        0 // every record is "persisted" the moment it is put
    }
}

/// Fold already-decoded records in (used by tests mirroring the file
/// backend's preload path).
impl MemoryBackend {
    pub(crate) fn preload(&self, records: Vec<(StoreKey, StoredRep)>) {
        let mut inner = self.lock();
        let mut fresh: Vec<StoreKey> = Vec::new();
        for (key, sr) in records {
            inner.clock = inner.clock.max(sr.touch);
            let known = inner.entries.contains_key(&key);
            fold_entry(&mut inner.entries, key, sr);
            if !known {
                fresh.push(key);
            }
        }
        fresh.sort();
        inner.journal.extend(fresh);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::AppId;

    fn key(m: u32, r: u32, rep: u32) -> StoreKey {
        StoreKey {
            cluster: 1,
            app: AppId::WordCount,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
            block_mb: StoreKey::PAPER_BLOCK_MB,
            rep,
            base_seed: 9,
        }
    }

    #[test]
    fn memory_backend_honors_journal_and_upgrade_contract() {
        let b = MemoryBackend::new(None);
        let k = key(20, 5, 0);
        assert!(b.put(k, RepOutcome::time_only(10.0)));
        assert!(!b.put(k, RepOutcome::time_only(10.0)), "recency only");
        assert!(b.put(k, RepOutcome::full(10.0, 2.0)), "CPU upgrade");
        assert!(
            !b.put(k, RepOutcome::time_only(10.0)),
            "never downgrades"
        );
        let full = RepOutcome::with_bytes(
            10.0,
            2.0,
            crate::mr::RepBytes { shuffle: 3, hdfs: 5 },
        );
        assert!(b.put(k, full), "bytes upgrade");
        assert!(
            !b.put(k, RepOutcome::full(10.0, 2.0)),
            "bytes-less never displaces a full record"
        );
        assert_eq!(b.get(&k), Some(full));
        assert_eq!(b.generation(), 3, "three journaled changes");
        let (records, g) = b.read_since(0);
        assert_eq!(g, 3);
        // Upsert log: the same key appears per journaled change, all
        // resolving to the current (upgraded) value.
        assert_eq!(records.len(), 3);
        assert!(records.iter().all(|(_, o)| *o == full));
        assert_eq!(b.pending(), 0);
        b.flush().unwrap();
        assert_eq!(b.refresh().unwrap(), 0);
    }

    #[test]
    fn capped_memory_backend_evicts_lru_but_pins_paper_plane() {
        let b = MemoryBackend::new(Some(700));
        for rep in 0..3 {
            b.put(key(20, 5, rep), RepOutcome::full(50.0, 5.0));
        }
        for i in 0..20u32 {
            // Off-plane filler: evictable.
            b.put(
                StoreKey {
                    cluster: 1,
                    app: AppId::Grep,
                    num_mappers: 4 + i,
                    num_reducers: 2,
                    input_gb_bits: 2.0f64.to_bits(),
                    block_mb: 128,
                    rep: 0,
                    base_seed: 9,
                },
                RepOutcome::full(5.0 + i as f64, 0.5),
            );
        }
        let pass = b.compact().unwrap();
        assert!(pass.compacted && pass.evicted > 0, "cap enforced: {pass}");
        let st = b.stats();
        assert!(st.bytes <= 700, "under cap after compaction: {st}");
        for rep in 0..3 {
            assert!(
                b.lookup(&key(20, 5, rep)).is_some(),
                "paper-plane rep {rep} pinned"
            );
        }
        let (records, _) = b.read_since(0);
        assert_eq!(
            records.len(),
            b.len(),
            "read_since skips evicted journal keys"
        );
    }
}
