//! The persistent identity of one simulated repetition.

use crate::apps::AppId;

/// Identity of one simulated repetition — the executor's cache key made
/// persistent.  The cluster fingerprint keeps times from one hardware
/// model from ever answering for another; `base_seed` keys the profiling
/// session so distinct sessions never alias.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreKey {
    /// Fingerprint of every simulation-relevant cluster field.
    pub cluster: u64,
    /// Application profiled.
    pub app: AppId,
    /// Number of map tasks (the paper's first parameter).
    pub num_mappers: u32,
    /// Number of reduce tasks (the paper's second parameter).
    pub num_reducers: u32,
    /// Input size in GB — the extended sweep's third parameter — as raw
    /// `f64` bits (`f64` has no `Eq`/`Hash`; bits keep the key exact).
    /// The paper's own setup is [`StoreKey::PAPER_INPUT_GB`].
    pub input_gb_bits: u64,
    /// HDFS block size in MB — the extended sweep's fourth parameter.
    /// The paper's own setup is [`StoreKey::PAPER_BLOCK_MB`].
    pub block_mb: u32,
    /// Repetition index within the profiling session.
    pub rep: u32,
    /// Profiling-session seed.
    pub base_seed: u64,
}

impl StoreKey {
    /// Input size of the paper's testbed (`JobConfig::paper_default`) —
    /// where 2-parameter keys, and migrated v1 records, live in the 4-D
    /// parameter space.
    pub const PAPER_INPUT_GB: f64 = 8.0;
    /// HDFS block size of the paper's testbed.
    pub const PAPER_BLOCK_MB: u32 = 64;

    /// Input size in GB.
    pub fn input_gb(&self) -> f64 {
        f64::from_bits(self.input_gb_bits)
    }

    /// Whether this key lies on the **paper plane** (paper-default input
    /// and block size).  Paper-plane repetitions feed the online trainer
    /// ([`crate::coordinator::Trainer`]) and are therefore *pinned*:
    /// size-capped eviction never drops them.
    pub fn is_paper_plane(&self) -> bool {
        self.input_gb_bits == StoreKey::PAPER_INPUT_GB.to_bits()
            && self.block_mb == StoreKey::PAPER_BLOCK_MB
    }
}

/// Why a record line failed to decode.
#[derive(Clone, Debug, PartialEq)]
pub enum RecordError {
    /// The line is a record of a store-format version this build cannot
    /// read (newer than [`super::STORE_FORMAT_VERSION`], or 0/garbage).
    StaleVersion(u64),
    /// The line is not a valid record at all (truncated write, garbage).
    Corrupt(String),
}
