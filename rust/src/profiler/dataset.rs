//! Profiled datasets: the (params, time) rows feeding the regression,
//! with JSON persistence.

use std::path::Path;

use crate::apps::AppId;
use crate::util::json::{parse, Json};

use super::experiment::{ExperimentResult, ExperimentSpec};

/// A set of profiled experiments for one application.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    /// Application the rows were profiled for.
    pub app_name: String,
    /// (num_mappers, num_reducers) rows.
    pub params: Vec<[f64; 2]>,
    /// Mean total execution time per row, seconds.
    pub times: Vec<f64>,
}

impl Dataset {
    /// Collapse experiment results into regression rows (spec → mean).
    pub fn from_results(app: AppId, results: &[ExperimentResult]) -> Dataset {
        Dataset {
            app_name: app.name().to_string(),
            params: results.iter().map(|r| r.spec.params()).collect(),
            times: results.iter().map(|r| r.mean_time_s).collect(),
        }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    /// Whether the dataset has no rows.
    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// Append one profiled row.
    pub fn push(&mut self, spec: &ExperimentSpec, time_s: f64) {
        self.params.push(spec.params());
        self.times.push(time_s);
    }

    /// Serialize for persistence.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::Str(self.app_name.clone())),
            (
                "params",
                Json::Arr(
                    self.params
                        .iter()
                        .map(|p| Json::from_f64_slice(p))
                        .collect(),
                ),
            ),
            ("times", Json::from_f64_slice(&self.times)),
        ])
    }

    /// Rebuild from [`Dataset::to_json`] output (validates row counts).
    pub fn from_json(v: &Json) -> Result<Dataset, String> {
        let app_name = v.req("app")?.as_str().ok_or("app must be str")?.to_string();
        let params = v
            .req("params")?
            .as_arr()
            .ok_or("params must be array")?
            .iter()
            .map(|row| {
                let xs = row.to_f64_vec()?;
                if xs.len() != 2 {
                    return Err(format!("param row must have 2 entries, got {}", xs.len()));
                }
                Ok([xs[0], xs[1]])
            })
            .collect::<Result<Vec<_>, String>>()?;
        let times = v.req("times")?.to_f64_vec()?;
        if params.len() != times.len() {
            return Err(format!(
                "params rows {} != times rows {}",
                params.len(),
                times.len()
            ));
        }
        Ok(Dataset { app_name, params, times })
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load from a file written by [`Dataset::save`].
    pub fn load(path: &Path) -> Result<Dataset, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Dataset::from_json(&parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Dataset {
        Dataset {
            app_name: "wordcount".into(),
            params: vec![[5.0, 10.0], [20.0, 5.0]],
            times: vec![300.5, 250.25],
        }
    }

    #[test]
    fn json_round_trip() {
        let d = sample();
        let j = d.to_json();
        let back = Dataset::from_json(&j).unwrap();
        assert_eq!(back.app_name, d.app_name);
        assert_eq!(back.params, d.params);
        assert_eq!(back.times, d.times);
    }

    #[test]
    fn file_round_trip() {
        let d = sample();
        let path = std::env::temp_dir().join("mrtuner_test_dataset.json");
        d.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.params, d.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_mismatched_lengths() {
        let j = parse(r#"{"app":"x","params":[[1,2]],"times":[1,2]}"#).unwrap();
        assert!(Dataset::from_json(&j).is_err());
        let j = parse(r#"{"app":"x","params":[[1,2,3]],"times":[1]}"#).unwrap();
        assert!(Dataset::from_json(&j).is_err());
        let j = parse(r#"{"params":[],"times":[]}"#).unwrap();
        assert!(Dataset::from_json(&j).is_err(), "missing app field");
    }

    #[test]
    fn push_appends() {
        let mut d = sample();
        d.push(&ExperimentSpec::new(AppId::WordCount, 40, 40), 500.0);
        assert_eq!(d.len(), 3);
        assert_eq!(d.params[2], [40.0, 40.0]);
    }
}
