//! Profiling campaigns: which (M, R) settings to run.
//!
//! The paper (§V.A) uses "20 sets of two configuration parameters values
//! ... chosen between 5 to 40" for modeling, and tests on further random
//! settings in the same range (§V.B).

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::util::rng::Rng;

use super::dataset::Dataset;
use super::executor::{CampaignExecutor, RepJob};
use super::experiment::{ExperimentResult, ExperimentSpec, REPS};

/// Lower end of the parameter range studied by the paper.
pub const PARAM_MIN: u32 = 5;
/// Upper end of the parameter range studied by the paper.
pub const PARAM_MAX: u32 = 40;

/// A profiling campaign: a list of experiment settings for one app.
#[derive(Clone, Debug)]
pub struct Campaign {
    /// Application under test.
    pub app: AppId,
    /// Settings to profile, in order.
    pub specs: Vec<ExperimentSpec>,
    /// Repetitions per setting (the paper uses 5).
    pub reps: u32,
    /// Profiling-session seed (layout + per-rep noise derive from it).
    pub base_seed: u64,
}

impl Campaign {
    /// Run every experiment serially, returning both raw results and the
    /// dataset.  Shorthand for [`Campaign::run_with`] on a one-shot serial
    /// executor; callers running several campaigns (or wanting the worker
    /// pool) should share one [`CampaignExecutor`] instead.
    pub fn run(&self, cluster: &Cluster) -> (Vec<ExperimentResult>, Dataset) {
        self.run_with(cluster, &CampaignExecutor::serial())
    }

    /// Run every experiment through `executor` (parallel fan-out + rep
    /// cache).  Results are in spec order and bit-identical to a serial
    /// run for the same `base_seed`, whatever the worker count.
    pub fn run_with(
        &self,
        cluster: &Cluster,
        executor: &CampaignExecutor,
    ) -> (Vec<ExperimentResult>, Dataset) {
        executor.run_campaign(cluster, self)
    }

    /// Every repetition of this campaign as executor work items, in
    /// dispatch order — the unit list `--resume` diffs against the
    /// profile store (see `CampaignExecutor::resume_status`).
    pub fn rep_jobs(&self) -> Vec<RepJob> {
        self.specs
            .iter()
            .flat_map(|s| {
                (0..self.reps).map(move |rep| RepJob::paper(*s, rep, self.base_seed))
            })
            .collect()
    }
}

/// Number of distinct settings in the paper's `[PARAM_MIN, PARAM_MAX]^2`
/// parameter lattice — the hard upper bound on any distinct sample.
pub const LATTICE_SIZE: usize =
    ((PARAM_MAX - PARAM_MIN + 1) * (PARAM_MAX - PARAM_MIN + 1)) as usize;

/// Sample `n` distinct settings uniformly from the paper's range.
///
/// The lattice holds only [`LATTICE_SIZE`] (= 36 × 36 = 1296) distinct
/// `(M, R)` pairs, so `n` is clamped to that bound — asking for more used
/// to spin the rejection loop forever.
pub fn random_specs(app: AppId, n: usize, rng: &mut Rng) -> Vec<ExperimentSpec> {
    let n = n.min(LATTICE_SIZE);
    let mut specs = Vec::with_capacity(n);
    let mut seen = std::collections::BTreeSet::new();
    while specs.len() < n {
        let m = rng.range_u64(PARAM_MIN as u64, PARAM_MAX as u64 + 1) as u32;
        let r = rng.range_u64(PARAM_MIN as u64, PARAM_MAX as u64 + 1) as u32;
        if seen.insert((m, r)) {
            specs.push(ExperimentSpec::new(app, m, r));
        }
    }
    specs
}

/// Space-filling training settings: a jittered grid covering the range
/// more evenly than pure uniform sampling (the paper does not specify its
/// 20 sets; a spread design is the natural reading of "20 sets ... chosen
/// between 5 to 40").
pub fn spread_specs(app: AppId, n: usize, rng: &mut Rng) -> Vec<ExperimentSpec> {
    // 5x4 (or similar) lattice over [5,40]^2, jittered by +-2.
    let cols = (n as f64).sqrt().ceil() as usize;
    let rows = n.div_ceil(cols);
    let span = (PARAM_MAX - PARAM_MIN) as f64;
    let mut specs = Vec::with_capacity(n);
    'outer: for i in 0..rows {
        for j in 0..cols {
            if specs.len() >= n {
                break 'outer;
            }
            let fx = if cols > 1 { j as f64 / (cols - 1) as f64 } else { 0.5 };
            let fy = if rows > 1 { i as f64 / (rows - 1) as f64 } else { 0.5 };
            let jitter = |rng: &mut Rng| rng.range_f64(-2.0, 2.0);
            let m = (PARAM_MIN as f64 + fx * span + jitter(rng))
                .round()
                .clamp(PARAM_MIN as f64, PARAM_MAX as f64) as u32;
            let r = (PARAM_MIN as f64 + fy * span + jitter(rng))
                .round()
                .clamp(PARAM_MIN as f64, PARAM_MAX as f64) as u32;
            specs.push(ExperimentSpec::new(app, m, r));
        }
    }
    specs
}

/// The paper's evaluation protocol for one app: 20 training settings and
/// 20 random held-out test settings, 5 reps each.
pub fn paper_campaign(app: AppId, seed: u64) -> (Campaign, Campaign) {
    let mut rng = Rng::new(seed ^ 0xCA3F_0CA3_F0CA_3F0C);
    let train = Campaign {
        app,
        specs: spread_specs(app, 20, &mut rng),
        reps: REPS,
        base_seed: seed,
    };
    // Held-out settings must be disjoint from training (prediction of
    // *new* experiments, Fig. 2b).
    let train_set: std::collections::BTreeSet<(u32, u32)> = train
        .specs
        .iter()
        .map(|s| (s.num_mappers, s.num_reducers))
        .collect();
    let mut test_specs = Vec::new();
    while test_specs.len() < 20 {
        for s in random_specs(app, 20 - test_specs.len(), &mut rng) {
            if !train_set.contains(&(s.num_mappers, s.num_reducers)) {
                test_specs.push(s);
            }
        }
    }
    let test = Campaign {
        app,
        specs: test_specs,
        reps: REPS,
        // Different session seed: test-time runs are new executions.
        base_seed: seed.wrapping_add(0x7E57),
    };
    (train, test)
}

/// Full-grid sweep for the Fig. 4 surface: every (M, R) on a step lattice.
pub fn grid_specs(app: AppId, step: u32) -> Vec<ExperimentSpec> {
    let mut out = Vec::new();
    let mut m = PARAM_MIN;
    while m <= PARAM_MAX {
        let mut r = PARAM_MIN;
        while r <= PARAM_MAX {
            out.push(ExperimentSpec::new(app, m, r));
            r += step;
        }
        m += step;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn paper_campaign_shape() {
        let (train, test) = paper_campaign(AppId::WordCount, 42);
        assert_eq!(train.specs.len(), 20);
        assert_eq!(test.specs.len(), 20);
        assert_eq!(train.reps, 5);
        for s in train.specs.iter().chain(&test.specs) {
            assert!((PARAM_MIN..=PARAM_MAX).contains(&s.num_mappers));
            assert!((PARAM_MIN..=PARAM_MAX).contains(&s.num_reducers));
        }
        // Held-out settings are disjoint from training settings.
        let train_set: std::collections::HashSet<(u32, u32)> = train
            .specs
            .iter()
            .map(|s| (s.num_mappers, s.num_reducers))
            .collect();
        for s in &test.specs {
            assert!(!train_set.contains(&(s.num_mappers, s.num_reducers)));
        }
    }

    #[test]
    fn spread_covers_corners_roughly() {
        let mut rng = Rng::new(1);
        let specs = spread_specs(AppId::WordCount, 20, &mut rng);
        assert_eq!(specs.len(), 20);
        let min_m = specs.iter().map(|s| s.num_mappers).min().unwrap();
        let max_m = specs.iter().map(|s| s.num_mappers).max().unwrap();
        assert!(min_m <= 10, "low corner covered, got {min_m}");
        assert!(max_m >= 35, "high corner covered, got {max_m}");
    }

    #[test]
    fn random_specs_distinct() {
        forall("random specs distinct", 10, |rng| {
            let n = rng.range_usize(1, 40);
            let specs = random_specs(AppId::Grep, n, rng);
            let set: std::collections::HashSet<(u32, u32)> = specs
                .iter()
                .map(|s| (s.num_mappers, s.num_reducers))
                .collect();
            assert_eq!(set.len(), n);
        });
    }

    #[test]
    fn random_specs_clamped_to_lattice() {
        assert_eq!(LATTICE_SIZE, 1296);
        let mut rng = Rng::new(5);
        // Asking for more than the lattice holds must terminate with every
        // distinct setting exactly once, not spin forever.
        let specs = random_specs(AppId::WordCount, LATTICE_SIZE + 500, &mut rng);
        assert_eq!(specs.len(), LATTICE_SIZE);
        let set: std::collections::HashSet<(u32, u32)> = specs
            .iter()
            .map(|s| (s.num_mappers, s.num_reducers))
            .collect();
        assert_eq!(set.len(), LATTICE_SIZE);
    }

    #[test]
    fn grid_specs_lattice() {
        let g = grid_specs(AppId::WordCount, 5);
        // 5,10,...,40 -> 8 values per axis.
        assert_eq!(g.len(), 64);
        assert!(g.iter().any(|s| s.num_mappers == 40 && s.num_reducers == 40));
    }

    #[test]
    fn campaign_runs_produce_dataset() {
        let cluster = Cluster::paper_cluster();
        let c = Campaign {
            app: AppId::WordCount,
            specs: vec![
                ExperimentSpec::new(AppId::WordCount, 10, 10),
                ExperimentSpec::new(AppId::WordCount, 20, 5),
            ],
            reps: 2,
            base_seed: 3,
        };
        let (results, ds) = c.run(&cluster);
        assert_eq!(results.len(), 2);
        assert_eq!(ds.len(), 2);
        assert!(ds.times.iter().all(|&t| t > 0.0));
    }
}
