//! Extended profiling — the paper's §I extension hook and its companion
//! work [24]:
//!
//! * **four configuration parameters**: number of mappers, number of
//!   reducers, input-file size and file-system (HDFS block) size;
//! * **two modeled outputs**: total execution time (this paper) and total
//!   CPU seconds ("CPU tick clocks", [24]).
//!
//! Since the executor generalization, these sweeps run through the same
//! [`CampaignExecutor`] as the paper's 2-parameter campaigns — parallel
//! fan-out, in-memory rep cache, persistent-store warm starts — via
//! [`crate::profiler::RepSpec::Ext4`].  The free functions here are
//! serial-executor conveniences, exactly like
//! [`super::experiment::run_experiment`].

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::mr::config::SplitPolicy;
use crate::mr::JobConfig;
use crate::profiler::store::StoreKey;
use crate::util::bytes::{GB, MB};
use crate::util::rng::Rng;

use super::executor::{CampaignExecutor, RepJob};

/// A four-parameter experiment setting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ext4Spec {
    /// Application profiled.
    pub app: AppId,
    /// Number of map tasks.
    pub num_mappers: u32,
    /// Number of reduce tasks.
    pub num_reducers: u32,
    /// Input size in GB (third studied parameter).
    pub input_gb: f64,
    /// HDFS block size in MB (fourth studied parameter).
    pub block_mb: u32,
}

/// Studied ranges (paper range for M/R; practical 2011 ranges for the
/// rest; the paper's own setup is input 8 GB, block 64 MB).
pub const INPUT_GB_RANGE: (f64, f64) = (1.0, 16.0);
/// Block sizes swept by the 4-parameter extension.
pub const BLOCK_MB_CHOICES: [u32; 4] = [32, 64, 128, 256];

/// Per-parameter normalization scales, in raw-row order.
pub fn scales() -> Vec<f64> {
    vec![40.0, 40.0, INPUT_GB_RANGE.1, 256.0]
}

impl Ext4Spec {
    /// Regression row: (M, R, input_gb, block_mb).
    pub fn params(&self) -> Vec<f64> {
        vec![
            self.num_mappers as f64,
            self.num_reducers as f64,
            self.input_gb,
            self.block_mb as f64,
        ]
    }

    /// The simulator config for this setting at the given run seed.
    pub fn job_config(&self, seed: u64) -> JobConfig {
        let mut cfg =
            JobConfig::paper_default(self.num_mappers, self.num_reducers);
        cfg.input_bytes = (self.input_gb * GB as f64) as u64;
        cfg.split_policy =
            SplitPolicy::HadoopHint { block_bytes: self.block_mb as u64 * MB };
        cfg.with_seed(seed)
    }

    /// Whether this setting lies on the **paper plane** of the 4-D space:
    /// input and block size at their paper-default values.  Such a
    /// setting *is* the corresponding 2-parameter experiment, bit for bit
    /// — same [`JobConfig`], same per-rep seed derivation, same
    /// `StoreKey` — so the executor's caches may (correctly) answer one
    /// shape's reps with the other's.
    pub fn is_paper_plane(&self) -> bool {
        self.input_gb.to_bits() == StoreKey::PAPER_INPUT_GB.to_bits()
            && self.block_mb == StoreKey::PAPER_BLOCK_MB
    }
}

/// Derive the run seed for one repetition of one extended setting within
/// a profiling session — the historical `run_ext4` recipe, kept verbatim
/// so executor-backed sweeps reproduce the pre-executor seed streams.
/// Settings on the paper plane use the 2-parameter derivation instead
/// (see [`Ext4Spec::is_paper_plane`]); the executor handles that split.
pub(crate) fn mix_ext4(base: u64, spec: &Ext4Spec, rep: u32) -> u64 {
    let mut h = base ^ 0xe474_5f65_7874_3464;
    for v in [
        spec.num_mappers as u64,
        spec.num_reducers as u64,
        (spec.input_gb * 2.0) as u64,
        spec.block_mb as u64,
        rep as u64,
    ] {
        h ^= v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = h.rotate_left(19).wrapping_mul(0x94D0_49BB_1331_11EB);
    }
    h
}

/// Every repetition of an extended sweep as executor work items, in
/// dispatch order — the unit list `--resume` diffs against the profile
/// store, and the list [`CampaignExecutor::run_ext4_specs`] dispatches.
pub fn ext4_rep_jobs(
    specs: &[Ext4Spec],
    reps: u32,
    base_seed: u64,
) -> Vec<RepJob> {
    specs
        .iter()
        .flat_map(|s| (0..reps).map(move |rep| RepJob::ext4(*s, rep, base_seed)))
        .collect()
}

/// Sample `n` random settings over the 4-D range.
pub fn random_ext4(app: AppId, n: usize, rng: &mut Rng) -> Vec<Ext4Spec> {
    (0..n)
        .map(|_| Ext4Spec {
            app,
            num_mappers: rng.range_u64(5, 41) as u32,
            num_reducers: rng.range_u64(5, 41) as u32,
            input_gb: (rng.range_f64(INPUT_GB_RANGE.0, INPUT_GB_RANGE.1) * 2.0)
                .round()
                / 2.0,
            block_mb: *rng.choice(&BLOCK_MB_CHOICES),
        })
        .collect()
}

/// Profiled outcome of one extended experiment (means over `reps`).
#[derive(Clone, Debug)]
pub struct Ext4Result {
    /// The setting profiled.
    pub spec: Ext4Spec,
    /// Mean total execution time over the reps.
    pub mean_time_s: f64,
    /// Mean total CPU-seconds over the reps (companion-work target).
    pub mean_cpu_s: f64,
}

/// Run one extended experiment: `reps` simulated executions, averaged.
///
/// Convenience wrapper over a one-shot serial
/// [`CampaignExecutor::run_ext4_specs`], so it agrees bit-for-bit with
/// executor-driven (parallel, store-backed) sweeps.
pub fn run_ext4(
    cluster: &Cluster,
    spec: &Ext4Spec,
    reps: u32,
    base_seed: u64,
) -> Ext4Result {
    CampaignExecutor::serial()
        .run_ext4_specs(cluster, std::slice::from_ref(spec), reps, base_seed)
        .pop()
        .expect("one spec in, one result out")
}

/// Run a whole campaign; returns raw rows for both modeled outputs.
/// Serial shorthand for [`CampaignExecutor::run_ext4_campaign`] —
/// callers wanting the worker pool or the persistent store should share
/// one executor instead.
pub fn run_ext4_campaign(
    cluster: &Cluster,
    specs: &[Ext4Spec],
    reps: u32,
    base_seed: u64,
) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>) {
    CampaignExecutor::serial().run_ext4_campaign(cluster, specs, reps, base_seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trip_into_config() {
        let s = Ext4Spec {
            app: AppId::WordCount,
            num_mappers: 20,
            num_reducers: 5,
            input_gb: 4.0,
            block_mb: 128,
        };
        let cfg = s.job_config(9);
        assert_eq!(cfg.input_bytes, 4 * GB);
        assert_eq!(
            cfg.split_policy,
            SplitPolicy::HadoopHint { block_bytes: 128 * MB }
        );
        // 4 GB / 128 MB blocks -> 32 tasks.
        assert_eq!(cfg.map_tasks(), 32);
        assert_eq!(s.params(), vec![20.0, 5.0, 4.0, 128.0]);
    }

    #[test]
    fn paper_plane_is_the_paper_default_config() {
        let mut s = Ext4Spec {
            app: AppId::WordCount,
            num_mappers: 20,
            num_reducers: 5,
            input_gb: 8.0,
            block_mb: 64,
        };
        assert!(s.is_paper_plane());
        // The whole cache-soundness argument: on the paper plane the
        // extended config *is* the paper-default config.
        assert_eq!(s.job_config(7), JobConfig::paper_default(20, 5).with_seed(7));
        s.input_gb = 4.0;
        assert!(!s.is_paper_plane());
        s.input_gb = 8.0;
        s.block_mb = 128;
        assert!(!s.is_paper_plane());
    }

    #[test]
    fn random_specs_in_range() {
        let mut rng = Rng::new(1);
        for s in random_ext4(AppId::EximParse, 50, &mut rng) {
            assert!((5..=40).contains(&s.num_mappers));
            assert!((5..=40).contains(&s.num_reducers));
            assert!(s.input_gb >= 1.0 && s.input_gb <= 16.0);
            assert!(BLOCK_MB_CHOICES.contains(&s.block_mb));
        }
    }

    #[test]
    fn bigger_input_costs_more_time_and_cpu() {
        let cluster = Cluster::paper_cluster();
        let mut small = Ext4Spec {
            app: AppId::WordCount,
            num_mappers: 20,
            num_reducers: 5,
            input_gb: 2.0,
            block_mb: 64,
        };
        let a = run_ext4(&cluster, &small, 3, 1);
        small.input_gb = 8.0;
        let b = run_ext4(&cluster, &small, 3, 1);
        assert!(b.mean_time_s > a.mean_time_s);
        assert!(b.mean_cpu_s > a.mean_cpu_s);
        assert!(a.mean_cpu_s > 0.0);
    }

    #[test]
    fn block_size_changes_task_count_and_time() {
        let cluster = Cluster::paper_cluster();
        let base = Ext4Spec {
            app: AppId::WordCount,
            num_mappers: 20,
            num_reducers: 5,
            input_gb: 8.0,
            block_mb: 32,
        };
        let many_tasks = run_ext4(&cluster, &base, 3, 2);
        let few = Ext4Spec { block_mb: 256, ..base };
        let few_tasks = run_ext4(&cluster, &few, 3, 2);
        // 256 tasks vs 32 tasks: per-task startup overhead dominates the
        // small-block configuration.
        assert!(many_tasks.mean_time_s != few_tasks.mean_time_s);
        assert_eq!(base.job_config(0).map_tasks(), 256);
        assert_eq!(few.job_config(0).map_tasks(), 32);
    }
}
