//! Parallel, caching campaign executor.
//!
//! Profiling is the dominant cost of the paper's pipeline: every `(M, R)`
//! setting is simulated [`super::experiment::REPS`] times and averaged
//! (§IV.A), and grid sweeps (Fig. 4) multiply that by 64+ settings.  The
//! executor rebuilds that path around two ideas:
//!
//! 1. **Fan-out.** Repetitions are independent by construction — every
//!    rep derives its seed from `mix(base_seed, spec, rep)` and its HDFS
//!    layout from a session-level [`JobContext`] — so misses fan out over
//!    a `std::thread::scope` worker pool with **work-stealing chunked
//!    dispatch**: chunks are dealt to per-worker deques and idle workers
//!    steal from busy ones, so a skewed grid (one 256-map ext4 setting
//!    among 4-map ones) cannot strand the pool behind one worker.
//!    Results are assembled in input order, making parallel output
//!    **bit-identical** to serial for any worker count and any steal
//!    schedule.
//! 2. **Caching.** Completed reps are cached under `(spec, rep,
//!    base_seed)`, so campaigns that overlap — train/test protocols, grid
//!    sweeps revisiting training settings, scheduler what-if replays —
//!    never re-simulate a setting.
//!
//! The executor runs **any spec shape** through one pipeline: work
//! arrives as [`RepJob`]s whose [`RepSpec`] yields the simulator
//! [`JobConfig`] and the stable [`StoreKey`] material the caches use —
//! the paper's 2-parameter settings ([`RepSpec::Paper`]) and the extended
//! 4-parameter sweeps ([`RepSpec::Ext4`]) both inherit parallelism and
//! persistence from the same code path.
//!
//! With a [`ProfileStore`] attached ([`CampaignExecutor::with_store`]),
//! the miss path consults the on-disk store before simulating and writes
//! fresh results back, so repeated CLI invocations warm-start from every
//! prior session on the machine.  [`CampaignExecutor::stats`] reports the
//! combined in-memory + on-disk picture.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::mr::context::{ContextShape, JobContext};
use crate::mr::cost::AppProfile;
use crate::mr::{run_job_in, JobConfig, RepOutcome};
use crate::util::stats;

use super::campaign::Campaign;
use super::dataset::Dataset;
use super::experiment::{mix, ExperimentResult, ExperimentSpec};
use super::extended::{mix_ext4, Ext4Result, Ext4Spec};
use super::store::{ProfileStore, StoreKey};

/// Order-sensitive digest of every simulation-relevant cluster field.
///
/// Hand-rolled (the same mixing recipe as `experiment::mix`) rather than
/// std's `DefaultHasher` because the value is persisted inside on-disk
/// [`StoreKey`] records: std's hasher algorithm is documented as
/// unstable across Rust releases, and a toolchain upgrade must not
/// silently orphan every stored rep.  Changing this recipe requires
/// bumping [`super::store::STORE_FORMAT_VERSION`].
///
/// Public because every consumer of raw [`StoreKey`]s (the online
/// trainer, store-inspection tools) must derive the *same* fingerprint
/// the executor keyed its records under.
pub fn cluster_fingerprint(cluster: &Cluster) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        let x = h ^ v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x.rotate_left(23).wrapping_mul(0x94D0_49BB_1331_11EB)
    }
    let mut h = 0x6d72_7475_6e65_7221_u64; // "mrtuner!"
    h = mix(h, cluster.num_nodes() as u64);
    for node in &cluster.nodes {
        let s = &node.spec;
        h = mix(h, s.cpu_ghz.to_bits());
        h = mix(h, s.ram_bytes);
        h = mix(h, s.disk_bytes);
        h = mix(h, s.cache_kb);
        h = mix(h, s.disk_read_mbps.to_bits());
        h = mix(h, s.disk_write_mbps.to_bits());
        h = mix(h, s.map_slots as u64);
        h = mix(h, s.reduce_slots as u64);
    }
    h = mix(h, cluster.network.nic_bps.to_bits());
    h = mix(h, cluster.network.fetch_latency_s.to_bits());
    h = mix(h, cluster.network.nodes as u64);
    h
}

/// The setting one repetition profiles — the rep-work abstraction that
/// lets *any* spec shape run through the executor.  A variant supplies
/// two things: the simulator [`JobConfig`] (including its shape's
/// historical per-rep seed derivation) and the stable [`StoreKey`]
/// material the in-memory cache and the persistent store share.
///
/// **Soundness invariant:** a [`StoreKey`] fully determines the
/// `JobConfig` simulated under it.  The key carries every config-relevant
/// coordinate `(app, M, R, input_gb, block_mb, rep, base_seed)` plus the
/// cluster fingerprint, and the seed derivation is a pure function of
/// those coordinates — so two work items with equal keys always describe
/// the *same* simulation and may alias freely.  In particular, an
/// [`RepSpec::Ext4`] setting on the paper plane
/// ([`Ext4Spec::is_paper_plane`]) uses the 2-parameter derivation and is
/// bit-identical to the corresponding [`RepSpec::Paper`] item.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RepSpec {
    /// The paper's 2-parameter shape, at paper-default input/block.
    Paper(ExperimentSpec),
    /// The extended 4-parameter shape (input and block size swept too).
    Ext4(Ext4Spec),
}

impl RepSpec {
    /// Application this setting profiles.
    pub fn app(&self) -> AppId {
        match self {
            RepSpec::Paper(s) => s.app,
            RepSpec::Ext4(s) => s.app,
        }
    }

    /// Persistent identity of one rep of this setting.  Paper-shape reps
    /// key under the paper-default input/block plane — exactly where
    /// records migrated from v1 stores land, so pre-v2 data keeps
    /// answering 2-parameter lookups.
    fn key(&self, cluster_fp: u64, rep: u32, base_seed: u64) -> StoreKey {
        let (app, m, r, input_gb, block_mb) = match self {
            RepSpec::Paper(s) => (
                s.app,
                s.num_mappers,
                s.num_reducers,
                StoreKey::PAPER_INPUT_GB,
                StoreKey::PAPER_BLOCK_MB,
            ),
            RepSpec::Ext4(s) => {
                (s.app, s.num_mappers, s.num_reducers, s.input_gb, s.block_mb)
            }
        };
        StoreKey {
            cluster: cluster_fp,
            app,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: input_gb.to_bits(),
            block_mb,
            rep,
            base_seed,
        }
    }

    /// The simulator config for one repetition, with the shape's
    /// historical seed derivation (bit-compatibility with pre-executor
    /// drivers and with every record already on disk).
    fn config(&self, rep: u32, base_seed: u64) -> JobConfig {
        match self {
            RepSpec::Paper(s) => {
                JobConfig::paper_default(s.num_mappers, s.num_reducers)
                    .with_seed(mix(base_seed, s, rep))
            }
            RepSpec::Ext4(s) if s.is_paper_plane() => {
                // On the paper plane the extended setting *is* the paper
                // setting; deriving the same seed makes the shared
                // StoreKey sound (same key ⇒ same simulation).
                let paper =
                    ExperimentSpec::new(s.app, s.num_mappers, s.num_reducers);
                s.job_config(mix(base_seed, &paper, rep))
            }
            RepSpec::Ext4(s) => s.job_config(mix_ext4(base_seed, s, rep)),
        }
    }
}

/// One unit of executor work: a single repetition of one setting within
/// a profiling session.
#[derive(Clone, Copy, Debug)]
pub struct RepJob {
    /// The setting to simulate.
    pub spec: RepSpec,
    /// Repetition index within the profiling session.
    pub rep: u32,
    /// Profiling-session seed.
    pub base_seed: u64,
}

impl RepJob {
    /// A repetition of a paper-shape (2-parameter) setting.
    pub fn paper(spec: ExperimentSpec, rep: u32, base_seed: u64) -> RepJob {
        RepJob { spec: RepSpec::Paper(spec), rep, base_seed }
    }

    /// A repetition of an extended 4-parameter setting.
    pub fn ext4(spec: Ext4Spec, rep: u32, base_seed: u64) -> RepJob {
        RepJob { spec: RepSpec::Ext4(spec), rep, base_seed }
    }

    fn key(&self, cluster_fp: u64) -> StoreKey {
        self.spec.key(cluster_fp, self.rep, self.base_seed)
    }

    fn config(&self) -> JobConfig {
        self.spec.config(self.rep, self.base_seed)
    }
}

/// Target chunks dealt per worker: enough slack that a worker stuck on
/// an expensive chunk leaves plenty for the others to steal, few enough
/// that queue locking stays negligible next to event simulation.
const CHUNKS_PER_WORKER: usize = 4;
/// Upper bound on one chunk's item count, so a huge campaign still
/// produces steal-able units.
const MAX_CHUNK: usize = 32;

/// Pop the next chunk for worker `wi`: its own deque front first, then a
/// steal from the back of the nearest non-empty victim.  Chunks are never
/// re-queued, so every chunk is executed exactly once and `None` means
/// the whole grid is taken.
fn next_chunk(
    queues: &[Mutex<VecDeque<Range<usize>>>],
    wi: usize,
) -> Option<Range<usize>> {
    if let Some(r) = queues[wi].lock().expect("chunk queue poisoned").pop_front()
    {
        return Some(r);
    }
    let n = queues.len();
    for d in 1..n {
        let victim = (wi + d) % n;
        if let Some(r) =
            queues[victim].lock().expect("chunk queue poisoned").pop_back()
        {
            return Some(r);
        }
    }
    None
}

/// The campaign executor: a worker pool plus a rep-level result cache.
///
/// One executor is meant to live for a whole analysis session (an `e2e`
/// run, a CLI invocation, a service lifetime) so overlapping campaigns
/// share both the cache and the per-session job contexts.  Misses are
/// dispatched to the workers as steal-able chunks, so skewed grids keep
/// every worker busy — with output bit-identical to serial either way.
///
/// ```
/// use mrtuner::apps::AppId;
/// use mrtuner::cluster::Cluster;
/// use mrtuner::profiler::{CampaignExecutor, ExperimentSpec};
///
/// let cluster = Cluster::paper_cluster();
/// let exec = CampaignExecutor::new(2);
/// let specs = [ExperimentSpec::new(AppId::WordCount, 20, 5)];
/// let results = exec.run_specs(&cluster, &specs, 2, 42);
/// assert_eq!(results.len(), 1);
/// assert!(results[0].mean_time_s > 0.0);
/// // Re-running the same profiling session is answered from the cache,
/// // bit-identically.
/// let again = exec.run_specs(&cluster, &specs, 2, 42);
/// assert_eq!(again[0].rep_times_s, results[0].rep_times_s);
/// assert_eq!(exec.cache_hits(), 2);
/// ```
pub struct CampaignExecutor {
    jobs: usize,
    cache: Mutex<HashMap<StoreKey, RepOutcome>>,
    hits: AtomicU64,
    misses: AtomicU64,
    store_hits: AtomicU64,
    store: Option<ProfileStore>,
}

impl CampaignExecutor {
    /// Executor with `jobs` workers (clamped to at least 1).
    pub fn new(jobs: usize) -> CampaignExecutor {
        CampaignExecutor {
            jobs: jobs.max(1),
            cache: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            store: None,
        }
    }

    /// Attach a persistent [`ProfileStore`]: cache misses consult it
    /// before simulating, fresh results are written back, and the store
    /// is flushed at every campaign boundary (and on drop).  Warm output
    /// is bit-identical to cold output — stored values are the very rep
    /// results the executor produced.
    pub fn with_store(mut self, store: ProfileStore) -> CampaignExecutor {
        self.store = Some(store);
        self
    }

    /// The attached persistent store, if any.
    pub fn store(&self) -> Option<&ProfileStore> {
        self.store.as_ref()
    }

    /// Single-worker executor — the serial reference behaviour.
    pub fn serial() -> CampaignExecutor {
        CampaignExecutor::new(1)
    }

    /// Executor sized to the host: one worker per available core.
    pub fn machine_sized() -> CampaignExecutor {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        CampaignExecutor::new(n)
    }

    /// Worker-pool size.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Reps answered from the in-memory cache (including duplicates
    /// coalesced within one call).
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Reps actually simulated so far.
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Reps answered from the persistent store (zero when none attached).
    pub fn store_hits(&self) -> u64 {
        self.store_hits.load(Ordering::Relaxed)
    }

    /// Distinct reps currently in the in-memory cache.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().expect("executor cache poisoned").len()
    }

    /// Combined in-memory **and** on-disk picture of this executor — the
    /// per-instance counters alone under-report once a store is attached
    /// or `--jobs` splits work across calls, so consumers should print
    /// this instead.
    pub fn stats(&self) -> ExecutorStats {
        ExecutorStats {
            jobs: self.jobs,
            simulated: self.cache_misses(),
            mem_hits: self.cache_hits(),
            store_hits: self.store_hits(),
            mem_entries: self.cache_len(),
            store_entries: self.store.as_ref().map(|s| s.len()).unwrap_or(0),
            store_attached: self.store.is_some(),
        }
    }

    /// Flush the attached store's buffered records to disk now (no-op
    /// without a store).  `run_reps` already does this at every campaign
    /// boundary; long-lived services can call it on their own cadence.
    pub fn flush_store(&self) -> Result<(), String> {
        match &self.store {
            Some(s) => s.flush(),
            None => Ok(()),
        }
    }

    /// Simulate every repetition in `items`, returning total execution
    /// times in input order.
    ///
    /// Cached reps are returned without re-simulation; misses fan out over
    /// the worker pool.  Output is bit-identical for any worker count:
    /// each rep's seed and layout derive from `(base_seed, spec, rep)`
    /// alone, never from scheduling order, and results are written back by
    /// input index.
    pub fn run_reps(&self, cluster: &Cluster, items: &[RepJob]) -> Vec<f64> {
        self.run_units(cluster, items, false)
            .iter()
            .map(|o| o.time_s)
            .collect()
    }

    /// Simulate every repetition in `items`, returning full per-rep
    /// outcomes (time **and** CPU seconds) in input order — the entry
    /// point the extended 4-parameter pipeline uses.
    ///
    /// Every returned outcome carries the CPU figure: a cached record
    /// lacking it (data migrated from a v1 store) counts as a miss here
    /// and is re-simulated, upgrading the stored record in place.
    pub fn run_outcomes(
        &self,
        cluster: &Cluster,
        items: &[RepJob],
    ) -> Vec<RepOutcome> {
        self.run_units(cluster, items, true)
    }

    /// Shared engine behind [`CampaignExecutor::run_reps`] and
    /// [`CampaignExecutor::run_outcomes`]: `need_cpu` decides whether a
    /// CPU-less cached outcome may answer, or must be re-simulated.
    fn run_units(
        &self,
        cluster: &Cluster,
        items: &[RepJob],
        need_cpu: bool,
    ) -> Vec<RepOutcome> {
        let cluster_fp = cluster_fingerprint(cluster);
        let usable =
            |o: &RepOutcome| -> bool { !need_cpu || o.cpu_s.is_some() };
        let mut out = vec![RepOutcome::time_only(f64::NAN); items.len()];
        // `todo` holds the first item index per distinct missing key;
        // duplicate items within one call alias the same simulation.
        let mut todo: Vec<usize> = Vec::new();
        let mut alias: Vec<(usize, usize)> = Vec::new();
        let mut store_hit_count: u64 = 0;
        {
            let mut cache = self.cache.lock().expect("executor cache poisoned");
            let mut pending: HashMap<StoreKey, usize> = HashMap::new();
            for (i, item) in items.iter().enumerate() {
                let key = item.key(cluster_fp);
                if let Some(o) = cache.get(&key).copied().filter(&usable) {
                    out[i] = o;
                } else if let Some(o) = self
                    .store
                    .as_ref()
                    .and_then(|s| s.get(&key))
                    .filter(&usable)
                {
                    // On-disk hit: promote into the in-memory cache so
                    // repeats within this session are memory-speed.
                    out[i] = o;
                    cache.insert(key, o);
                    store_hit_count += 1;
                } else if let Some(&k) = pending.get(&key) {
                    alias.push((i, k));
                } else {
                    pending.insert(key, todo.len());
                    todo.push(i);
                }
            }
        }
        self.store_hits.fetch_add(store_hit_count, Ordering::Relaxed);
        self.hits.fetch_add(
            items.len() as u64 - todo.len() as u64 - store_hit_count,
            Ordering::Relaxed,
        );
        self.misses.fetch_add(todo.len() as u64, Ordering::Relaxed);
        if todo.is_empty() {
            return out;
        }

        // Build each distinct (shape, session) context and each distinct
        // app profile once, up front and serially, so workers only pay for
        // event simulation — the JobContext reuse contract.  `ctx_keys[k]`
        // and `cfgs[k]` resolve todo item `k` without re-deriving anything.
        let mut contexts: HashMap<(ContextShape, u64), JobContext> = HashMap::new();
        let mut profiles: HashMap<AppId, AppProfile> = HashMap::new();
        let mut ctx_keys: Vec<(ContextShape, u64)> = Vec::with_capacity(todo.len());
        let mut cfgs: Vec<JobConfig> = Vec::with_capacity(todo.len());
        for &i in &todo {
            let item = &items[i];
            let config = item.config();
            let key = (ContextShape::of(cluster, &config), item.base_seed);
            contexts
                .entry(key)
                .or_insert_with(|| JobContext::for_session(cluster, &config, item.base_seed));
            profiles
                .entry(item.spec.app())
                .or_insert_with(|| item.spec.app().profile());
            ctx_keys.push(key);
            cfgs.push(config);
        }

        // Each todo item k simulates items[todo[k]] against its context.
        let run_one = |k: usize| -> RepOutcome {
            let item = &items[todo[k]];
            let ctx = &contexts[&ctx_keys[k]];
            let profile = &profiles[&item.spec.app()];
            run_job_in(cluster, profile, &cfgs[k], ctx).rep_outcome()
        };

        let workers = self.jobs.min(todo.len());
        if workers <= 1 {
            for k in 0..todo.len() {
                out[todo[k]] = run_one(k);
            }
        } else {
            // Work-stealing chunked dispatch.  Contiguous index chunks are
            // dealt round-robin onto per-worker deques up front; a worker
            // drains its own deque from the front and, when empty, steals
            // from the back of a victim's.  Chunks amortize queue locking
            // on dense grids; stealing keeps every worker busy on skewed
            // ones (an ext4 sweep mixes 256-map settings with 4-map ones,
            // so equal-share splits leave workers idle).  Output stays
            // bit-identical to serial because results are written back by
            // input index — scheduling order never touches the data.
            let chunk = (todo.len() / (workers * CHUNKS_PER_WORKER))
                .clamp(1, MAX_CHUNK);
            let queues: Vec<Mutex<VecDeque<Range<usize>>>> =
                (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
            {
                let mut lo = 0;
                let mut w = 0;
                while lo < todo.len() {
                    let hi = (lo + chunk).min(todo.len());
                    queues[w % workers]
                        .lock()
                        .expect("chunk queue poisoned")
                        .push_back(lo..hi);
                    w += 1;
                    lo = hi;
                }
            }
            let computed: Vec<(usize, RepOutcome)> = std::thread::scope(|scope| {
                let run_one = &run_one;
                let todo = &todo;
                let queues = &queues[..];
                let handles: Vec<_> = (0..workers)
                    .map(|wi| {
                        scope.spawn(move || {
                            let mut local = Vec::new();
                            while let Some(range) = next_chunk(queues, wi) {
                                for k in range {
                                    local.push((todo[k], run_one(k)));
                                }
                            }
                            local
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("executor worker panicked"))
                    .collect()
            });
            for (i, o) in computed {
                out[i] = o;
            }
        }

        for &(i, k) in &alias {
            out[i] = out[todo[k]];
        }

        {
            let mut cache = self.cache.lock().expect("executor cache poisoned");
            for &i in &todo {
                cache.insert(items[i].key(cluster_fp), out[i]);
            }
        }
        // Write fresh results through to the persistent store and flush:
        // every run_reps/run_outcomes call is a campaign boundary, and a
        // flush here means a crash later never loses completed work.
        if let Some(store) = &self.store {
            for &i in &todo {
                store.put(items[i].key(cluster_fp), out[i]);
            }
            if let Err(e) = store.flush() {
                eprintln!("warn: profile store flush failed: {e}");
            }
        }
        out
    }

    /// Run `reps` repetitions of every spec (one profiling session keyed
    /// by `base_seed`), returning per-spec averaged results in spec order.
    pub fn run_specs(
        &self,
        cluster: &Cluster,
        specs: &[ExperimentSpec],
        reps: u32,
        base_seed: u64,
    ) -> Vec<ExperimentResult> {
        let items: Vec<RepJob> = specs
            .iter()
            .flat_map(|s| (0..reps).map(move |rep| RepJob::paper(*s, rep, base_seed)))
            .collect();
        let times = self.run_reps(cluster, &items);
        specs
            .iter()
            .enumerate()
            .map(|(si, s)| {
                let lo = si * reps as usize;
                let rep_times_s = times[lo..lo + reps as usize].to_vec();
                ExperimentResult {
                    spec: *s,
                    mean_time_s: stats::mean(&rep_times_s),
                    rep_times_s,
                }
            })
            .collect()
    }

    /// Run a whole campaign, returning raw results and the fitted-on
    /// dataset — the executor-backed replacement for `Campaign::run`.
    pub fn run_campaign(
        &self,
        cluster: &Cluster,
        campaign: &Campaign,
    ) -> (Vec<ExperimentResult>, Dataset) {
        let results =
            self.run_specs(cluster, &campaign.specs, campaign.reps, campaign.base_seed);
        let ds = Dataset::from_results(campaign.app, &results);
        (results, ds)
    }

    /// Run `reps` repetitions of every extended 4-parameter setting (one
    /// profiling session keyed by `base_seed`), returning per-spec
    /// averaged results — both modeled outputs — in spec order.
    ///
    /// Same contract as [`CampaignExecutor::run_specs`]: parallel output
    /// is bit-identical to serial, overlapping sweeps hit the rep cache,
    /// and an attached [`ProfileStore`] warm-starts later processes.
    pub fn run_ext4_specs(
        &self,
        cluster: &Cluster,
        specs: &[Ext4Spec],
        reps: u32,
        base_seed: u64,
    ) -> Vec<Ext4Result> {
        let items: Vec<RepJob> = specs
            .iter()
            .flat_map(|s| (0..reps).map(move |rep| RepJob::ext4(*s, rep, base_seed)))
            .collect();
        let outcomes = self.run_outcomes(cluster, &items);
        specs
            .iter()
            .enumerate()
            .map(|(si, s)| {
                let lo = si * reps as usize;
                let chunk = &outcomes[lo..lo + reps as usize];
                let times: Vec<f64> = chunk.iter().map(|o| o.time_s).collect();
                let cpus: Vec<f64> = chunk
                    .iter()
                    .map(|o| {
                        o.cpu_s.expect("run_outcomes returns full outcomes")
                    })
                    .collect();
                Ext4Result {
                    spec: *s,
                    mean_time_s: stats::mean(&times),
                    mean_cpu_s: stats::mean(&cpus),
                }
            })
            .collect()
    }

    /// Run a whole extended campaign, returning regression rows plus the
    /// two modeled outputs — the executor-backed replacement for the old
    /// serial `extended::run_ext4_campaign` driver.
    pub fn run_ext4_campaign(
        &self,
        cluster: &Cluster,
        specs: &[Ext4Spec],
        reps: u32,
        base_seed: u64,
    ) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>) {
        let results = self.run_ext4_specs(cluster, specs, reps, base_seed);
        let rows = specs.iter().map(|s| s.params()).collect();
        let times = results.iter().map(|r| r.mean_time_s).collect();
        let cpus = results.iter().map(|r| r.mean_cpu_s).collect();
        (rows, times, cpus)
    }
}

/// Combined in-memory + on-disk executor counters, for CLI/e2e/scheduler
/// reporting.  `simulated` is the work actually done; `mem_hits` and
/// `store_hits` are the work avoided, split by which layer answered.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    /// Worker-pool size.
    pub jobs: usize,
    /// Reps simulated fresh (the executor's `cache_misses`).
    pub simulated: u64,
    /// Reps answered by the in-memory cache (incl. coalesced duplicates).
    pub mem_hits: u64,
    /// Reps answered by the persistent store.
    pub store_hits: u64,
    /// Distinct reps in the in-memory cache.
    pub mem_entries: usize,
    /// Distinct reps in the persistent store (0 when none attached).
    pub store_entries: usize,
    /// Whether a persistent store is attached.
    pub store_attached: bool,
}

impl fmt::Display for ExecutorStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "jobs={} simulated={} mem_hits={} store_hits={} mem_entries={} \
             store_entries={} store={}",
            self.jobs,
            self.simulated,
            self.mem_hits,
            self.store_hits,
            self.mem_entries,
            self.store_entries,
            if self.store_attached { "on" } else { "off" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(m: u32, r: u32) -> ExperimentSpec {
        ExperimentSpec::new(AppId::WordCount, m, r)
    }

    #[test]
    fn serial_and_parallel_reps_are_bit_identical() {
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5), spec(35, 30)];
        let serial = CampaignExecutor::serial().run_specs(&cluster, &specs, 3, 11);
        for jobs in [2, 4] {
            let par = CampaignExecutor::new(jobs).run_specs(&cluster, &specs, 3, 11);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.rep_times_s, b.rep_times_s, "jobs={jobs}");
                assert_eq!(a.mean_time_s, b.mean_time_s, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        let specs = [spec(10, 10), spec(20, 5)];
        exec.run_specs(&cluster, &specs, 2, 3);
        assert_eq!(exec.cache_misses(), 4);
        assert_eq!(exec.cache_hits(), 0);
        assert_eq!(exec.cache_len(), 4);
        // Re-running the same session is pure cache.
        let again = exec.run_specs(&cluster, &specs, 2, 3);
        assert_eq!(exec.cache_misses(), 4);
        assert_eq!(exec.cache_hits(), 4);
        assert!(again.iter().all(|r| r.rep_times_s.iter().all(|t| t.is_finite())));
        // A different session seed must not hit.
        exec.run_specs(&cluster, &specs, 2, 4);
        assert_eq!(exec.cache_misses(), 8);
        assert_eq!(exec.cache_hits(), 4);
    }

    #[test]
    fn cached_values_equal_fresh_computation() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        let warm = exec.run_specs(&cluster, &[spec(20, 5)], 2, 9);
        let cached = exec.run_specs(&cluster, &[spec(20, 5)], 2, 9);
        let fresh = CampaignExecutor::serial().run_specs(&cluster, &[spec(20, 5)], 2, 9);
        assert_eq!(warm[0].rep_times_s, cached[0].rep_times_s);
        assert_eq!(warm[0].rep_times_s, fresh[0].rep_times_s);
    }

    #[test]
    fn duplicate_items_in_one_call_are_coalesced() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(4);
        let items = [RepJob::paper(spec(20, 5), 0, 1); 3];
        let times = exec.run_reps(&cluster, &items);
        assert_eq!(exec.cache_misses(), 1, "one simulation for three duplicates");
        assert_eq!(exec.cache_hits(), 2);
        assert_eq!(times[0], times[1]);
        assert_eq!(times[1], times[2]);
    }

    #[test]
    fn cache_is_cluster_aware() {
        let paper = Cluster::paper_cluster();
        let mut big = Cluster::paper_cluster();
        for n in &mut big.nodes {
            n.spec.map_slots += 2;
        }
        let exec = CampaignExecutor::serial();
        let a = exec.run_specs(&paper, &[spec(20, 5)], 1, 7);
        let b = exec.run_specs(&big, &[spec(20, 5)], 1, 7);
        // Same (spec, rep, base_seed) on a different cluster must be a
        // fresh simulation, not a stale hit.
        assert_eq!(exec.cache_misses(), 2);
        assert_eq!(exec.cache_hits(), 0);
        assert_ne!(a[0].rep_times_s, b[0].rep_times_s);
    }

    #[test]
    fn ext4_serial_and_parallel_are_bit_identical() {
        let cluster = Cluster::paper_cluster();
        let specs = [
            Ext4Spec {
                app: AppId::WordCount,
                num_mappers: 20,
                num_reducers: 5,
                input_gb: 2.0,
                block_mb: 64,
            },
            Ext4Spec {
                app: AppId::WordCount,
                num_mappers: 10,
                num_reducers: 30,
                input_gb: 4.5,
                block_mb: 128,
            },
        ];
        let serial =
            CampaignExecutor::serial().run_ext4_specs(&cluster, &specs, 3, 11);
        for jobs in [2, 4] {
            let par = CampaignExecutor::new(jobs)
                .run_ext4_specs(&cluster, &specs, 3, 11);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.mean_time_s.to_bits(), b.mean_time_s.to_bits());
                assert_eq!(a.mean_cpu_s.to_bits(), b.mean_cpu_s.to_bits());
            }
        }
    }

    #[test]
    fn paper_plane_ext4_aliases_paper_reps() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        // 2-parameter campaign first: reps cached with full outcomes.
        let paper = exec.run_specs(&cluster, &[spec(20, 5)], 2, 7);
        assert_eq!(exec.cache_misses(), 2);
        // The same point of the 4-D space at paper-default input/block is
        // the same simulation: pure cache, bit-identical times.
        let e = Ext4Spec {
            app: AppId::WordCount,
            num_mappers: 20,
            num_reducers: 5,
            input_gb: 8.0,
            block_mb: 64,
        };
        assert!(e.is_paper_plane());
        let ext = exec.run_ext4_specs(&cluster, &[e], 2, 7);
        assert_eq!(exec.cache_misses(), 2, "no new simulation");
        assert_eq!(exec.cache_hits(), 2);
        assert_eq!(ext[0].mean_time_s.to_bits(), paper[0].mean_time_s.to_bits());
        // Off the paper plane the key differs and a fresh sim runs.
        let off = Ext4Spec { block_mb: 128, ..e };
        exec.run_ext4_specs(&cluster, &[off], 2, 7);
        assert_eq!(exec.cache_misses(), 4);
    }

    #[test]
    fn cpu_less_store_records_answer_times_but_not_outcomes() {
        let base = std::env::temp_dir()
            .join(format!("mrtuner_exec_v1up_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let dir_a = base.join("a");
        let dir_b = base.join("b");
        let cluster = Cluster::paper_cluster();
        let item = RepJob::paper(spec(20, 5), 0, 3);

        // Cold run into store A to learn the executor-derived key and the
        // full outcome under it.
        {
            let exec = CampaignExecutor::serial()
                .with_store(ProfileStore::open(&dir_a).unwrap());
            exec.run_reps(&cluster, &[item]);
        }
        let (key, full) = {
            let mut records = Vec::new();
            for p in std::fs::read_dir(&dir_a)
                .unwrap()
                .map(|e| e.unwrap().path())
                .filter(|p| p.extension().is_some_and(|x| x == "bin"))
            {
                records
                    .extend(super::super::store::read_file_records(&p).unwrap());
            }
            let (k, o, _) = records.into_iter().next().unwrap();
            (k, o)
        };
        assert!(full.cpu_s.is_some(), "executor stores full outcomes");

        // Store B holds the same record *without* the CPU figure — what a
        // migrated v1 store looks like after open.
        std::fs::create_dir_all(&dir_b).unwrap();
        std::fs::write(
            dir_b.join("index.jsonl"),
            format!(
                "{}\n",
                super::super::store::encode_record(
                    &key,
                    &RepOutcome::time_only(full.time_s)
                )
            ),
        )
        .unwrap();

        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir_b).unwrap());
        // Time-only consumers are answered from the CPU-less record ...
        let times = exec.run_reps(&cluster, &[item]);
        assert_eq!(exec.cache_misses(), 0);
        assert_eq!(exec.store_hits(), 1);
        assert_eq!(times[0].to_bits(), full.time_s.to_bits());
        // ... but an outcome consumer re-simulates and upgrades in place.
        let outs = exec.run_outcomes(&cluster, &[item]);
        assert_eq!(exec.cache_misses(), 1, "CPU-less entry is a miss here");
        assert!(outs[0].same_bits(&full), "re-simulation is bit-identical");
        assert_eq!(
            exec.store().unwrap().get(&key),
            Some(full),
            "stored record upgraded with the CPU figure"
        );
        drop(exec);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn executor_clamps_zero_jobs() {
        assert_eq!(CampaignExecutor::new(0).jobs(), 1);
        assert!(CampaignExecutor::machine_sized().jobs() >= 1);
    }

    #[test]
    fn stats_combine_memory_and_store() {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_exec_stats_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5)];
        {
            let exec = CampaignExecutor::new(2)
                .with_store(ProfileStore::open(&dir).unwrap());
            exec.run_specs(&cluster, &specs, 2, 3);
            let st = exec.stats();
            assert_eq!(st.simulated, 4);
            assert_eq!(st.mem_hits, 0);
            assert_eq!(st.store_hits, 0);
            assert_eq!(st.mem_entries, 4);
            assert_eq!(st.store_entries, 4, "fresh reps written through");
            assert!(st.store_attached);
            assert!(st.to_string().contains("simulated=4"));
        }
        // A second executor on the same directory answers purely from
        // disk: zero simulations, bit-identical results.
        let cold = CampaignExecutor::serial().run_specs(&cluster, &specs, 2, 3);
        let exec2 = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        let warm = exec2.run_specs(&cluster, &specs, 2, 3);
        let st = exec2.stats();
        assert_eq!(st.simulated, 0);
        assert_eq!(st.store_hits, 4);
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.rep_times_s, b.rep_times_s);
        }
        drop(exec2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn skewed_grid_work_stealing_is_bit_identical_and_complete() {
        // A deliberately skewed grid: one 256-map monster among cheap
        // 4-map settings, at worker counts that do not divide the item
        // count.  Every item must be simulated exactly once and the
        // output must match serial bit for bit whatever got stolen.
        let cluster = Cluster::paper_cluster();
        let specs: Vec<Ext4Spec> = (0..9)
            .map(|i| Ext4Spec {
                app: AppId::WordCount,
                num_mappers: 5 + i,
                num_reducers: 5,
                input_gb: if i == 0 { 8.0 } else { 1.0 },
                block_mb: if i == 0 { 32 } else { 256 },
            })
            .collect();
        let serial =
            CampaignExecutor::serial().run_ext4_specs(&cluster, &specs, 1, 13);
        for jobs in [3, 8] {
            let exec = CampaignExecutor::new(jobs);
            let par = exec.run_ext4_specs(&cluster, &specs, 1, 13);
            assert_eq!(exec.cache_misses(), 9, "jobs={jobs}: each item once");
            assert_eq!(exec.cache_hits(), 0, "jobs={jobs}");
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.mean_time_s.to_bits(), b.mean_time_s.to_bits());
                assert_eq!(a.mean_cpu_s.to_bits(), b.mean_cpu_s.to_bits());
            }
        }
    }

    #[test]
    fn chunk_queues_hand_out_every_range_exactly_once() {
        let queues: Vec<Mutex<VecDeque<Range<usize>>>> =
            (0..3).map(|_| Mutex::new(VecDeque::new())).collect();
        for (w, lo) in (0..10).enumerate() {
            queues[w % 3]
                .lock()
                .unwrap()
                .push_back(lo * 2..lo * 2 + 2);
        }
        // Worker 1 drains everything (its own queue plus steals).
        let mut seen = Vec::new();
        while let Some(r) = next_chunk(&queues, 1) {
            seen.extend(r);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
        // And every queue is now empty for the other workers too.
        assert!(next_chunk(&queues, 0).is_none());
        assert!(next_chunk(&queues, 2).is_none());
    }

    #[test]
    fn storeless_executor_stats_read_off() {
        let exec = CampaignExecutor::serial();
        let st = exec.stats();
        assert!(!st.store_attached);
        assert_eq!(st.store_entries, 0);
        assert!(st.to_string().contains("store=off"));
        assert!(exec.flush_store().is_ok(), "flush without store is a no-op");
    }
}
