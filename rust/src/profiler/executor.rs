//! Parallel, caching campaign executor.
//!
//! Profiling is the dominant cost of the paper's pipeline: every `(M, R)`
//! setting is simulated [`super::experiment::REPS`] times and averaged
//! (§IV.A), and grid sweeps (Fig. 4) multiply that by 64+ settings.  The
//! executor rebuilds that path around two ideas:
//!
//! 1. **Fan-out.** Repetitions are independent by construction — every
//!    rep derives its seed from `mix(base_seed, spec, rep)` and its HDFS
//!    layout from a session-level [`JobContext`] — so misses fan out over
//!    a `std::thread::scope` worker pool with **work-stealing chunked
//!    dispatch**: chunks are dealt to per-worker deques and idle workers
//!    steal from busy ones, so a skewed grid (one 256-map ext4 setting
//!    among 4-map ones) cannot strand the pool behind one worker.
//!    Results are assembled in input order, making parallel output
//!    **bit-identical** to serial for any worker count and any steal
//!    schedule.
//! 2. **Caching.** Completed reps are cached under `(spec, rep,
//!    base_seed)`, so campaigns that overlap — train/test protocols, grid
//!    sweeps revisiting training settings, scheduler what-if replays —
//!    never re-simulate a setting.
//!
//! The executor runs **any spec shape** through one pipeline: work
//! arrives as [`RepJob`]s whose [`RepSpec`] yields the simulator
//! [`JobConfig`] and the stable [`StoreKey`] material the caches use —
//! the paper's 2-parameter settings ([`RepSpec::Paper`]) and the extended
//! 4-parameter sweeps ([`RepSpec::Ext4`]) both inherit parallelism and
//! persistence from the same code path.
//!
//! With a [`ProfileStore`] attached ([`CampaignExecutor::with_store`]),
//! the miss path consults the on-disk store before simulating and writes
//! fresh results back — **incrementally**, one rep at a time with
//! chunk-grain flushes, so the store journal doubles as a campaign
//! checkpoint: a SIGKILL'd campaign re-run (`--resume`) re-simulates
//! nothing that completed ([`CampaignExecutor::resume_status`] reports
//! the diff).  Each rep runs under `catch_unwind` fault isolation with a
//! bounded [`RetryPolicy`]; reps that keep failing are quarantined into
//! the dead-letter queue ([`super::dlq`]) instead of aborting the run.
//! With [`CampaignExecutor::with_cooperative`], N processes sharing one
//! store split a campaign via per-setting lease files.
//! [`CampaignExecutor::stats`] reports the combined in-memory + on-disk
//! picture.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;
use std::fs::{self, OpenOptions};
use std::io::Write;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::mr::context::{ContextShape, JobContext};
use crate::mr::cost::AppProfile;
use crate::mr::{fault, run_job_in, JobConfig, RepOutcome};
use crate::util::stats;

use super::campaign::Campaign;
use super::dataset::Dataset;
use super::dlq::{self, DlqRecord};
use super::experiment::{
    mix, ExperimentResult, ExperimentSpec, FullExperimentResult,
};
use super::extended::{ext4_rep_jobs, mix_ext4, Ext4Result, Ext4Spec};
use super::store::{pid_alive, ProfileStore, StoreKey};

/// Order-sensitive digest of every simulation-relevant cluster field.
///
/// Hand-rolled (the same mixing recipe as `experiment::mix`) rather than
/// std's `DefaultHasher` because the value is persisted inside on-disk
/// [`StoreKey`] records: std's hasher algorithm is documented as
/// unstable across Rust releases, and a toolchain upgrade must not
/// silently orphan every stored rep.  Changing this recipe requires
/// bumping [`super::store::STORE_FORMAT_VERSION`].
///
/// Public because every consumer of raw [`StoreKey`]s (the online
/// trainer, store-inspection tools) must derive the *same* fingerprint
/// the executor keyed its records under.
pub fn cluster_fingerprint(cluster: &Cluster) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        let x = h ^ v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x.rotate_left(23).wrapping_mul(0x94D0_49BB_1331_11EB)
    }
    let mut h = 0x6d72_7475_6e65_7221_u64; // "mrtuner!"
    h = mix(h, cluster.num_nodes() as u64);
    for node in &cluster.nodes {
        let s = &node.spec;
        h = mix(h, s.cpu_ghz.to_bits());
        h = mix(h, s.ram_bytes);
        h = mix(h, s.disk_bytes);
        h = mix(h, s.cache_kb);
        h = mix(h, s.disk_read_mbps.to_bits());
        h = mix(h, s.disk_write_mbps.to_bits());
        h = mix(h, s.map_slots as u64);
        h = mix(h, s.reduce_slots as u64);
    }
    h = mix(h, cluster.network.nic_bps.to_bits());
    h = mix(h, cluster.network.fetch_latency_s.to_bits());
    h = mix(h, cluster.network.nodes as u64);
    h
}

/// The setting one repetition profiles — the rep-work abstraction that
/// lets *any* spec shape run through the executor.  A variant supplies
/// two things: the simulator [`JobConfig`] (including its shape's
/// historical per-rep seed derivation) and the stable [`StoreKey`]
/// material the in-memory cache and the persistent store share.
///
/// **Soundness invariant:** a [`StoreKey`] fully determines the
/// `JobConfig` simulated under it.  The key carries every config-relevant
/// coordinate `(app, M, R, input_gb, block_mb, rep, base_seed)` plus the
/// cluster fingerprint, and the seed derivation is a pure function of
/// those coordinates — so two work items with equal keys always describe
/// the *same* simulation and may alias freely.  In particular, an
/// [`RepSpec::Ext4`] setting on the paper plane
/// ([`Ext4Spec::is_paper_plane`]) uses the 2-parameter derivation and is
/// bit-identical to the corresponding [`RepSpec::Paper`] item.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RepSpec {
    /// The paper's 2-parameter shape, at paper-default input/block.
    Paper(ExperimentSpec),
    /// The extended 4-parameter shape (input and block size swept too).
    Ext4(Ext4Spec),
}

impl RepSpec {
    /// Application this setting profiles.
    pub fn app(&self) -> AppId {
        match self {
            RepSpec::Paper(s) => s.app,
            RepSpec::Ext4(s) => s.app,
        }
    }

    /// Persistent identity of one rep of this setting.  Paper-shape reps
    /// key under the paper-default input/block plane — exactly where
    /// records migrated from v1 stores land, so pre-v2 data keeps
    /// answering 2-parameter lookups.
    fn key(&self, cluster_fp: u64, rep: u32, base_seed: u64) -> StoreKey {
        let (app, m, r, input_gb, block_mb) = match self {
            RepSpec::Paper(s) => (
                s.app,
                s.num_mappers,
                s.num_reducers,
                StoreKey::PAPER_INPUT_GB,
                StoreKey::PAPER_BLOCK_MB,
            ),
            RepSpec::Ext4(s) => {
                (s.app, s.num_mappers, s.num_reducers, s.input_gb, s.block_mb)
            }
        };
        StoreKey {
            cluster: cluster_fp,
            app,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: input_gb.to_bits(),
            block_mb,
            rep,
            base_seed,
        }
    }

    /// The simulator config for one repetition, with the shape's
    /// historical seed derivation (bit-compatibility with pre-executor
    /// drivers and with every record already on disk).
    fn config(&self, rep: u32, base_seed: u64) -> JobConfig {
        match self {
            RepSpec::Paper(s) => {
                JobConfig::paper_default(s.num_mappers, s.num_reducers)
                    .with_seed(mix(base_seed, s, rep))
            }
            RepSpec::Ext4(s) if s.is_paper_plane() => {
                // On the paper plane the extended setting *is* the paper
                // setting; deriving the same seed makes the shared
                // StoreKey sound (same key ⇒ same simulation).
                let paper =
                    ExperimentSpec::new(s.app, s.num_mappers, s.num_reducers);
                s.job_config(mix(base_seed, &paper, rep))
            }
            RepSpec::Ext4(s) => s.job_config(mix_ext4(base_seed, s, rep)),
        }
    }
}

/// One unit of executor work: a single repetition of one setting within
/// a profiling session.
#[derive(Clone, Copy, Debug)]
pub struct RepJob {
    /// The setting to simulate.
    pub spec: RepSpec,
    /// Repetition index within the profiling session.
    pub rep: u32,
    /// Profiling-session seed.
    pub base_seed: u64,
}

impl RepJob {
    /// A repetition of a paper-shape (2-parameter) setting.
    pub fn paper(spec: ExperimentSpec, rep: u32, base_seed: u64) -> RepJob {
        RepJob { spec: RepSpec::Paper(spec), rep, base_seed }
    }

    /// A repetition of an extended 4-parameter setting.
    pub fn ext4(spec: Ext4Spec, rep: u32, base_seed: u64) -> RepJob {
        RepJob { spec: RepSpec::Ext4(spec), rep, base_seed }
    }

    fn key(&self, cluster_fp: u64) -> StoreKey {
        self.spec.key(cluster_fp, self.rep, self.base_seed)
    }

    fn config(&self) -> JobConfig {
        self.spec.config(self.rep, self.base_seed)
    }
}

/// Target chunks dealt per worker: enough slack that a worker stuck on
/// an expensive chunk leaves plenty for the others to steal, few enough
/// that queue locking stays negligible next to event simulation.
const CHUNKS_PER_WORKER: usize = 4;
/// Upper bound on one chunk's item count, so a huge campaign still
/// produces steal-able units.
const MAX_CHUNK: usize = 32;

/// Pop the next chunk for worker `wi`: its own deque front first, then a
/// steal from the back of the nearest non-empty victim.  Chunks are never
/// re-queued, so every chunk is executed exactly once and `None` means
/// the whole grid is taken.
fn next_chunk(
    queues: &[Mutex<VecDeque<Range<usize>>>],
    wi: usize,
) -> Option<Range<usize>> {
    if let Some(r) = queues[wi].lock().expect("chunk queue poisoned").pop_front()
    {
        return Some(r);
    }
    let n = queues.len();
    for d in 1..n {
        let victim = (wi + d) % n;
        if let Some(r) =
            queues[victim].lock().expect("chunk queue poisoned").pop_back()
        {
            return Some(r);
        }
    }
    None
}

/// Bounded retry policy for a failing repetition: how many times the
/// executor attempts a rep before quarantining it into the dead-letter
/// queue, and how long it backs off between attempts.
///
/// The default — two attempts, 25 ms apart — retries once on the theory
/// that a panic may be environmental (resource exhaustion in a worker)
/// while a *deterministic* failure will fail identically and should
/// reach the DLQ quickly rather than stall the campaign.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per rep (clamped to at least 1).
    pub max_attempts: u32,
    /// Sleep between consecutive attempts of one rep.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 2, backoff: Duration::from_millis(25) }
    }
}

/// Render a caught panic payload (the two shapes `panic!` produces).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    match payload.downcast::<String>() {
        Ok(s) => *s,
        Err(p) => match p.downcast::<&'static str>() {
            Ok(s) => (*s).to_string(),
            Err(_) => "panic payload of unknown type".to_string(),
        },
    }
}

/// One rep that exhausted its retry budget (index into `todo`).
struct Quarantine {
    k: usize,
    attempts: u32,
    error: String,
}

/// Sentinel returned for a quarantined rep: NaN time and CPU, byte
/// counters absent.  Campaign means containing it go NaN — visibly
/// poisoned, never silently wrong — and byte-means go `None`, while the
/// campaign itself completes.  It is never cached or stored, so a later
/// resume (or `dlq retry`) re-dispatches the rep.
fn quarantined_outcome() -> RepOutcome {
    RepOutcome::full(f64::NAN, f64::NAN)
}

/// What a cached [`RepOutcome`] must carry to answer a dispatch without
/// re-simulation.  Partial records (earlier store formats) still answer
/// the paths that don't need the missing figures — which is what keeps
/// the paper's `time_s` pipeline zero-re-simulation and bit-identical
/// across format migrations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Need {
    /// Any record answers: the paper's 2-parameter time path.
    Time,
    /// CPU seconds required (the ext4 pipeline); v1-migrated records
    /// re-simulate and upgrade in place.
    Cpu,
    /// CPU *and* byte counters required (multi-target profiling);
    /// pre-v4 records re-simulate and upgrade in place.
    Full,
}

impl Need {
    fn usable(self, o: &RepOutcome) -> bool {
        match self {
            Need::Time => true,
            Need::Cpu => o.cpu_s.is_some(),
            Need::Full => o.cpu_s.is_some() && o.bytes.is_some(),
        }
    }
}

// ------------------------------------------------ cooperative leases
//
// Cooperative drain lets N independent processes share one campaign by
// claiming per-setting **lease files** under `<store>/leases/` — the
// same create-new + pid-liveness protocol the store's segment locks
// use.  The lease name hashes every key coordinate *except* the rep
// index, so a setting's whole rep block moves as one claim and the
// name is stable across processes whatever their private dispatch
// order — which is what makes combined `simulated` counts cover the
// grid exactly, with no double simulation in the fault-free case.

/// Stable file name of the lease covering every rep of one setting
/// (`key` with its rep component ignored).  Same mixing recipe as
/// [`cluster_fingerprint`] — the name must agree across processes and
/// toolchains, so std's unstable hasher is out.
fn lease_name(key: &StoreKey) -> String {
    fn mix(h: u64, v: u64) -> u64 {
        let x = h ^ v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x.rotate_left(29).wrapping_mul(0x94D0_49BB_1331_11EB)
    }
    let mut h = 0x6c65_6173_6573_2121_u64; // "leases!!"
    h = mix(h, key.cluster);
    h = mix(h, key.app as u64);
    h = mix(h, key.num_mappers as u64);
    h = mix(h, key.num_reducers as u64);
    h = mix(h, key.input_gb_bits);
    h = mix(h, key.block_mb as u64);
    h = mix(h, key.base_seed);
    format!("lease-{h:016x}.lock")
}

/// Atomically claim a lease: create-new the file and write our pid.
fn try_claim_lease(path: &Path) -> bool {
    match OpenOptions::new().write(true).create_new(true).open(path) {
        Ok(mut f) => {
            let _ = writeln!(f, "{}", std::process::id());
            true
        }
        Err(_) => false,
    }
}

/// Whether a lease is held by a **live** process.  Mirrors the store's
/// segment-lock semantics: a missing file is free, an unreadable or
/// not-yet-written one is assumed live (it may be mid-creation), and a
/// pid-bearing one is as alive as its pid.
fn lease_is_live(path: &Path) -> bool {
    match fs::read_to_string(path) {
        Err(_) => path.exists(),
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid_alive(pid),
            Err(_) => true,
        },
    }
}

/// The campaign executor: a worker pool plus a rep-level result cache.
///
/// One executor is meant to live for a whole analysis session (an `e2e`
/// run, a CLI invocation, a service lifetime) so overlapping campaigns
/// share both the cache and the per-session job contexts.  Misses are
/// dispatched to the workers as steal-able chunks, so skewed grids keep
/// every worker busy — with output bit-identical to serial either way.
///
/// ```
/// use mrtuner::apps::AppId;
/// use mrtuner::cluster::Cluster;
/// use mrtuner::profiler::{CampaignExecutor, ExperimentSpec};
///
/// let cluster = Cluster::paper_cluster();
/// let exec = CampaignExecutor::new(2);
/// let specs = [ExperimentSpec::new(AppId::WordCount, 20, 5)];
/// let results = exec.run_specs(&cluster, &specs, 2, 42);
/// assert_eq!(results.len(), 1);
/// assert!(results[0].mean_time_s > 0.0);
/// // Re-running the same profiling session is answered from the cache,
/// // bit-identically.
/// let again = exec.run_specs(&cluster, &specs, 2, 42);
/// assert_eq!(again[0].rep_times_s, results[0].rep_times_s);
/// assert_eq!(exec.cache_hits(), 2);
/// ```
pub struct CampaignExecutor {
    jobs: usize,
    cache: Mutex<BTreeMap<StoreKey, RepOutcome>>,
    hits: AtomicU64,
    misses: AtomicU64,
    store_hits: AtomicU64,
    quarantined: AtomicU64,
    retry: RetryPolicy,
    cooperative: bool,
    store: Option<ProfileStore>,
}

impl CampaignExecutor {
    /// Executor with `jobs` workers (clamped to at least 1).
    pub fn new(jobs: usize) -> CampaignExecutor {
        CampaignExecutor {
            jobs: jobs.max(1),
            cache: Mutex::new(BTreeMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            retry: RetryPolicy::default(),
            cooperative: false,
            store: None,
        }
    }

    /// Attach a persistent [`ProfileStore`]: cache misses consult it
    /// before simulating, fresh results are written back, and the store
    /// is flushed at every campaign boundary (and on drop).  Warm output
    /// is bit-identical to cold output — stored values are the very rep
    /// results the executor produced.
    pub fn with_store(mut self, store: ProfileStore) -> CampaignExecutor {
        self.store = Some(store);
        self
    }

    /// The attached persistent store, if any.
    pub fn store(&self) -> Option<&ProfileStore> {
        self.store.as_ref()
    }

    /// Set the per-rep retry policy (see [`RetryPolicy`]).
    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> CampaignExecutor {
        self.retry = RetryPolicy { max_attempts: retry.max_attempts.max(1), ..retry };
        self
    }

    /// The per-rep retry policy in force.
    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    /// Enable **cooperative drain**: missing reps are claimed via
    /// per-setting lease files in the attached store's directory, so N
    /// independent processes pointed at one store split a campaign
    /// between them — each setting is simulated by exactly one process
    /// and everyone's output is bit-identical to a solo run.  Requires a
    /// store ([`CampaignExecutor::with_store`]); without one the flag is
    /// ignored.  Dispatch within the process is serial in this mode (the
    /// fleet *is* the parallelism).
    pub fn with_cooperative(mut self, on: bool) -> CampaignExecutor {
        self.cooperative = on;
        self
    }

    /// Whether cooperative drain is enabled.
    pub fn cooperative(&self) -> bool {
        self.cooperative
    }

    /// Single-worker executor — the serial reference behaviour.
    pub fn serial() -> CampaignExecutor {
        CampaignExecutor::new(1)
    }

    /// Executor sized to the host: one worker per available core.
    pub fn machine_sized() -> CampaignExecutor {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        CampaignExecutor::new(n)
    }

    /// Worker-pool size.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Reps answered from the in-memory cache (including duplicates
    /// coalesced within one call).
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Reps dispatched to the simulator so far (quarantined reps count:
    /// they were attempted, whatever the outcome).
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Reps answered from the persistent store (zero when none attached).
    pub fn store_hits(&self) -> u64 {
        self.store_hits.load(Ordering::Relaxed)
    }

    /// Reps that exhausted their retry budget and were quarantined into
    /// the dead-letter queue instead of aborting the campaign.
    pub fn quarantined(&self) -> u64 {
        self.quarantined.load(Ordering::Relaxed)
    }

    /// Distinct reps currently in the in-memory cache.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().expect("executor cache poisoned").len()
    }

    /// Combined in-memory **and** on-disk picture of this executor — the
    /// per-instance counters alone under-report once a store is attached
    /// or `--jobs` splits work across calls, so consumers should print
    /// this instead.
    pub fn stats(&self) -> ExecutorStats {
        ExecutorStats {
            jobs: self.jobs,
            simulated: self.cache_misses(),
            mem_hits: self.cache_hits(),
            store_hits: self.store_hits(),
            quarantined: self.quarantined(),
            mem_entries: self.cache_len(),
            store_entries: self.store.as_ref().map(|s| s.len()).unwrap_or(0),
            store_shards: self
                .store
                .as_ref()
                .map(|s| s.shard_count())
                .unwrap_or(0),
            store_attached: self.store.is_some(),
        }
    }

    /// Flush the attached store's buffered records to disk now (no-op
    /// without a store).  `run_reps` already does this at every campaign
    /// boundary; long-lived services can call it on their own cadence.
    pub fn flush_store(&self) -> Result<(), String> {
        match &self.store {
            Some(s) => s.flush(),
            None => Ok(()),
        }
    }

    /// Simulate every repetition in `items`, returning total execution
    /// times in input order.
    ///
    /// Cached reps are returned without re-simulation; misses fan out over
    /// the worker pool.  Output is bit-identical for any worker count:
    /// each rep's seed and layout derive from `(base_seed, spec, rep)`
    /// alone, never from scheduling order, and results are written back by
    /// input index.
    pub fn run_reps(&self, cluster: &Cluster, items: &[RepJob]) -> Vec<f64> {
        self.run_units(cluster, items, Need::Time)
            .iter()
            .map(|o| o.time_s)
            .collect()
    }

    /// Simulate every repetition in `items`, returning per-rep outcomes
    /// carrying time **and** CPU seconds in input order — the entry
    /// point the extended 4-parameter pipeline uses.
    ///
    /// Every returned outcome carries the CPU figure: a cached record
    /// lacking it (data migrated from a v1 store) counts as a miss here
    /// and is re-simulated, upgrading the stored record in place.
    pub fn run_outcomes(
        &self,
        cluster: &Cluster,
        items: &[RepJob],
    ) -> Vec<RepOutcome> {
        self.run_units(cluster, items, Need::Cpu)
    }

    /// Simulate every repetition in `items`, returning outcomes carrying
    /// every modeled output — time, CPU seconds, and the shuffle/HDFS
    /// byte counters — in input order: the multi-target profiling entry
    /// point.
    ///
    /// A cached record lacking the byte counters (data from a pre-v4
    /// store) counts as a miss here and is re-simulated, upgrading the
    /// stored record in place — exactly the v1→v2 CPU migration pattern.
    pub fn run_full_outcomes(
        &self,
        cluster: &Cluster,
        items: &[RepJob],
    ) -> Vec<RepOutcome> {
        self.run_units(cluster, items, Need::Full)
    }

    /// Shared engine behind [`CampaignExecutor::run_reps`],
    /// [`CampaignExecutor::run_outcomes`], and
    /// [`CampaignExecutor::run_full_outcomes`]: `need` decides whether a
    /// partial cached outcome may answer, or must be re-simulated.
    fn run_units(
        &self,
        cluster: &Cluster,
        items: &[RepJob],
        need: Need,
    ) -> Vec<RepOutcome> {
        let cluster_fp = cluster_fingerprint(cluster);
        let usable = |o: &RepOutcome| -> bool { need.usable(o) };
        let mut out = vec![RepOutcome::time_only(f64::NAN); items.len()];
        // `todo` holds the first item index per distinct missing key;
        // duplicate items within one call alias the same simulation.
        let mut todo: Vec<usize> = Vec::new();
        let mut alias: Vec<(usize, usize)> = Vec::new();
        let mut store_hit_count: u64 = 0;
        {
            let mut cache = self.cache.lock().expect("executor cache poisoned");
            let mut pending: BTreeMap<StoreKey, usize> = BTreeMap::new();
            for (i, item) in items.iter().enumerate() {
                let key = item.key(cluster_fp);
                if let Some(o) = cache.get(&key).copied().filter(&usable) {
                    out[i] = o;
                } else if let Some(o) = self
                    .store
                    .as_ref()
                    .and_then(|s| s.get(&key))
                    .filter(&usable)
                {
                    // On-disk hit: promote into the in-memory cache so
                    // repeats within this session are memory-speed.
                    out[i] = o;
                    cache.insert(key, o);
                    store_hit_count += 1;
                } else if let Some(&k) = pending.get(&key) {
                    alias.push((i, k));
                } else {
                    pending.insert(key, todo.len());
                    todo.push(i);
                }
            }
        }
        self.store_hits.fetch_add(store_hit_count, Ordering::Relaxed);
        self.hits.fetch_add(
            items.len() as u64 - todo.len() as u64 - store_hit_count,
            Ordering::Relaxed,
        );
        if todo.is_empty() {
            return out;
        }

        // Build each distinct (shape, session) context and each distinct
        // app profile once, up front and serially, so workers only pay for
        // event simulation — the JobContext reuse contract.  `ctx_keys[k]`
        // and `cfgs[k]` resolve todo item `k` without re-deriving anything.
        let mut contexts: BTreeMap<(ContextShape, u64), JobContext> = BTreeMap::new();
        let mut profiles: BTreeMap<AppId, AppProfile> = BTreeMap::new();
        let mut ctx_keys: Vec<(ContextShape, u64)> = Vec::with_capacity(todo.len());
        let mut cfgs: Vec<JobConfig> = Vec::with_capacity(todo.len());
        for &i in &todo {
            let item = &items[i];
            let config = item.config();
            let key = (ContextShape::of(cluster, &config), item.base_seed);
            contexts
                .entry(key)
                .or_insert_with(|| JobContext::for_session(cluster, &config, item.base_seed));
            profiles
                .entry(item.spec.app())
                .or_insert_with(|| item.spec.app().profile());
            ctx_keys.push(key);
            cfgs.push(config);
        }

        // Each todo item k simulates items[todo[k]] against its context.
        let run_one = |k: usize| -> RepOutcome {
            let item = &items[todo[k]];
            let ctx = &contexts[&ctx_keys[k]];
            let profile = &profiles[&item.spec.app()];
            run_job_in(cluster, profile, &cfgs[k], ctx).rep_outcome()
        };

        // Per-rep fault isolation: every attempt runs under the rep's
        // fault scope (so `MRTUNER_FAIL_SPEC` can target `rep=N`) inside
        // `catch_unwind`; each panic consumes one attempt of the retry
        // budget.  An exhausted budget yields the last panic message —
        // the caller quarantines the rep and the campaign never aborts.
        let retry = self.retry;
        let run_guarded = |k: usize| -> Result<RepOutcome, (u32, String)> {
            let attempts = retry.max_attempts.max(1);
            let mut last = String::new();
            for attempt in 1..=attempts {
                let _scope = fault::rep_scope(items[todo[k]].rep);
                match std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| run_one(k)),
                ) {
                    Ok(o) => return Ok(o),
                    Err(payload) => {
                        last = panic_message(payload);
                        if attempt < attempts && !retry.backoff.is_zero() {
                            std::thread::sleep(retry.backoff);
                        }
                    }
                }
            }
            Err((attempts, last))
        };

        let mut ok = vec![true; todo.len()];
        let mut failures: Vec<Quarantine> = Vec::new();

        // Cooperative drain needs its lease directory; if that cannot be
        // created, degrade to solo dispatch rather than fail the run.
        let lease_dir = if self.cooperative {
            self.store.as_ref().and_then(|s| {
                let dir = s.dir().join("leases");
                match fs::create_dir_all(&dir) {
                    Ok(()) => Some(dir),
                    Err(e) => {
                        eprintln!(
                            "warn: cooperative drain disabled: create {}: {e}",
                            dir.display()
                        );
                        None
                    }
                }
            })
        } else {
            None
        };

        if let Some(lease_dir) = lease_dir {
            let store =
                self.store.as_ref().expect("cooperative drain has a store");
            let dlq_dir = dlq::dlq_dir(store.dir());

            // Drain one *claimed* setting: refresh, resolve each rep
            // from the store (a peer may have finished it since our
            // classification), simulate the rest, write through, flush,
            // and only then let the caller release the lease — a lease
            // disappearing therefore implies its records are on disk,
            // which is what keeps combined `simulated` counts across a
            // fleet exactly equal to the grid.
            let drain_claimed = |ks: &[usize],
                                 out: &mut Vec<RepOutcome>,
                                 ok: &mut Vec<bool>,
                                 failures: &mut Vec<Quarantine>| {
                if let Err(e) = store.refresh() {
                    eprintln!("warn: store refresh failed: {e}");
                }
                for &k in ks {
                    let key = items[todo[k]].key(cluster_fp);
                    if let Some(o) = store.get(&key).filter(&usable) {
                        out[todo[k]] = o;
                        self.store_hits.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    match run_guarded(k) {
                        Ok(o) => {
                            out[todo[k]] = o;
                            store.put(key, o);
                        }
                        Err((attempts, error)) => {
                            out[todo[k]] = quarantined_outcome();
                            ok[k] = false;
                            failures.push(Quarantine { k, attempts, error });
                        }
                    }
                }
                if let Err(e) = store.flush() {
                    eprintln!("warn: profile store flush failed: {e}");
                }
            };

            // The lease unit is the *setting*: every rep of one (cluster,
            // app, M, R, input, block, session) block moves as one claim.
            let mut groups: BTreeMap<StoreKey, Vec<usize>> = BTreeMap::new();
            for k in 0..todo.len() {
                let mut setting = items[todo[k]].key(cluster_fp);
                setting.rep = 0;
                groups.entry(setting).or_default().push(k);
            }

            // Pass 1: claim whatever is free and drain it.
            let mut waiting: Vec<(PathBuf, Vec<usize>)> = Vec::new();
            for (setting, ks) in groups {
                let lease = lease_dir.join(lease_name(&setting));
                if try_claim_lease(&lease) {
                    drain_claimed(&ks, &mut out, &mut ok, &mut failures);
                    let _ = fs::remove_file(&lease);
                } else {
                    waiting.push((lease, ks));
                }
            }

            // Pass 2: wait on peers, absorbing their results as they
            // land (store records, or DLQ verdicts for reps a peer
            // quarantined) and reclaiming leases whose holder died.
            while !waiting.is_empty() {
                if let Err(e) = store.refresh() {
                    eprintln!("warn: store refresh failed: {e}");
                }
                let peer_dlq: BTreeSet<StoreKey> = dlq::load(&dlq_dir)
                    .unwrap_or_default()
                    .into_iter()
                    .map(|r| r.key)
                    .collect();
                let mut still: Vec<(PathBuf, Vec<usize>)> = Vec::new();
                for (lease, mut ks) in waiting {
                    ks.retain(|&k| {
                        let key = items[todo[k]].key(cluster_fp);
                        if let Some(o) = store.get(&key).filter(&usable) {
                            out[todo[k]] = o;
                            self.store_hits.fetch_add(1, Ordering::Relaxed);
                            false
                        } else if peer_dlq.contains(&key) {
                            // Quarantined by a peer: inherit the verdict
                            // (the peer already appended the DLQ record).
                            out[todo[k]] = quarantined_outcome();
                            ok[k] = false;
                            false
                        } else {
                            true
                        }
                    });
                    if ks.is_empty() {
                        continue;
                    }
                    if !lease_is_live(&lease) {
                        // Holder gone: either it crashed, or it finished
                        // and its records raced our refresh.  Reclaim —
                        // drain_claimed re-refreshes before simulating,
                        // so a finished peer costs zero re-simulation and
                        // a crashed peer's unflushed reps are redone
                        // bit-identically.
                        let _ = fs::remove_file(&lease);
                        if try_claim_lease(&lease) {
                            drain_claimed(
                                &ks,
                                &mut out,
                                &mut ok,
                                &mut failures,
                            );
                            let _ = fs::remove_file(&lease);
                            continue;
                        }
                    }
                    still.push((lease, ks));
                }
                waiting = still;
                if !waiting.is_empty() {
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        } else {
            self.misses.fetch_add(todo.len() as u64, Ordering::Relaxed);
            // Each completed rep is written through to the store as it
            // finishes and flushed at chunk grain: the store journal IS
            // the campaign checkpoint, so a SIGKILL mid-campaign loses at
            // most the in-flight chunk and `--resume` (or any re-run)
            // skips everything already on disk.
            let commit = |k: usize, o: RepOutcome| {
                if let Some(store) = &self.store {
                    store.put(items[todo[k]].key(cluster_fp), o);
                }
            };
            let flush = || {
                if let Some(store) = &self.store {
                    if let Err(e) = store.flush() {
                        eprintln!("warn: profile store flush failed: {e}");
                    }
                }
            };
            let workers = self.jobs.min(todo.len());
            if workers <= 1 {
                for k in 0..todo.len() {
                    match run_guarded(k) {
                        Ok(o) => {
                            out[todo[k]] = o;
                            commit(k, o);
                            flush();
                        }
                        Err((attempts, error)) => {
                            out[todo[k]] = quarantined_outcome();
                            ok[k] = false;
                            failures.push(Quarantine { k, attempts, error });
                        }
                    }
                }
            } else {
                // Work-stealing chunked dispatch.  Contiguous index
                // chunks are dealt round-robin onto per-worker deques up
                // front; a worker drains its own deque from the front
                // and, when empty, steals from the back of a victim's.
                // Chunks amortize queue locking on dense grids; stealing
                // keeps every worker busy on skewed ones (an ext4 sweep
                // mixes 256-map settings with 4-map ones, so equal-share
                // splits leave workers idle).  Output stays bit-identical
                // to serial because results are written back by input
                // index — scheduling order never touches the data.
                let chunk = (todo.len() / (workers * CHUNKS_PER_WORKER))
                    .clamp(1, MAX_CHUNK);
                let queues: Vec<Mutex<VecDeque<Range<usize>>>> =
                    (0..workers).map(|_| Mutex::new(VecDeque::new())).collect();
                {
                    let mut lo = 0;
                    let mut w = 0;
                    while lo < todo.len() {
                        let hi = (lo + chunk).min(todo.len());
                        queues[w % workers]
                            .lock()
                            .expect("chunk queue poisoned")
                            .push_back(lo..hi);
                        w += 1;
                        lo = hi;
                    }
                }
                let failed: Mutex<Vec<Quarantine>> = Mutex::new(Vec::new());
                let computed: Vec<(usize, RepOutcome, bool)> =
                    std::thread::scope(|scope| {
                        let run_guarded = &run_guarded;
                        let commit = &commit;
                        let flush = &flush;
                        let queues = &queues[..];
                        let failed = &failed;
                        let handles: Vec<_> = (0..workers)
                            .map(|wi| {
                                scope.spawn(move || {
                                    let mut local = Vec::new();
                                    while let Some(range) =
                                        next_chunk(queues, wi)
                                    {
                                        for k in range {
                                            match run_guarded(k) {
                                                Ok(o) => {
                                                    commit(k, o);
                                                    local.push((k, o, true));
                                                }
                                                Err((attempts, error)) => {
                                                    failed
                                                        .lock()
                                                        .expect(
                                                            "quarantine list \
                                                             poisoned",
                                                        )
                                                        .push(Quarantine {
                                                            k,
                                                            attempts,
                                                            error,
                                                        });
                                                    local.push((
                                                        k,
                                                        quarantined_outcome(),
                                                        false,
                                                    ));
                                                }
                                            }
                                        }
                                        flush();
                                    }
                                    local
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .flat_map(|h| {
                                h.join().expect("executor worker panicked")
                            })
                            .collect()
                    });
                for (k, o, is_ok) in computed {
                    out[todo[k]] = o;
                    ok[k] = is_ok;
                }
                failures
                    .extend(failed.into_inner().expect("quarantine list poisoned"));
            }
        }

        for &(i, k) in &alias {
            out[i] = out[todo[k]];
        }

        {
            let mut cache = self.cache.lock().expect("executor cache poisoned");
            for (k, &i) in todo.iter().enumerate() {
                if ok[k] {
                    cache.insert(items[i].key(cluster_fp), out[i]);
                }
            }
        }

        // Quarantine whatever exhausted its retries: versioned DLQ
        // records when a store is attached (surfaced by `mrtuner dlq
        // list|retry|clear`), a non-fatal stderr summary either way.
        // The campaign completes — a poisoned rep never aborts it.
        if !failures.is_empty() {
            self.quarantined
                .fetch_add(failures.len() as u64, Ordering::Relaxed);
            failures.sort_by_key(|f| f.k);
            let records: Vec<DlqRecord> = failures
                .iter()
                .map(|f| DlqRecord {
                    key: items[todo[f.k]].key(cluster_fp),
                    attempts: f.attempts,
                    error: f.error.clone(),
                })
                .collect();
            if let Some(store) = &self.store {
                let dir = dlq::dlq_dir(store.dir());
                if let Err(e) = dlq::append(&dir, &records) {
                    eprintln!("warn: dead-letter append failed: {e}");
                }
            }
            eprintln!(
                "warn: {} rep(s) quarantined; campaign continued (inspect \
                 with `mrtuner dlq list`)",
                records.len()
            );
            for r in &records {
                eprintln!(
                    "warn:   quarantined {} m={} r={} rep={} after {} \
                     attempt(s): {}",
                    r.key.app.name(),
                    r.key.num_mappers,
                    r.key.num_reducers,
                    r.key.rep,
                    r.attempts,
                    r.error
                );
            }
        }
        out
    }

    /// Diff a campaign's work list against the attached store and DLQ —
    /// the `--resume` report.  `done` reps are already on disk and will
    /// not be re-simulated; `quarantined` reps (a subset of `missing`)
    /// are parked in the dead-letter queue from a previous run and will
    /// be re-attempted by this dispatch.  Requires a store.
    pub fn resume_status(
        &self,
        cluster: &Cluster,
        items: &[RepJob],
    ) -> Result<ResumeStatus, String> {
        let store = self.store.as_ref().ok_or_else(|| {
            "resume requires a persistent store (--store or MRTUNER_STORE)"
                .to_string()
        })?;
        store.refresh()?;
        let parked: BTreeSet<StoreKey> = dlq::load(&dlq::dlq_dir(store.dir()))?
            .into_iter()
            .map(|r| r.key)
            .collect();
        let cluster_fp = cluster_fingerprint(cluster);
        let mut seen = BTreeSet::new();
        let mut status = ResumeStatus::default();
        for item in items {
            let key = item.key(cluster_fp);
            if !seen.insert(key) {
                continue;
            }
            status.total += 1;
            if store.get(&key).is_some() {
                status.done += 1;
            } else {
                status.missing += 1;
                if parked.contains(&key) {
                    status.quarantined += 1;
                }
            }
        }
        Ok(status)
    }

    /// Run `reps` repetitions of every spec (one profiling session keyed
    /// by `base_seed`), returning per-spec averaged results in spec order.
    pub fn run_specs(
        &self,
        cluster: &Cluster,
        specs: &[ExperimentSpec],
        reps: u32,
        base_seed: u64,
    ) -> Vec<ExperimentResult> {
        let items: Vec<RepJob> = specs
            .iter()
            .flat_map(|s| (0..reps).map(move |rep| RepJob::paper(*s, rep, base_seed)))
            .collect();
        let times = self.run_reps(cluster, &items);
        specs
            .iter()
            .enumerate()
            .map(|(si, s)| {
                let lo = si * reps as usize;
                let rep_times_s = times[lo..lo + reps as usize].to_vec();
                ExperimentResult {
                    spec: *s,
                    mean_time_s: stats::mean(&rep_times_s),
                    rep_times_s,
                }
            })
            .collect()
    }

    /// [`CampaignExecutor::run_specs`] with every modeled output: per-spec
    /// mean time, mean CPU, and mean shuffle/HDFS bytes.
    ///
    /// Byte-means are `None` when *any* rep of the setting lacks its
    /// counters — exactly the quarantined-rep sentinel, since every
    /// simulated (or v4-cached) outcome carries them — so a poisoned
    /// setting surfaces as a null byte-mean without aborting the
    /// campaign, mirroring the NaN-poisoned time mean.
    pub fn run_specs_full(
        &self,
        cluster: &Cluster,
        specs: &[ExperimentSpec],
        reps: u32,
        base_seed: u64,
    ) -> Vec<FullExperimentResult> {
        let items: Vec<RepJob> = specs
            .iter()
            .flat_map(|s| (0..reps).map(move |rep| RepJob::paper(*s, rep, base_seed)))
            .collect();
        let outcomes = self.run_full_outcomes(cluster, &items);
        specs
            .iter()
            .enumerate()
            .map(|(si, s)| {
                let lo = si * reps as usize;
                let chunk = &outcomes[lo..lo + reps as usize];
                let times: Vec<f64> = chunk.iter().map(|o| o.time_s).collect();
                let byte_mean = |f: fn(&crate::mr::RepBytes) -> u64| {
                    chunk
                        .iter()
                        .map(|o| o.bytes.as_ref().map(|b| f(b) as f64))
                        .collect::<Option<Vec<f64>>>()
                        .map(|v| stats::mean(&v))
                };
                FullExperimentResult {
                    spec: *s,
                    mean_time_s: stats::mean(&times),
                    mean_cpu_s: stats::mean(
                        &chunk
                            .iter()
                            .map(|o| o.cpu_s.unwrap_or(f64::NAN))
                            .collect::<Vec<f64>>(),
                    ),
                    mean_shuffle_bytes: byte_mean(|b| b.shuffle),
                    mean_hdfs_bytes: byte_mean(|b| b.hdfs),
                    rep_times_s: times,
                }
            })
            .collect()
    }

    /// Run a whole campaign, returning raw results and the fitted-on
    /// dataset — the executor-backed replacement for `Campaign::run`.
    pub fn run_campaign(
        &self,
        cluster: &Cluster,
        campaign: &Campaign,
    ) -> (Vec<ExperimentResult>, Dataset) {
        let results =
            self.run_specs(cluster, &campaign.specs, campaign.reps, campaign.base_seed);
        let ds = Dataset::from_results(campaign.app, &results);
        (results, ds)
    }

    /// [`CampaignExecutor::resume_status`] for a whole paper campaign —
    /// shorthand over [`Campaign::rep_jobs`].
    pub fn campaign_resume_status(
        &self,
        cluster: &Cluster,
        campaign: &Campaign,
    ) -> Result<ResumeStatus, String> {
        self.resume_status(cluster, &campaign.rep_jobs())
    }

    /// Run `reps` repetitions of every extended 4-parameter setting (one
    /// profiling session keyed by `base_seed`), returning per-spec
    /// averaged results — both modeled outputs — in spec order.
    ///
    /// Same contract as [`CampaignExecutor::run_specs`]: parallel output
    /// is bit-identical to serial, overlapping sweeps hit the rep cache,
    /// and an attached [`ProfileStore`] warm-starts later processes.
    pub fn run_ext4_specs(
        &self,
        cluster: &Cluster,
        specs: &[Ext4Spec],
        reps: u32,
        base_seed: u64,
    ) -> Vec<Ext4Result> {
        let items = ext4_rep_jobs(specs, reps, base_seed);
        let outcomes = self.run_outcomes(cluster, &items);
        specs
            .iter()
            .enumerate()
            .map(|(si, s)| {
                let lo = si * reps as usize;
                let chunk = &outcomes[lo..lo + reps as usize];
                let times: Vec<f64> = chunk.iter().map(|o| o.time_s).collect();
                let cpus: Vec<f64> = chunk
                    .iter()
                    .map(|o| {
                        o.cpu_s.expect("run_outcomes returns full outcomes")
                    })
                    .collect();
                Ext4Result {
                    spec: *s,
                    mean_time_s: stats::mean(&times),
                    mean_cpu_s: stats::mean(&cpus),
                }
            })
            .collect()
    }

    /// Run a whole extended campaign, returning regression rows plus the
    /// two modeled outputs — the executor-backed replacement for the old
    /// serial `extended::run_ext4_campaign` driver.
    pub fn run_ext4_campaign(
        &self,
        cluster: &Cluster,
        specs: &[Ext4Spec],
        reps: u32,
        base_seed: u64,
    ) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>) {
        let results = self.run_ext4_specs(cluster, specs, reps, base_seed);
        let rows = specs.iter().map(|s| s.params()).collect();
        let times = results.iter().map(|r| r.mean_time_s).collect();
        let cpus = results.iter().map(|r| r.mean_cpu_s).collect();
        (rows, times, cpus)
    }
}

/// Combined in-memory + on-disk executor counters, for CLI/e2e/scheduler
/// reporting.  `simulated` is the work actually done; `mem_hits` and
/// `store_hits` are the work avoided, split by which layer answered.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    /// Worker-pool size.
    pub jobs: usize,
    /// Reps simulated fresh (the executor's `cache_misses`).
    pub simulated: u64,
    /// Reps answered by the in-memory cache (incl. coalesced duplicates).
    pub mem_hits: u64,
    /// Reps answered by the persistent store.
    pub store_hits: u64,
    /// Reps quarantined into the dead-letter queue by *this* executor
    /// (peer-quarantined reps inherited during cooperative drain are
    /// counted by the peer that parked them).
    pub quarantined: u64,
    /// Distinct reps in the in-memory cache.
    pub mem_entries: usize,
    /// Distinct reps in the persistent store (0 when none attached).
    pub store_entries: usize,
    /// Shards behind the attached store (0 when none attached).
    pub store_shards: usize,
    /// Whether a persistent store is attached.
    pub store_attached: bool,
}

impl fmt::Display for ExecutorStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "jobs={} simulated={} mem_hits={} store_hits={} quarantined={} \
             mem_entries={} store_entries={} store_shards={} store={}",
            self.jobs,
            self.simulated,
            self.mem_hits,
            self.store_hits,
            self.quarantined,
            self.mem_entries,
            self.store_entries,
            self.store_shards,
            if self.store_attached { "on" } else { "off" }
        )
    }
}

/// The `--resume` diff of a campaign's work list against the store and
/// the dead-letter queue, over *distinct* rep keys.
///
/// `done + missing == total`; `quarantined` is the subset of `missing`
/// parked in the DLQ by an earlier run (re-attempted on dispatch — use
/// `mrtuner dlq retry` to drain them without re-running the campaign).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResumeStatus {
    /// Distinct reps the campaign needs.
    pub total: usize,
    /// Reps already completed on disk — never re-simulated.
    pub done: usize,
    /// Missing reps currently quarantined in the dead-letter queue.
    pub quarantined: usize,
    /// Reps not yet on disk — the remainder this run dispatches.
    pub missing: usize,
}

impl fmt::Display for ResumeStatus {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}/{} reps already complete on disk, {} quarantined; \
             dispatching {}",
            self.done, self.total, self.quarantined, self.missing
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(m: u32, r: u32) -> ExperimentSpec {
        ExperimentSpec::new(AppId::WordCount, m, r)
    }

    #[test]
    fn serial_and_parallel_reps_are_bit_identical() {
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5), spec(35, 30)];
        let serial = CampaignExecutor::serial().run_specs(&cluster, &specs, 3, 11);
        for jobs in [2, 4] {
            let par = CampaignExecutor::new(jobs).run_specs(&cluster, &specs, 3, 11);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.rep_times_s, b.rep_times_s, "jobs={jobs}");
                assert_eq!(a.mean_time_s, b.mean_time_s, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        let specs = [spec(10, 10), spec(20, 5)];
        exec.run_specs(&cluster, &specs, 2, 3);
        assert_eq!(exec.cache_misses(), 4);
        assert_eq!(exec.cache_hits(), 0);
        assert_eq!(exec.cache_len(), 4);
        // Re-running the same session is pure cache.
        let again = exec.run_specs(&cluster, &specs, 2, 3);
        assert_eq!(exec.cache_misses(), 4);
        assert_eq!(exec.cache_hits(), 4);
        assert!(again.iter().all(|r| r.rep_times_s.iter().all(|t| t.is_finite())));
        // A different session seed must not hit.
        exec.run_specs(&cluster, &specs, 2, 4);
        assert_eq!(exec.cache_misses(), 8);
        assert_eq!(exec.cache_hits(), 4);
    }

    #[test]
    fn cached_values_equal_fresh_computation() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        let warm = exec.run_specs(&cluster, &[spec(20, 5)], 2, 9);
        let cached = exec.run_specs(&cluster, &[spec(20, 5)], 2, 9);
        let fresh = CampaignExecutor::serial().run_specs(&cluster, &[spec(20, 5)], 2, 9);
        assert_eq!(warm[0].rep_times_s, cached[0].rep_times_s);
        assert_eq!(warm[0].rep_times_s, fresh[0].rep_times_s);
    }

    #[test]
    fn duplicate_items_in_one_call_are_coalesced() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(4);
        let items = [RepJob::paper(spec(20, 5), 0, 1); 3];
        let times = exec.run_reps(&cluster, &items);
        assert_eq!(exec.cache_misses(), 1, "one simulation for three duplicates");
        assert_eq!(exec.cache_hits(), 2);
        assert_eq!(times[0], times[1]);
        assert_eq!(times[1], times[2]);
    }

    #[test]
    fn cache_is_cluster_aware() {
        let paper = Cluster::paper_cluster();
        let mut big = Cluster::paper_cluster();
        for n in &mut big.nodes {
            n.spec.map_slots += 2;
        }
        let exec = CampaignExecutor::serial();
        let a = exec.run_specs(&paper, &[spec(20, 5)], 1, 7);
        let b = exec.run_specs(&big, &[spec(20, 5)], 1, 7);
        // Same (spec, rep, base_seed) on a different cluster must be a
        // fresh simulation, not a stale hit.
        assert_eq!(exec.cache_misses(), 2);
        assert_eq!(exec.cache_hits(), 0);
        assert_ne!(a[0].rep_times_s, b[0].rep_times_s);
    }

    #[test]
    fn ext4_serial_and_parallel_are_bit_identical() {
        let cluster = Cluster::paper_cluster();
        let specs = [
            Ext4Spec {
                app: AppId::WordCount,
                num_mappers: 20,
                num_reducers: 5,
                input_gb: 2.0,
                block_mb: 64,
            },
            Ext4Spec {
                app: AppId::WordCount,
                num_mappers: 10,
                num_reducers: 30,
                input_gb: 4.5,
                block_mb: 128,
            },
        ];
        let serial =
            CampaignExecutor::serial().run_ext4_specs(&cluster, &specs, 3, 11);
        for jobs in [2, 4] {
            let par = CampaignExecutor::new(jobs)
                .run_ext4_specs(&cluster, &specs, 3, 11);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.mean_time_s.to_bits(), b.mean_time_s.to_bits());
                assert_eq!(a.mean_cpu_s.to_bits(), b.mean_cpu_s.to_bits());
            }
        }
    }

    #[test]
    fn paper_plane_ext4_aliases_paper_reps() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        // 2-parameter campaign first: reps cached with full outcomes.
        let paper = exec.run_specs(&cluster, &[spec(20, 5)], 2, 7);
        assert_eq!(exec.cache_misses(), 2);
        // The same point of the 4-D space at paper-default input/block is
        // the same simulation: pure cache, bit-identical times.
        let e = Ext4Spec {
            app: AppId::WordCount,
            num_mappers: 20,
            num_reducers: 5,
            input_gb: 8.0,
            block_mb: 64,
        };
        assert!(e.is_paper_plane());
        let ext = exec.run_ext4_specs(&cluster, &[e], 2, 7);
        assert_eq!(exec.cache_misses(), 2, "no new simulation");
        assert_eq!(exec.cache_hits(), 2);
        assert_eq!(ext[0].mean_time_s.to_bits(), paper[0].mean_time_s.to_bits());
        // Off the paper plane the key differs and a fresh sim runs.
        let off = Ext4Spec { block_mb: 128, ..e };
        exec.run_ext4_specs(&cluster, &[off], 2, 7);
        assert_eq!(exec.cache_misses(), 4);
    }

    #[test]
    fn cpu_less_store_records_answer_times_but_not_outcomes() {
        let base = std::env::temp_dir()
            .join(format!("mrtuner_exec_v1up_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let dir_a = base.join("a");
        let dir_b = base.join("b");
        let cluster = Cluster::paper_cluster();
        let item = RepJob::paper(spec(20, 5), 0, 3);

        // Cold run into store A to learn the executor-derived key and the
        // full outcome under it.
        {
            let exec = CampaignExecutor::serial()
                .with_store(ProfileStore::open(&dir_a).unwrap());
            exec.run_reps(&cluster, &[item]);
        }
        let (key, full) = {
            let mut records = Vec::new();
            for p in std::fs::read_dir(&dir_a)
                .unwrap()
                .map(|e| e.unwrap().path())
                .filter(|p| p.extension().is_some_and(|x| x == "bin"))
            {
                records
                    .extend(super::super::store::read_file_records(&p).unwrap());
            }
            let (k, o, _) = records.into_iter().next().unwrap();
            (k, o)
        };
        assert!(full.cpu_s.is_some(), "executor stores full outcomes");

        // Store B holds the same record *without* the CPU figure — what a
        // migrated v1 store looks like after open.
        std::fs::create_dir_all(&dir_b).unwrap();
        std::fs::write(
            dir_b.join("index.jsonl"),
            format!(
                "{}\n",
                super::super::store::encode_record(
                    &key,
                    &RepOutcome::time_only(full.time_s)
                )
            ),
        )
        .unwrap();

        let exec = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir_b).unwrap());
        // Time-only consumers are answered from the CPU-less record ...
        let times = exec.run_reps(&cluster, &[item]);
        assert_eq!(exec.cache_misses(), 0);
        assert_eq!(exec.store_hits(), 1);
        assert_eq!(times[0].to_bits(), full.time_s.to_bits());
        // ... but an outcome consumer re-simulates and upgrades in place.
        let outs = exec.run_outcomes(&cluster, &[item]);
        assert_eq!(exec.cache_misses(), 1, "CPU-less entry is a miss here");
        assert!(outs[0].same_bits(&full), "re-simulation is bit-identical");
        assert_eq!(
            exec.store().unwrap().get(&key),
            Some(full),
            "stored record upgraded with the CPU figure"
        );
        drop(exec);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn executor_clamps_zero_jobs() {
        assert_eq!(CampaignExecutor::new(0).jobs(), 1);
        assert!(CampaignExecutor::machine_sized().jobs() >= 1);
    }

    #[test]
    fn stats_combine_memory_and_store() {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_exec_stats_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5)];
        {
            let exec = CampaignExecutor::new(2)
                .with_store(ProfileStore::open(&dir).unwrap());
            exec.run_specs(&cluster, &specs, 2, 3);
            let st = exec.stats();
            assert_eq!(st.simulated, 4);
            assert_eq!(st.mem_hits, 0);
            assert_eq!(st.store_hits, 0);
            assert_eq!(st.mem_entries, 4);
            assert_eq!(st.store_entries, 4, "fresh reps written through");
            assert!(st.store_attached);
            assert!(st.to_string().contains("simulated=4"));
        }
        // A second executor on the same directory answers purely from
        // disk: zero simulations, bit-identical results.
        let cold = CampaignExecutor::serial().run_specs(&cluster, &specs, 2, 3);
        let exec2 = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        let warm = exec2.run_specs(&cluster, &specs, 2, 3);
        let st = exec2.stats();
        assert_eq!(st.simulated, 0);
        assert_eq!(st.store_hits, 4);
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.rep_times_s, b.rep_times_s);
        }
        drop(exec2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn skewed_grid_work_stealing_is_bit_identical_and_complete() {
        // A deliberately skewed grid: one 256-map monster among cheap
        // 4-map settings, at worker counts that do not divide the item
        // count.  Every item must be simulated exactly once and the
        // output must match serial bit for bit whatever got stolen.
        let cluster = Cluster::paper_cluster();
        let specs: Vec<Ext4Spec> = (0..9)
            .map(|i| Ext4Spec {
                app: AppId::WordCount,
                num_mappers: 5 + i,
                num_reducers: 5,
                input_gb: if i == 0 { 8.0 } else { 1.0 },
                block_mb: if i == 0 { 32 } else { 256 },
            })
            .collect();
        let serial =
            CampaignExecutor::serial().run_ext4_specs(&cluster, &specs, 1, 13);
        for jobs in [3, 8] {
            let exec = CampaignExecutor::new(jobs);
            let par = exec.run_ext4_specs(&cluster, &specs, 1, 13);
            assert_eq!(exec.cache_misses(), 9, "jobs={jobs}: each item once");
            assert_eq!(exec.cache_hits(), 0, "jobs={jobs}");
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.mean_time_s.to_bits(), b.mean_time_s.to_bits());
                assert_eq!(a.mean_cpu_s.to_bits(), b.mean_cpu_s.to_bits());
            }
        }
    }

    #[test]
    fn chunk_queues_hand_out_every_range_exactly_once() {
        let queues: Vec<Mutex<VecDeque<Range<usize>>>> =
            (0..3).map(|_| Mutex::new(VecDeque::new())).collect();
        for (w, lo) in (0..10).enumerate() {
            queues[w % 3]
                .lock()
                .unwrap()
                .push_back(lo * 2..lo * 2 + 2);
        }
        // Worker 1 drains everything (its own queue plus steals).
        let mut seen = Vec::new();
        while let Some(r) = next_chunk(&queues, 1) {
            seen.extend(r);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..20).collect::<Vec<_>>());
        // And every queue is now empty for the other workers too.
        assert!(next_chunk(&queues, 0).is_none());
        assert!(next_chunk(&queues, 2).is_none());
    }

    #[test]
    fn storeless_executor_stats_read_off() {
        let exec = CampaignExecutor::serial();
        let st = exec.stats();
        assert!(!st.store_attached);
        assert_eq!(st.store_entries, 0);
        assert!(st.to_string().contains("store=off"));
        assert!(st.to_string().contains("quarantined=0"));
        assert!(exec.flush_store().is_ok(), "flush without store is a no-op");
    }

    #[test]
    fn retry_policy_defaults_and_clamp() {
        let p = RetryPolicy::default();
        assert_eq!(p.max_attempts, 2);
        assert!(!p.backoff.is_zero());
        let exec = CampaignExecutor::serial().with_retry_policy(RetryPolicy {
            max_attempts: 0,
            backoff: Duration::ZERO,
        });
        assert_eq!(exec.retry_policy().max_attempts, 1, "clamped to >= 1");
    }

    #[test]
    fn resume_status_diffs_grid_against_store() {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_exec_resume_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5)];
        let all: Vec<RepJob> = specs
            .iter()
            .flat_map(|s| (0..3).map(move |rep| RepJob::paper(*s, rep, 5)))
            .collect();
        {
            // Complete only the first setting's reps.
            let exec = CampaignExecutor::serial()
                .with_store(ProfileStore::open(&dir).unwrap());
            exec.run_reps(&cluster, &all[..3]);
        }
        let exec = CampaignExecutor::serial()
            .with_store(ProfileStore::open(&dir).unwrap());
        let st = exec.resume_status(&cluster, &all).unwrap();
        assert_eq!(st.total, 6);
        assert_eq!(st.done, 3);
        assert_eq!(st.quarantined, 0);
        assert_eq!(st.missing, 3);
        assert!(st.to_string().contains("3/6"));
        // Dispatching resumes exactly the remainder, bit-identically.
        let warm = exec.run_reps(&cluster, &all);
        assert_eq!(exec.cache_misses(), 3, "only the missing half simulated");
        let fresh = CampaignExecutor::serial().run_reps(&cluster, &all);
        assert_eq!(
            warm.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
            fresh.iter().map(|t| t.to_bits()).collect::<Vec<_>>()
        );
        assert!(
            exec.resume_status(&cluster, &all).unwrap().missing == 0,
            "everything on disk after the resumed run"
        );
        drop(exec);
        let _ = std::fs::remove_dir_all(&dir);
        // Without a store the diff is meaningless and must error.
        assert!(CampaignExecutor::serial()
            .resume_status(&cluster, &all)
            .is_err());
    }

    #[test]
    fn cooperative_drain_completes_solo_and_releases_leases() {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_exec_coop_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5), spec(7, 31)];
        let exec = CampaignExecutor::serial()
            .with_store(ProfileStore::open(&dir).unwrap())
            .with_cooperative(true);
        assert!(exec.cooperative());
        let solo = exec.run_specs(&cluster, &specs, 2, 21);
        assert_eq!(exec.cache_misses(), 6, "cooperative solo simulates all");
        assert_eq!(exec.quarantined(), 0);
        // Every lease was released; results match plain serial bit-for-bit.
        let leases: Vec<_> = std::fs::read_dir(dir.join("leases"))
            .unwrap()
            .collect();
        assert!(leases.is_empty(), "leases released after drain");
        let plain = CampaignExecutor::serial().run_specs(&cluster, &specs, 2, 21);
        for (a, b) in solo.iter().zip(&plain) {
            assert_eq!(a.rep_times_s, b.rep_times_s);
        }
        // A second cooperative process on the same store does zero work.
        let exec2 = CampaignExecutor::serial()
            .with_store(ProfileStore::open(&dir).unwrap())
            .with_cooperative(true);
        let again = exec2.run_specs(&cluster, &specs, 2, 21);
        assert_eq!(exec2.cache_misses(), 0, "fleet peer warm-starts");
        for (a, b) in again.iter().zip(&plain) {
            assert_eq!(a.rep_times_s, b.rep_times_s);
        }
        drop(exec);
        drop(exec2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lease_names_are_stable_and_rep_blind() {
        let key = StoreKey {
            cluster: 0xC0FFEE,
            app: AppId::Grep,
            num_mappers: 16,
            num_reducers: 4,
            input_gb_bits: 8.0f64.to_bits(),
            block_mb: 64,
            rep: 0,
            base_seed: 42,
        };
        let name = lease_name(&key);
        assert!(name.starts_with("lease-") && name.ends_with(".lock"));
        assert_eq!(name, lease_name(&key), "deterministic");
        // The rep index must not change the lease identity...
        assert_eq!(name, lease_name(&StoreKey { rep: 3, ..key }));
        // ...but every other coordinate must.
        assert_ne!(name, lease_name(&StoreKey { num_mappers: 17, ..key }));
        assert_ne!(name, lease_name(&StoreKey { base_seed: 43, ..key }));
        assert_ne!(
            name,
            lease_name(&StoreKey { app: AppId::WordCount, ..key })
        );
    }

    #[test]
    fn lease_claim_is_exclusive_and_liveness_aware() {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_exec_lease_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let lease = dir.join("lease-test.lock");
        assert!(!lease_is_live(&lease), "missing lease is free");
        assert!(try_claim_lease(&lease));
        assert!(!try_claim_lease(&lease), "second claim must fail");
        assert!(lease_is_live(&lease), "our own pid is alive");
        // A lease held by a dead pid is reclaimable (pid 0 never runs;
        // /proc/0 does not exist).
        std::fs::write(&lease, "0\n").unwrap();
        #[cfg(target_os = "linux")]
        assert!(!lease_is_live(&lease), "dead holder frees the lease");
        // Garbage content is treated as live (mid-creation).
        std::fs::write(&lease, "not-a-pid\n").unwrap();
        assert!(lease_is_live(&lease));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn panic_message_extracts_both_payload_shapes() {
        let s = std::panic::catch_unwind(|| panic!("plain literal"))
            .err()
            .map(panic_message)
            .unwrap();
        assert_eq!(s, "plain literal");
        let s = std::panic::catch_unwind(|| panic!("formatted {}", 7))
            .err()
            .map(panic_message)
            .unwrap();
        assert_eq!(s, "formatted 7");
    }
}
