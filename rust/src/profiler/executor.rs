//! Parallel, caching campaign executor.
//!
//! Profiling is the dominant cost of the paper's pipeline: every `(M, R)`
//! setting is simulated [`super::experiment::REPS`] times and averaged
//! (§IV.A), and grid sweeps (Fig. 4) multiply that by 64+ settings.  The
//! executor rebuilds that path around two ideas:
//!
//! 1. **Fan-out.** Repetitions are independent by construction — every
//!    rep derives its seed from `mix(base_seed, spec, rep)` and its HDFS
//!    layout from a session-level [`JobContext`] — so misses fan out over
//!    a `std::thread::scope` worker pool.  Results are assembled in input
//!    order, making parallel output **bit-identical** to serial for any
//!    worker count.
//! 2. **Caching.** Completed reps are cached under `(spec, rep,
//!    base_seed)`, so campaigns that overlap — train/test protocols, grid
//!    sweeps revisiting training settings, scheduler what-if replays —
//!    never re-simulate a setting.
//!
//! The executor runs the paper's standard job shape
//! ([`JobConfig::paper_default`]); the extended 4-parameter sweeps in
//! [`super::extended`] keep their own driver.
//!
//! With a [`ProfileStore`] attached ([`CampaignExecutor::with_store`]),
//! the miss path consults the on-disk store before simulating and writes
//! fresh results back, so repeated CLI invocations warm-start from every
//! prior session on the machine.  [`CampaignExecutor::stats`] reports the
//! combined in-memory + on-disk picture.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::apps::AppId;
use crate::cluster::Cluster;
use crate::mr::context::{ContextShape, JobContext};
use crate::mr::cost::AppProfile;
use crate::mr::{run_job_in, JobConfig};
use crate::util::stats;

use super::campaign::Campaign;
use super::dataset::Dataset;
use super::experiment::{mix, ExperimentResult, ExperimentSpec};
use super::store::{ProfileStore, StoreKey};

/// Cache key for one simulated repetition — [`StoreKey`], the same
/// identity the persistent store uses.  Includes a fingerprint of the
/// cluster the rep ran on: one long-lived executor may be queried with
/// several clusters (capacity what-ifs), and times from one hardware model
/// must never answer for another.
fn rep_key(cluster_fp: u64, spec: &ExperimentSpec, rep: u32, base_seed: u64) -> StoreKey {
    StoreKey {
        cluster: cluster_fp,
        app: spec.app,
        num_mappers: spec.num_mappers,
        num_reducers: spec.num_reducers,
        rep,
        base_seed,
    }
}

/// Order-sensitive digest of every simulation-relevant cluster field.
///
/// Hand-rolled (the same mixing recipe as `experiment::mix`) rather than
/// std's `DefaultHasher` because the value is persisted inside on-disk
/// [`StoreKey`] records: std's hasher algorithm is documented as
/// unstable across Rust releases, and a toolchain upgrade must not
/// silently orphan every stored rep.  Changing this recipe requires
/// bumping [`super::store::STORE_FORMAT_VERSION`].
fn cluster_fingerprint(cluster: &Cluster) -> u64 {
    fn mix(h: u64, v: u64) -> u64 {
        let x = h ^ v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x.rotate_left(23).wrapping_mul(0x94D0_49BB_1331_11EB)
    }
    let mut h = 0x6d72_7475_6e65_7221_u64; // "mrtuner!"
    h = mix(h, cluster.num_nodes() as u64);
    for node in &cluster.nodes {
        let s = &node.spec;
        h = mix(h, s.cpu_ghz.to_bits());
        h = mix(h, s.ram_bytes);
        h = mix(h, s.disk_bytes);
        h = mix(h, s.cache_kb);
        h = mix(h, s.disk_read_mbps.to_bits());
        h = mix(h, s.disk_write_mbps.to_bits());
        h = mix(h, s.map_slots as u64);
        h = mix(h, s.reduce_slots as u64);
    }
    h = mix(h, cluster.network.nic_bps.to_bits());
    h = mix(h, cluster.network.fetch_latency_s.to_bits());
    h = mix(h, cluster.network.nodes as u64);
    h
}

/// One unit of executor work: a single repetition of one setting within
/// a profiling session.
#[derive(Clone, Copy, Debug)]
pub struct RepJob {
    /// The (app, M, R) setting to simulate.
    pub spec: ExperimentSpec,
    /// Repetition index within the profiling session.
    pub rep: u32,
    /// Profiling-session seed.
    pub base_seed: u64,
}

impl RepJob {
    fn key(&self, cluster_fp: u64) -> StoreKey {
        rep_key(cluster_fp, &self.spec, self.rep, self.base_seed)
    }

    fn config(&self) -> JobConfig {
        JobConfig::paper_default(self.spec.num_mappers, self.spec.num_reducers)
            .with_seed(mix(self.base_seed, &self.spec, self.rep))
    }
}

/// The campaign executor: a worker pool plus a rep-level result cache.
///
/// One executor is meant to live for a whole analysis session (an `e2e`
/// run, a CLI invocation, a service lifetime) so overlapping campaigns
/// share both the cache and the per-session job contexts.
pub struct CampaignExecutor {
    jobs: usize,
    cache: Mutex<HashMap<StoreKey, f64>>,
    hits: AtomicU64,
    misses: AtomicU64,
    store_hits: AtomicU64,
    store: Option<ProfileStore>,
}

impl CampaignExecutor {
    /// Executor with `jobs` workers (clamped to at least 1).
    pub fn new(jobs: usize) -> CampaignExecutor {
        CampaignExecutor {
            jobs: jobs.max(1),
            cache: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            store: None,
        }
    }

    /// Attach a persistent [`ProfileStore`]: cache misses consult it
    /// before simulating, fresh results are written back, and the store
    /// is flushed at every campaign boundary (and on drop).  Warm output
    /// is bit-identical to cold output — stored values are the very rep
    /// results the executor produced.
    pub fn with_store(mut self, store: ProfileStore) -> CampaignExecutor {
        self.store = Some(store);
        self
    }

    /// The attached persistent store, if any.
    pub fn store(&self) -> Option<&ProfileStore> {
        self.store.as_ref()
    }

    /// Single-worker executor — the serial reference behaviour.
    pub fn serial() -> CampaignExecutor {
        CampaignExecutor::new(1)
    }

    /// Executor sized to the host: one worker per available core.
    pub fn machine_sized() -> CampaignExecutor {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        CampaignExecutor::new(n)
    }

    /// Worker-pool size.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Reps answered from the in-memory cache (including duplicates
    /// coalesced within one call).
    pub fn cache_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Reps actually simulated so far.
    pub fn cache_misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Reps answered from the persistent store (zero when none attached).
    pub fn store_hits(&self) -> u64 {
        self.store_hits.load(Ordering::Relaxed)
    }

    /// Distinct reps currently in the in-memory cache.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().expect("executor cache poisoned").len()
    }

    /// Combined in-memory **and** on-disk picture of this executor — the
    /// per-instance counters alone under-report once a store is attached
    /// or `--jobs` splits work across calls, so consumers should print
    /// this instead.
    pub fn stats(&self) -> ExecutorStats {
        ExecutorStats {
            jobs: self.jobs,
            simulated: self.cache_misses(),
            mem_hits: self.cache_hits(),
            store_hits: self.store_hits(),
            mem_entries: self.cache_len(),
            store_entries: self.store.as_ref().map(|s| s.len()).unwrap_or(0),
            store_attached: self.store.is_some(),
        }
    }

    /// Flush the attached store's buffered records to disk now (no-op
    /// without a store).  `run_reps` already does this at every campaign
    /// boundary; long-lived services can call it on their own cadence.
    pub fn flush_store(&self) -> Result<(), String> {
        match &self.store {
            Some(s) => s.flush(),
            None => Ok(()),
        }
    }

    /// Simulate every repetition in `items`, returning total execution
    /// times in input order.
    ///
    /// Cached reps are returned without re-simulation; misses fan out over
    /// the worker pool.  Output is bit-identical for any worker count:
    /// each rep's seed and layout derive from `(base_seed, spec, rep)`
    /// alone, never from scheduling order, and results are written back by
    /// input index.
    pub fn run_reps(&self, cluster: &Cluster, items: &[RepJob]) -> Vec<f64> {
        let cluster_fp = cluster_fingerprint(cluster);
        let mut out = vec![f64::NAN; items.len()];
        // `todo` holds the first item index per distinct missing key;
        // duplicate items within one call alias the same simulation.
        let mut todo: Vec<usize> = Vec::new();
        let mut alias: Vec<(usize, usize)> = Vec::new();
        let mut store_hit_count: u64 = 0;
        {
            let mut cache = self.cache.lock().expect("executor cache poisoned");
            let mut pending: HashMap<StoreKey, usize> = HashMap::new();
            for (i, item) in items.iter().enumerate() {
                let key = item.key(cluster_fp);
                if let Some(&t) = cache.get(&key) {
                    out[i] = t;
                } else if let Some(t) =
                    self.store.as_ref().and_then(|s| s.get(&key))
                {
                    // On-disk hit: promote into the in-memory cache so
                    // repeats within this session are memory-speed.
                    out[i] = t;
                    cache.insert(key, t);
                    store_hit_count += 1;
                } else if let Some(&k) = pending.get(&key) {
                    alias.push((i, k));
                } else {
                    pending.insert(key, todo.len());
                    todo.push(i);
                }
            }
        }
        self.store_hits.fetch_add(store_hit_count, Ordering::Relaxed);
        self.hits.fetch_add(
            items.len() as u64 - todo.len() as u64 - store_hit_count,
            Ordering::Relaxed,
        );
        self.misses.fetch_add(todo.len() as u64, Ordering::Relaxed);
        if todo.is_empty() {
            return out;
        }

        // Build each distinct (shape, session) context and each distinct
        // app profile once, up front and serially, so workers only pay for
        // event simulation — the JobContext reuse contract.  `ctx_keys[k]`
        // and `cfgs[k]` resolve todo item `k` without re-deriving anything.
        let mut contexts: HashMap<(ContextShape, u64), JobContext> = HashMap::new();
        let mut profiles: HashMap<AppId, AppProfile> = HashMap::new();
        let mut ctx_keys: Vec<(ContextShape, u64)> = Vec::with_capacity(todo.len());
        let mut cfgs: Vec<JobConfig> = Vec::with_capacity(todo.len());
        for &i in &todo {
            let item = &items[i];
            let config = item.config();
            let key = (ContextShape::of(cluster, &config), item.base_seed);
            contexts
                .entry(key)
                .or_insert_with(|| JobContext::for_session(cluster, &config, item.base_seed));
            profiles
                .entry(item.spec.app)
                .or_insert_with(|| item.spec.app.profile());
            ctx_keys.push(key);
            cfgs.push(config);
        }

        // Each todo item k simulates items[todo[k]] against its context.
        let run_one = |k: usize| -> f64 {
            let item = &items[todo[k]];
            let ctx = &contexts[&ctx_keys[k]];
            let profile = &profiles[&item.spec.app];
            run_job_in(cluster, profile, &cfgs[k], ctx).total_time_s
        };

        let workers = self.jobs.min(todo.len());
        if workers <= 1 {
            for k in 0..todo.len() {
                out[todo[k]] = run_one(k);
            }
        } else {
            let cursor = AtomicUsize::new(0);
            let computed: Vec<(usize, f64)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|_| {
                        scope.spawn(|| {
                            let mut local = Vec::new();
                            loop {
                                let k = cursor.fetch_add(1, Ordering::Relaxed);
                                if k >= todo.len() {
                                    break;
                                }
                                local.push((todo[k], run_one(k)));
                            }
                            local
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("executor worker panicked"))
                    .collect()
            });
            for (i, t) in computed {
                out[i] = t;
            }
        }

        for &(i, k) in &alias {
            out[i] = out[todo[k]];
        }

        {
            let mut cache = self.cache.lock().expect("executor cache poisoned");
            for &i in &todo {
                cache.insert(items[i].key(cluster_fp), out[i]);
            }
        }
        // Write fresh results through to the persistent store and flush:
        // every `run_reps` call is a campaign boundary, and a flush here
        // means a crash later never loses completed simulations.
        if let Some(store) = &self.store {
            for &i in &todo {
                store.put(items[i].key(cluster_fp), out[i]);
            }
            if let Err(e) = store.flush() {
                eprintln!("warn: profile store flush failed: {e}");
            }
        }
        out
    }

    /// Run `reps` repetitions of every spec (one profiling session keyed
    /// by `base_seed`), returning per-spec averaged results in spec order.
    pub fn run_specs(
        &self,
        cluster: &Cluster,
        specs: &[ExperimentSpec],
        reps: u32,
        base_seed: u64,
    ) -> Vec<ExperimentResult> {
        let items: Vec<RepJob> = specs
            .iter()
            .flat_map(|s| (0..reps).map(move |rep| RepJob { spec: *s, rep, base_seed }))
            .collect();
        let times = self.run_reps(cluster, &items);
        specs
            .iter()
            .enumerate()
            .map(|(si, s)| {
                let lo = si * reps as usize;
                let rep_times_s = times[lo..lo + reps as usize].to_vec();
                ExperimentResult {
                    spec: *s,
                    mean_time_s: stats::mean(&rep_times_s),
                    rep_times_s,
                }
            })
            .collect()
    }

    /// Run a whole campaign, returning raw results and the fitted-on
    /// dataset — the executor-backed replacement for `Campaign::run`.
    pub fn run_campaign(
        &self,
        cluster: &Cluster,
        campaign: &Campaign,
    ) -> (Vec<ExperimentResult>, Dataset) {
        let results =
            self.run_specs(cluster, &campaign.specs, campaign.reps, campaign.base_seed);
        let ds = Dataset::from_results(campaign.app, &results);
        (results, ds)
    }
}

/// Combined in-memory + on-disk executor counters, for CLI/e2e/scheduler
/// reporting.  `simulated` is the work actually done; `mem_hits` and
/// `store_hits` are the work avoided, split by which layer answered.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    /// Worker-pool size.
    pub jobs: usize,
    /// Reps simulated fresh (the executor's `cache_misses`).
    pub simulated: u64,
    /// Reps answered by the in-memory cache (incl. coalesced duplicates).
    pub mem_hits: u64,
    /// Reps answered by the persistent store.
    pub store_hits: u64,
    /// Distinct reps in the in-memory cache.
    pub mem_entries: usize,
    /// Distinct reps in the persistent store (0 when none attached).
    pub store_entries: usize,
    /// Whether a persistent store is attached.
    pub store_attached: bool,
}

impl fmt::Display for ExecutorStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "jobs={} simulated={} mem_hits={} store_hits={} mem_entries={} \
             store_entries={} store={}",
            self.jobs,
            self.simulated,
            self.mem_hits,
            self.store_hits,
            self.mem_entries,
            self.store_entries,
            if self.store_attached { "on" } else { "off" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(m: u32, r: u32) -> ExperimentSpec {
        ExperimentSpec::new(AppId::WordCount, m, r)
    }

    #[test]
    fn serial_and_parallel_reps_are_bit_identical() {
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5), spec(35, 30)];
        let serial = CampaignExecutor::serial().run_specs(&cluster, &specs, 3, 11);
        for jobs in [2, 4] {
            let par = CampaignExecutor::new(jobs).run_specs(&cluster, &specs, 3, 11);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.rep_times_s, b.rep_times_s, "jobs={jobs}");
                assert_eq!(a.mean_time_s, b.mean_time_s, "jobs={jobs}");
            }
        }
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        let specs = [spec(10, 10), spec(20, 5)];
        exec.run_specs(&cluster, &specs, 2, 3);
        assert_eq!(exec.cache_misses(), 4);
        assert_eq!(exec.cache_hits(), 0);
        assert_eq!(exec.cache_len(), 4);
        // Re-running the same session is pure cache.
        let again = exec.run_specs(&cluster, &specs, 2, 3);
        assert_eq!(exec.cache_misses(), 4);
        assert_eq!(exec.cache_hits(), 4);
        assert!(again.iter().all(|r| r.rep_times_s.iter().all(|t| t.is_finite())));
        // A different session seed must not hit.
        exec.run_specs(&cluster, &specs, 2, 4);
        assert_eq!(exec.cache_misses(), 8);
        assert_eq!(exec.cache_hits(), 4);
    }

    #[test]
    fn cached_values_equal_fresh_computation() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(2);
        let warm = exec.run_specs(&cluster, &[spec(20, 5)], 2, 9);
        let cached = exec.run_specs(&cluster, &[spec(20, 5)], 2, 9);
        let fresh = CampaignExecutor::serial().run_specs(&cluster, &[spec(20, 5)], 2, 9);
        assert_eq!(warm[0].rep_times_s, cached[0].rep_times_s);
        assert_eq!(warm[0].rep_times_s, fresh[0].rep_times_s);
    }

    #[test]
    fn duplicate_items_in_one_call_are_coalesced() {
        let cluster = Cluster::paper_cluster();
        let exec = CampaignExecutor::new(4);
        let items = [RepJob { spec: spec(20, 5), rep: 0, base_seed: 1 }; 3];
        let times = exec.run_reps(&cluster, &items);
        assert_eq!(exec.cache_misses(), 1, "one simulation for three duplicates");
        assert_eq!(exec.cache_hits(), 2);
        assert_eq!(times[0], times[1]);
        assert_eq!(times[1], times[2]);
    }

    #[test]
    fn cache_is_cluster_aware() {
        let paper = Cluster::paper_cluster();
        let mut big = Cluster::paper_cluster();
        for n in &mut big.nodes {
            n.spec.map_slots += 2;
        }
        let exec = CampaignExecutor::serial();
        let a = exec.run_specs(&paper, &[spec(20, 5)], 1, 7);
        let b = exec.run_specs(&big, &[spec(20, 5)], 1, 7);
        // Same (spec, rep, base_seed) on a different cluster must be a
        // fresh simulation, not a stale hit.
        assert_eq!(exec.cache_misses(), 2);
        assert_eq!(exec.cache_hits(), 0);
        assert_ne!(a[0].rep_times_s, b[0].rep_times_s);
    }

    #[test]
    fn executor_clamps_zero_jobs() {
        assert_eq!(CampaignExecutor::new(0).jobs(), 1);
        assert!(CampaignExecutor::machine_sized().jobs() >= 1);
    }

    #[test]
    fn stats_combine_memory_and_store() {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_exec_stats_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cluster = Cluster::paper_cluster();
        let specs = [spec(10, 10), spec(20, 5)];
        {
            let exec = CampaignExecutor::new(2)
                .with_store(ProfileStore::open(&dir).unwrap());
            exec.run_specs(&cluster, &specs, 2, 3);
            let st = exec.stats();
            assert_eq!(st.simulated, 4);
            assert_eq!(st.mem_hits, 0);
            assert_eq!(st.store_hits, 0);
            assert_eq!(st.mem_entries, 4);
            assert_eq!(st.store_entries, 4, "fresh reps written through");
            assert!(st.store_attached);
            assert!(st.to_string().contains("simulated=4"));
        }
        // A second executor on the same directory answers purely from
        // disk: zero simulations, bit-identical results.
        let cold = CampaignExecutor::serial().run_specs(&cluster, &specs, 2, 3);
        let exec2 = CampaignExecutor::new(2)
            .with_store(ProfileStore::open(&dir).unwrap());
        let warm = exec2.run_specs(&cluster, &specs, 2, 3);
        let st = exec2.stats();
        assert_eq!(st.simulated, 0);
        assert_eq!(st.store_hits, 4);
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.rep_times_s, b.rep_times_s);
        }
        drop(exec2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn storeless_executor_stats_read_off() {
        let exec = CampaignExecutor::serial();
        let st = exec.stats();
        assert!(!st.store_attached);
        assert_eq!(st.store_entries, 0);
        assert!(st.to_string().contains("store=off"));
        assert!(exec.flush_store().is_ok(), "flush without store is a no-op");
    }
}
