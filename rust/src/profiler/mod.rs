//! Profiling phase — the paper's Fig. 2a algorithm.
//!
//! "For each set of configuration parameters values S_j = (M_j, R_j): run
//! φ_i five times with S_j ... assign average total execution time as the
//! total execution time of the experiment."
//!
//! [`campaign`] chooses the settings, [`executor`] runs them (parallel
//! fan-out + rep-level cache over *any* spec shape, via [`RepSpec`],
//! with per-rep fault isolation and checkpoint/resume through the
//! store), [`store`] persists completed reps on disk so later processes
//! warm-start, [`dlq`] quarantines reps that keep failing so they never
//! abort a campaign, [`dataset`] shapes results for the regression, and
//! [`extended`] hosts the beyond-paper 4-parameter sweeps — which run
//! through the same executor and store as the paper campaigns.

pub mod campaign;
pub mod dataset;
pub mod dlq;
pub mod executor;
pub mod experiment;
pub mod extended;
pub mod store;

pub use campaign::{paper_campaign, Campaign};
pub use dataset::Dataset;
pub use dlq::DlqRecord;
pub use executor::{
    cluster_fingerprint, CampaignExecutor, ExecutorStats, RepJob, RepSpec,
    ResumeStatus, RetryPolicy,
};
pub use experiment::{
    run_experiment, ExperimentResult, ExperimentSpec, FullExperimentResult,
    REPS,
};
pub use extended::{
    ext4_rep_jobs, run_ext4, run_ext4_campaign, Ext4Result, Ext4Spec,
};
pub use store::{ProfileStore, StoreKey, StoreStats, STORE_FORMAT_VERSION};
