//! Profiling phase — the paper's Fig. 2a algorithm.
//!
//! "For each set of configuration parameters values S_j = (M_j, R_j): run
//! φ_i five times with S_j ... assign average total execution time as the
//! total execution time of the experiment."

pub mod campaign;
pub mod dataset;
pub mod executor;
pub mod experiment;
pub mod extended;

pub use campaign::{paper_campaign, Campaign};
pub use dataset::Dataset;
pub use executor::{CampaignExecutor, RepJob};
pub use experiment::{run_experiment, ExperimentResult, ExperimentSpec, REPS};
